"""Jitted public wrapper for the MoE grouped GEMM: padding + block planning."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import interpret_default, pad_dim
from repro.kernels.moe_gmm.moe_gmm import grouped_matmul as _kernel


def grouped_matmul(
    x: jnp.ndarray,          # (e, c, k)
    w: jnp.ndarray,          # (e, k, n)
    counts: jnp.ndarray | None = None,
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 256,
    out_dtype=None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = interpret_default() if interpret is None else interpret
    e, c, k = x.shape
    n = w.shape[2]
    bm, bn, bk = min(bm, c), min(bn, n), min(bk, k)
    xp = pad_dim(pad_dim(x, 1, bm), 2, bk)
    wp = pad_dim(pad_dim(w, 1, bk), 2, bn)
    out = _kernel(
        xp, wp, counts, bm=bm, bn=bn, bk=bk,
        out_dtype=out_dtype or x.dtype, interpret=interpret,
    )
    return out[:, :c, :n]
