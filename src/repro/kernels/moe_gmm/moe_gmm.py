"""MoE grouped expert GEMM Pallas kernel (capacity-based dispatch layout).

Tokens are gathered into per-expert capacity buffers (GShard-style), turning
the ragged expert matmul into a regular batched GEMM the MXU can eat:
``y[e] = x[e] @ w[e]``.

Policy story: expert weights are the interesting operand.  With few tokens
per expert (decode, high expert count) the weight tile is touched ~once —
the paper's throughput-sensitive regime: STREAM the weights, don't burn
VMEM keeping them.  With large per-expert batches the weights become
reuse-dense and the planner keeps each expert's (K, N) panel RESIDENT
across the token blocks.  Both show up here purely as block shapes/grid
from the engine's allocator.

Experts whose token count is zero are skipped entirely (`pl.when` guard) —
compute and HBM writes for empty capacity slots are elided.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(cnt_ref, x_ref, w_ref, o_ref, acc_ref, *, k_steps: int, bm: int):
    ie = pl.program_id(0)
    im = pl.program_id(1)
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Skip token blocks entirely beyond this expert's live count.
    live = cnt_ref[0] > im * bm

    @pl.when(live)
    def _():
        acc_ref[...] += jnp.dot(
            x_ref[0], w_ref[0], preferred_element_type=jnp.float32
        )

    @pl.when(kk == k_steps - 1)
    def _flush():
        rows = jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 0)
        valid = rows + im * bm < cnt_ref[0]
        o_ref[0] = jnp.where(valid, acc_ref[...], 0.0).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret")
)
def grouped_matmul(
    x: jnp.ndarray,          # (e, c, k)
    w: jnp.ndarray,          # (e, k, n)
    counts: jnp.ndarray | None = None,  # (e,)
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 256,
    out_dtype=None,
    interpret: bool = True,
) -> jnp.ndarray:
    e, c, k = x.shape
    _, _, n = w.shape
    out_dtype = out_dtype or x.dtype
    if counts is None:
        counts = jnp.full((e,), c, jnp.int32)
    bm, bn, bk = min(bm, c), min(bn, n), min(bk, k)
    assert c % bm == 0 and n % bn == 0 and k % bk == 0, (
        "caller (ops.py) must pad to block multiples"
    )
    k_steps = k // bk
    grid = (e, c // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, k_steps=k_steps, bm=bm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda ie, im, jn, kk: (ie,)),
            pl.BlockSpec((1, bm, bk), lambda ie, im, jn, kk: (ie, im, kk)),
            pl.BlockSpec((1, bk, bn), lambda ie, im, jn, kk: (ie, kk, jn)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda ie, im, jn, kk: (ie, im, jn)),
        out_shape=jax.ShapeDtypeStruct((e, c, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(counts.astype(jnp.int32), x, w)
