"""Pure-jnp oracle for the MoE grouped expert GEMM (capacity layout)."""
from __future__ import annotations

import jax.numpy as jnp


def grouped_matmul(
    x: jnp.ndarray,       # (e, c, k) tokens gathered per expert
    w: jnp.ndarray,       # (e, k, n) expert weights
    counts: jnp.ndarray | None = None,  # (e,) valid tokens per expert
    out_dtype=None,
) -> jnp.ndarray:
    e, c, k = x.shape
    out = jnp.einsum(
        "eck,ekn->ecn", x.astype(jnp.float32), w.astype(jnp.float32)
    )
    if counts is not None:
        mask = jnp.arange(c)[None, :, None] < counts[:, None, None]
        out = jnp.where(mask, out, 0.0)
    return out.astype(out_dtype or x.dtype)
