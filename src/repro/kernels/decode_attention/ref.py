"""Pure-jnp oracle for single-token GQA decode attention."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention(
    q: jnp.ndarray,        # (b, hq, d)
    k: jnp.ndarray,        # (b, hkv, s, d)
    v: jnp.ndarray,        # (b, hkv, s, d)
    lengths: jnp.ndarray | None = None,  # (b,) valid KV lengths
    scale: float | None = None,
) -> jnp.ndarray:
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum(
        "bhd,bhsd->bhs", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) * scale
    if lengths is not None:
        mask = jnp.arange(s)[None, None, :] < lengths[:, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhs,bhsd->bhd", p, vx.astype(jnp.float32))
    return o.astype(q.dtype)
