"""GQA decode attention with split-KV (flash-decoding) Pallas kernel.

Decode is the paper's throughput-sensitive regime personified: the KV cache
is a huge, zero-reuse stream (each cache line is touched exactly once per
step), so the right policy is pure STREAM with maximal HBM bandwidth —
bypass, don't cache.  The only RESIDENT_ACCUM state is the online-softmax
accumulator (hq, d), tiny and revisited every block.

``splits > 1`` partitions the KV sequence across grid workers that each
write (acc, m, l) partials; a cheap log-sum-exp combine merges them.  On
real TPUs the split dimension is marked PARALLEL so Mosaic can spread it
over cores; it is also the schedule the sequence-parallel decoder uses
across chips (see repro/distributed/sp_decode.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, cdiv, interpret_default


def _decode_kernel(
    q_ref, k_ref, v_ref, len_ref,
    acc_out, m_out, l_out,
    acc_ref, m_ref, l_ref,
    *,
    bkv: int,
    kv_steps: int,
    scale: float,
):
    s_idx = pl.program_id(2)   # split index
    ik = pl.program_id(3)      # kv block within split

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid_len = len_ref[0]
    base = (s_idx * kv_steps + ik) * bkv
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1)[0]
    mask = pos < valid_len

    q = q_ref[0].astype(jnp.float32)                    # (hq, d)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bkv, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (hq, bkv)
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(mask[None, :], jnp.exp(s - m_cur[:, None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bkv, d)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(ik == kv_steps - 1)
    def _flush():
        acc_out[0, :, 0, :] = acc_ref[...]
        m_out[0, :, 0] = m_ref[...]
        l_out[0, :, 0] = l_ref[...]


@functools.partial(
    jax.jit, static_argnames=("scale", "bkv", "splits", "interpret")
)
def decode_attention(
    q: jnp.ndarray,          # (b, hq, d)
    k: jnp.ndarray,          # (b, hkv, s, d)
    v: jnp.ndarray,          # (b, hkv, s, d)
    lengths: jnp.ndarray | None = None,   # (b,) valid lengths
    *,
    scale: float | None = None,
    bkv: int = 512,
    splits: int = 1,
    interpret: bool | None = None,
) -> jnp.ndarray:
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    interpret = interpret_default() if interpret is None else interpret
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)

    bkv = min(bkv, s)
    # Pad s so it divides evenly into splits * kv_steps * bkv.
    per_split = cdiv(cdiv(s, splits), bkv) * bkv
    s_pad = per_split * splits
    if s_pad != s:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    kv_steps = per_split // bkv

    grid = (b, hkv, splits, kv_steps)
    acc, m, l = pl.pallas_call(
        functools.partial(
            _decode_kernel, bkv=bkv, kv_steps=kv_steps, scale=scale
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, group, d), lambda ib, ih, sp, ik, g=group: (ib, ih, 0)
            ),
            pl.BlockSpec(
                (1, 1, bkv, d),
                lambda ib, ih, sp, ik, ks=kv_steps: (ib, ih, sp * ks + ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, bkv, d),
                lambda ib, ih, sp, ik, ks=kv_steps: (ib, ih, sp * ks + ik, 0),
            ),
            pl.BlockSpec((1,), lambda ib, ih, sp, ik: (ib,)),
        ],
        out_specs=[
            pl.BlockSpec(
                (1, group, 1, d), lambda ib, ih, sp, ik: (ib, ih, sp, 0)
            ),
            pl.BlockSpec((1, group, 1), lambda ib, ih, sp, ik: (ib, ih, sp)),
            pl.BlockSpec((1, group, 1), lambda ib, ih, sp, ik: (ib, ih, sp)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, splits, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, splits), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, splits), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths.astype(jnp.int32))
    return combine_partials(acc, m, l).astype(q.dtype)


def combine_partials(
    acc: jnp.ndarray,  # (b, hq, splits, d)
    m: jnp.ndarray,    # (b, hq, splits)
    l: jnp.ndarray,    # (b, hq, splits)
) -> jnp.ndarray:
    """Log-sum-exp merge of flash-decoding partials (also used across chips
    by the sequence-parallel decoder)."""
    m_glob = jnp.max(m, axis=-1, keepdims=True)
    w = jnp.exp(m - m_glob)
    l_glob = jnp.sum(l * w, axis=-1)
    num = jnp.sum(acc * w[..., None], axis=2)
    return num / jnp.maximum(l_glob, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# Paged variant: dereference the page table inside the kernel.
#
# The paged engine's KV lives in a (N, page_size, hkv, d) pool addressed
# through per-slot page tables (models/common.py, DESIGN.md §5.2).  The
# dense path pays ``gather_pages`` — an XLA copy of the whole resident
# context — before every decode step.  Here the gather disappears: the page
# table rides in as a scalar-prefetch operand, the K/V BlockSpec index maps
# dereference it per grid step, and the pool is read in place, one page per
# block.  Everything downstream (online-softmax accumulator, partials,
# combine_partials merge) is shared with the dense kernel, block for block,
# so with bkv == page_size and equal ``splits`` the two paths are
# bit-identical — the CI identity gate relies on exactly that.
# ---------------------------------------------------------------------------


def _paged_decode_kernel(
    pages_ref, len_ref,            # scalar-prefetch: (b, P) table, (b,) lens
    q_ref, k_ref, v_ref,
    acc_out, m_out, l_out,
    acc_ref, m_ref, l_ref,
    *,
    psz: int,
    page_steps: int,
    scale: float,
):
    ib = pl.program_id(0)
    s_idx = pl.program_id(2)   # split index
    ik = pl.program_id(3)      # page within split

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Same mask as the dense kernel over the gathered view: logical page
    # lp covers positions [lp*psz, (lp+1)*psz), valid below the slot's
    # cursor.  Unmapped (-1) and grid-overrun pages were clamped by the
    # index map; every lane they contribute sits at pos >= valid_len, so
    # the mask zeroes them exactly (p == 0.0, alpha == 1.0) — the paged
    # twin of gather_pages' clamp-to-page-0-then-mask contract.
    valid_len = len_ref[ib]
    base = (s_idx * page_steps + ik) * psz
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, psz), 1)[0]
    mask = pos < valid_len

    q = q_ref[0].astype(jnp.float32)                    # (group, d)
    k = k_ref[...].astype(jnp.float32)[0, :, 0]         # (psz, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(mask[None, :], jnp.exp(s - m_cur[:, None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    v = v_ref[...].astype(jnp.float32)[0, :, 0]         # (psz, d)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(ik == page_steps - 1)
    def _flush():
        acc_out[0, :, 0, :] = acc_ref[...]
        m_out[0, :, 0] = m_ref[...]
        l_out[0, :, 0] = l_ref[...]


@functools.partial(
    jax.jit, static_argnames=("scale", "splits", "interpret")
)
def paged_decode_attention(
    q: jnp.ndarray,          # (b, hq, d)
    k_pool: jnp.ndarray,     # (N, page_size, hkv, d) physical page pool
    v_pool: jnp.ndarray,     # (N, page_size, hkv, d)
    pages: jnp.ndarray,      # (b, P) int32 page table, -1 = unmapped
    lengths: jnp.ndarray | None = None,   # (b,) valid lengths, <= P*psz
    *,
    scale: float | None = None,
    splits: int = 1,
    interpret: bool | None = None,
) -> jnp.ndarray:
    b, hq, d = q.shape
    N, psz, hkv, _ = k_pool.shape
    P = pages.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    interpret = interpret_default() if interpret is None else interpret
    if lengths is None:
        lengths = jnp.full((b,), P * psz, jnp.int32)

    # One KV block per page: the page table is the block index map, so the
    # split-K decomposition is over logical pages.  Grid overrun past P
    # (when splits does not divide P) is clamped in the map and masked in
    # the kernel — an exact no-op, same as the dense kernel's zero padding.
    splits = max(1, min(int(splits), P))
    page_steps = cdiv(P, splits)
    grid = (b, hkv, splits, page_steps)

    # Index maps get the grid indices plus the scalar-prefetch refs; the
    # K/V maps dereference the table (clamping unmapped entries to page 0,
    # mirroring gather_pages) so only the referenced page is ever pulled
    # from HBM — no dense per-slot copy exists anywhere.
    kv_spec = pl.BlockSpec(
        (1, psz, 1, d),
        lambda ib, ih, sp, ik, pt, ln, ps=page_steps, Pn=P, Nn=N: (
            jnp.clip(pt[ib, jnp.minimum(sp * ps + ik, Pn - 1)], 0, Nn - 1),
            0, ih, 0,
        ),
    )
    acc, m, l = pl.pallas_call(
        functools.partial(
            _paged_decode_kernel, psz=psz, page_steps=page_steps, scale=scale
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, group, d),
                    lambda ib, ih, sp, ik, pt, ln: (ib, ih, 0),
                ),
                kv_spec,
                kv_spec,
            ],
            out_specs=[
                pl.BlockSpec(
                    (1, group, 1, d),
                    lambda ib, ih, sp, ik, pt, ln: (ib, ih, sp, 0),
                ),
                pl.BlockSpec(
                    (1, group, 1),
                    lambda ib, ih, sp, ik, pt, ln: (ib, ih, sp),
                ),
                pl.BlockSpec(
                    (1, group, 1),
                    lambda ib, ih, sp, ik, pt, ln: (ib, ih, sp),
                ),
            ],
            scratch_shapes=[
                pltpu.VMEM((group, d), jnp.float32),
                pltpu.VMEM((group,), jnp.float32),
                pltpu.VMEM((group,), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, splits, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, splits), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, splits), jnp.float32),
        ],
        interpret=interpret,
    )(pages.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pool, v_pool)
    return combine_partials(acc, m, l).astype(q.dtype)
