"""Jitted public wrapper for decode attention: split planning from the engine.

The split count is a policy decision: more splits means more parallelism on
the zero-reuse KV stream but more partial (acc, m, l) write-through traffic
— exactly the STREAM-output trade-off the cost model prices.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import CachePolicyEngine
from repro.kernels.common import interpret_default


def plan_splits(s: int, bkv: int, target_parallelism: int = 8) -> int:
    """Enough splits to feed the cores without drowning in partials."""
    blocks = max(1, s // bkv)
    return max(1, min(target_parallelism, blocks))


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray | None = None,
    *,
    scale: float | None = None,
    engine: CachePolicyEngine | None = None,
    bkv: int | None = None,
    splits: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    from repro.kernels.decode_attention.decode_attention import (
        decode_attention as _kernel,
    )

    interpret = interpret_default() if interpret is None else interpret
    s = k.shape[2]
    bkv = bkv or 512
    if splits is None:
        splits = plan_splits(s, bkv)
    return _kernel(
        q, k, v, lengths, scale=scale, bkv=min(bkv, s), splits=splits,
        interpret=interpret,
    )
