"""Jitted public wrapper for decode attention: split planning from the engine.

The split count is a policy decision: more splits means more parallelism on
the zero-reuse KV stream but more partial (acc, m, l) write-through traffic
— exactly the STREAM-output trade-off the cost model prices.  When a
``CachePolicyEngine`` is passed, its (PlanCache-memoized) plan for the
decode-shaped attention op supplies the target: one split per planned KV
block, so the grid parallelism tracks the same lattice argmin the serve
tier plans with (``ServeEngine.decode_plan`` flows through here).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import CachePolicyEngine
from repro.core.characterize import attention_op
from repro.kernels.common import cdiv, interpret_default


def plan_splits(
    s: int,
    bkv: int,
    target_parallelism: int = 8,
    *,
    plan=None,
) -> int:
    """Enough splits to feed the cores without drowning in partials.

    ``blocks`` counts the padded grid's KV blocks (cdiv — a 513-token
    stream over 512-wide blocks runs 2 grid steps, not 1), so the split
    count never exceeds the real parallelism available.  ``plan`` (a
    ``core.allocator.KernelPlan``) overrides the default target with the
    engine's own block decision: one split per engine-planned KV block.
    """
    blocks = max(1, cdiv(s, bkv))
    if plan is not None:
        planned_bkv = int(plan.block.get("bkv", bkv)) or bkv
        target_parallelism = max(1, cdiv(s, planned_bkv))
    return max(1, min(target_parallelism, blocks))


def _engine_plan(engine: CachePolicyEngine, b, hq, hkv, s, d):
    """The engine's plan for a decode-shaped attention op (sq == 1), via
    the engine's own PlanCache — repeat calls are hits, not re-sweeps."""
    return engine.plan_op(attention_op(
        b, hq, max(1, hkv), 1, s, d, causal=False, name="decode_attention",
    ))


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray | None = None,
    *,
    scale: float | None = None,
    engine: CachePolicyEngine | None = None,
    bkv: int | None = None,
    splits: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    from repro.kernels.decode_attention.decode_attention import (
        decode_attention as _kernel,
    )

    interpret = interpret_default() if interpret is None else interpret
    s = k.shape[2]
    bkv = bkv or 512
    if splits is None:
        plan = None
        if engine is not None:
            plan = _engine_plan(
                engine, q.shape[0], q.shape[1], k.shape[1], s, q.shape[2]
            )
        splits = plan_splits(s, bkv, plan=plan)
    return _kernel(
        q, k, v, lengths, scale=scale, bkv=min(bkv, s), splits=splits,
        interpret=interpret,
    )


def paged_decode_attention(
    q: jnp.ndarray,          # (b, hq, d)
    k_pool: jnp.ndarray,     # (N, page_size, hkv, d)
    v_pool: jnp.ndarray,     # (N, page_size, hkv, d)
    pages: jnp.ndarray,      # (b, P) int32, -1 = unmapped
    lengths: jnp.ndarray | None = None,
    *,
    scale: float | None = None,
    engine: CachePolicyEngine | None = None,
    splits: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Paged split-KV decode attention: the page pool read in place.

    The KV block size is pinned to the page size (the page table is the
    block index map), so split planning runs over the dense-equivalent
    width ``P * page_size`` with ``bkv = page_size`` — with equal splits
    this is bit-identical to ``gather_pages`` + :func:`decode_attention`.
    """
    from repro.kernels.decode_attention.decode_attention import (
        paged_decode_attention as _kernel,
    )

    interpret = interpret_default() if interpret is None else interpret
    psz = k_pool.shape[1]
    P = pages.shape[1]
    if splits is None:
        plan = None
        if engine is not None:
            plan = _engine_plan(
                engine, q.shape[0], q.shape[1], k_pool.shape[2],
                P * psz, q.shape[2],
            )
        splits = plan_splits(P * psz, psz, plan=plan)
    return _kernel(
        q, k_pool, v_pool, pages, lengths, scale=scale, splits=splits,
        interpret=interpret,
    )
