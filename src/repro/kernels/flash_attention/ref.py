"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention(
    q: jnp.ndarray,  # (b, hq, sq, d)
    k: jnp.ndarray,  # (b, hkv, skv, d)
    v: jnp.ndarray,  # (b, hkv, skv, d)
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) * scale
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(skv)[None, :]
        s = jnp.where(ki <= qi, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    return o.astype(q.dtype)
