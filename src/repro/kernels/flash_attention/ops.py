"""Jitted public wrapper for flash attention: plan integration."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import CachePolicyEngine
from repro.core.characterize import attention_op
from repro.kernels.common import interpret_default
from repro.kernels.flash_attention.flash_attention import flash_attention as _kernel


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
    engine: CachePolicyEngine | None = None,
    bq: int | None = None,
    bkv: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    interpret = interpret_default() if interpret is None else interpret
    if engine is not None and (bq is None or bkv is None):
        plan = engine.plan_op(
            attention_op(b, hq, hkv, sq, skv, d, causal=causal, dtype=str(q.dtype))
        )
        bq = bq or plan.block["bq"]
        bkv = bkv or plan.block["bkv"]
    return _kernel(
        q, k, v, causal=causal, scale=scale, q_offset=q_offset,
        bq=bq or 256, bkv=bkv or 256, interpret=interpret,
    )
