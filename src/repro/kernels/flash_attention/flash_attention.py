"""Causal GQA flash attention (Pallas TPU) with policy-driven KV schedule.

Online-softmax attention: grid (batch, q_head, q_blocks, kv_blocks), kv
innermost; the output tile, running max and running sum live in VMEM scratch
across the kv sweep (the RESIDENT_ACCUM policy applied to the attention
output — one HBM writeback per q tile).

KV policy shows up as block sizing from the engine's allocator: small KV
working sets get a large ``bkv`` (whole-KV-resident per (batch, kv_head)),
streaming workloads get double-buffered tiles.  GQA sharing is expressed in
the K/V index maps (q heads in a group revisit the same KV block index — the
VMEM-reuse analogue of the paper's cache hit).

``q_offset`` supports chunked prefill: query position i attends to kv
positions <= i + q_offset.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, cdiv


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    kv_steps: int,
    bq: int,
    bkv: int,
    scale: float,
    causal: bool,
    q_offset: int,
    sq_valid: int,
    skv_valid: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + q_offset
    k_pos = ik * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = k_pos < skv_valid
    if causal:
        mask &= k_pos <= q_pos

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)            # (bkv, d)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_cur

    if causal:
        # Skip kv blocks entirely above the causal diagonal.
        first_q_pos = iq * bq + q_offset
        block_needed = ik * bkv <= first_q_pos + bq - 1

        @pl.when(block_needed)
        def _():
            _body()
    else:
        _body()

    @pl.when(ik == kv_steps - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "scale", "bq", "bkv", "q_offset", "interpret",
    ),
)
def flash_attention(
    q: jnp.ndarray,      # (b, hq, sq, d)
    k: jnp.ndarray,      # (b, hkv, skv, d)
    v: jnp.ndarray,      # (b, hkv, skv, d)
    *,
    causal: bool = True,
    scale: float | None = None,
    bq: int = 256,
    bkv: int = 256,
    q_offset: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    bq = min(bq, sq)
    bkv = min(bkv, skv)

    sq_pad = cdiv(sq, bq) * bq
    skv_pad = cdiv(skv, bkv) * bkv
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    if skv_pad != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))

    kv_steps = skv_pad // bkv
    grid = (b, hq, sq_pad // bq, kv_steps)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            kv_steps=kv_steps, bq=bq, bkv=bkv, scale=scale, causal=causal,
            q_offset=q_offset, sq_valid=sq, skv_valid=skv,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec(
                (1, 1, bkv, d),
                lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, bkv, d),
                lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]
