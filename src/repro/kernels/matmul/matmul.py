"""Blocked matmul Pallas kernel with policy-selectable schedule.

Policies map to schedules (DESIGN.md §2):

* output ``RESIDENT_ACCUM`` (CacheRW analogue, default): grid iterates
  (m, n, k) with k innermost; the output tile accumulates in a VMEM fp32
  scratch and is written back exactly once — the write-coalescing policy.
  The rinse-planned order keeps the (m, n) sweep row-major so writebacks hit
  HBM in address order.
* output ``STREAM`` (write-through / split-K analogue): the K range is split
  across grid workers; each writes fp32 partials straight through to HBM and
  a cheap reduction combines them.  This is the "Uncached-writes" baseline
  the cost model charges for, and is also the right plan when M*N is tiny
  but K is huge (the reduction needs the parallelism).
* input residency (``RESIDENT`` A or B) is expressed through the grid order:
  the operand whose block index is innermost-invariant stays in VMEM across
  revisits (Pallas skips the re-copy when the block index repeats).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int, out_dtype):
    """Grid (m, n, k) or (n, m, k): k innermost, accumulate in VMEM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def _mm_splitk_kernel(a_ref, b_ref, o_ref):
    """Grid (k, m, n): every k split writes its fp32 partial through to HBM."""
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "order", "split_k", "out_dtype", "interpret"),
)
def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 256,
    order: str = "mnk",          # "mnk" (rinse row-major) or "nmk"
    split_k: int = 1,            # >1 -> STREAM-output write-through partials
    out_dtype=None,
    interpret: bool = True,
) -> jnp.ndarray:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        "caller (ops.py) must pad to block multiples"
    )

    if split_k > 1:
        ks = cdiv(k, split_k * bk) * bk          # k elems per split, bk-aligned
        split_k = cdiv(k, ks)
        grid = (split_k, m // bm, n // bn, ks // bk)

        def kern(a_ref, b_ref, o_ref, acc_ref):
            kk = pl.program_id(3)

            @pl.when(kk == 0)
            def _():
                acc_ref[...] = jnp.zeros_like(acc_ref)

            acc_ref[...] += jnp.dot(
                a_ref[...], b_ref[...], preferred_element_type=jnp.float32
            )

            @pl.when(kk == grid[3] - 1)
            def _():
                o_ref[0] = acc_ref[...]

        partials = pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda s, i, j, kk: (i, s * (ks // bk) + kk)),
                pl.BlockSpec((bk, bn), lambda s, i, j, kk: (s * (ks // bk) + kk, j)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn), lambda s, i, j, kk: (s, i, j)),
            out_shape=jax.ShapeDtypeStruct((split_k, m, n), jnp.float32),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(a, b)
        return jnp.sum(partials, axis=0).astype(out_dtype)

    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    if order == "mnk":
        a_map = lambda i, j, kk: (i, kk)
        b_map = lambda i, j, kk: (kk, j)
        o_map = lambda i, j, kk: (i, j)
    elif order == "nmk":  # column-major tile sweep (no-rinse baseline)
        grid = (n // bn, m // bm, k_steps)
        a_map = lambda j, i, kk: (i, kk)
        b_map = lambda j, i, kk: (kk, j)
        o_map = lambda j, i, kk: (i, j)
    else:
        raise ValueError(order)

    return pl.pallas_call(
        functools.partial(_mm_kernel, k_steps=k_steps, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), a_map),
            pl.BlockSpec((bk, bn), b_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
