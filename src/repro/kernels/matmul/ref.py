"""Pure-jnp oracle for the policy-parameterized matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def matmul(a: jnp.ndarray, b: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_dtype)
