"""Jitted public wrapper for the matmul kernel: padding + plan integration."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import CachePolicyEngine, Policy
from repro.core.characterize import matmul_op
from repro.kernels.common import interpret_default, pad_dim
from repro.kernels.matmul.matmul import matmul as _matmul_kernel


def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    engine: CachePolicyEngine | None = None,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    split_k: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Policy-planned blocked matmul.

    With an engine, block shapes / grid order / output policy come from the
    paper's characterize->predict->allocate pipeline; explicit kwargs
    override for benchmarking ablations.
    """
    m, k = a.shape
    _, n = b.shape
    interpret = interpret_default() if interpret is None else interpret

    if engine is not None:
        plan = engine.plan_op(matmul_op(m, k, n, dtype=str(a.dtype)))
        bm = bm or plan.block["bm"]
        bn = bn or plan.block["bn"]
        bk = bk or plan.block["bk"]
        order = "mnk" if plan.grid_order[0] == "m" else "nmk"
        if split_k is None:
            split_k = 1 if plan.policy("out") is Policy.RESIDENT_ACCUM else max(
                2, k // max(bk, 1) // 4
            )
    else:
        bm, bn, bk = bm or 256, bn or 256, bk or 256
        order = "mnk"
        split_k = split_k or 1

    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    ap = pad_dim(pad_dim(a, 0, bm), 1, bk)
    bp = pad_dim(pad_dim(b, 0, bk), 1, bn)
    out = _matmul_kernel(
        ap, bp, bm=bm, bn=bn, bk=bk, order=order, split_k=split_k,
        out_dtype=out_dtype or a.dtype, interpret=interpret,
    )
    return out[:m, :n]
