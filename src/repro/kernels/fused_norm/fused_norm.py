"""Fused (RMS/Layer)Norm (+ residual add) Pallas kernel.

The paper's throughput-sensitive class, as a kernel: activations stream
through once (reuse = 1), so the only correct policy is STREAM with
full-bandwidth row-major sweeps — the fusion (residual add + normalize +
scale in one pass) removes the extra HBM round-trips an unfused stack would
pay, which is the TPU-native way to "win" on a no-reuse layer.  The tiny
(d,) weight/bias are RESIDENT via constant index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv


def _norm_kernel(x_ref, w_ref, b_ref, r_ref, o_ref, *, eps: float, kind: str,
                 has_bias: bool, has_residual: bool):
    h = x_ref[...].astype(jnp.float32)
    if has_residual:
        h = h + r_ref[...].astype(jnp.float32)
    if kind == "layer":
        mu = jnp.mean(h, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
        y = (h - mu) * jax.lax.rsqrt(var + eps)
    else:
        ms = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
        y = h * jax.lax.rsqrt(ms + eps)
    y = y * w_ref[...].astype(jnp.float32)
    if has_bias:
        y = y + b_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("eps", "kind", "block_rows", "interpret")
)
def fused_norm(
    x: jnp.ndarray,
    weight: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    residual: jnp.ndarray | None = None,
    *,
    eps: float = 1e-6,
    kind: str = "rms",
    block_rows: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    r2 = residual.reshape(rows, d) if residual is not None else None

    br = min(block_rows, rows)
    rows_pad = cdiv(rows, br) * br
    if rows_pad != rows:
        x2 = jnp.pad(x2, ((0, rows_pad - rows), (0, 0)))
        if r2 is not None:
            r2 = jnp.pad(r2, ((0, rows_pad - rows), (0, 0)))

    has_bias = bias is not None
    has_residual = r2 is not None
    b_arg = bias if has_bias else jnp.zeros((d,), x.dtype)
    r_arg = r2 if has_residual else jnp.zeros((1, d), x.dtype)

    out = pl.pallas_call(
        functools.partial(
            _norm_kernel, eps=eps, kind=kind,
            has_bias=has_bias, has_residual=has_residual,
        ),
        grid=(rows_pad // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),       # RESIDENT weight
            pl.BlockSpec((d,), lambda i: (0,)),       # RESIDENT bias
            pl.BlockSpec(
                (br, d) if has_residual else (1, d),
                (lambda i: (i, 0)) if has_residual else (lambda i: (0, 0)),
            ),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, d), x.dtype),
        interpret=interpret,
    )(x2, weight, b_arg, r_arg)
    return out[:rows].reshape(orig_shape)
