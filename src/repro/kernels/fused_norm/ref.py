"""Pure-jnp oracle for fused (RMS/Layer)Norm + optional residual add."""
from __future__ import annotations

import jax.numpy as jnp


def fused_norm(
    x: jnp.ndarray,                   # (..., d)
    weight: jnp.ndarray,              # (d,)
    bias: jnp.ndarray | None = None,  # (d,) -> LayerNorm-style shift
    residual: jnp.ndarray | None = None,
    eps: float = 1e-6,
    kind: str = "rms",                # "rms" | "layer"
) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    if residual is not None:
        h = h + residual.astype(jnp.float32)
    if kind == "layer":
        mu = jnp.mean(h, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
        y = (h - mu) / jnp.sqrt(var + eps)
    else:
        ms = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
        y = h / jnp.sqrt(ms + eps)
    y = y * weight.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)
