"""Jitted public wrapper for fused norm."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import interpret_default
from repro.kernels.fused_norm.fused_norm import fused_norm as _kernel


def fused_norm(
    x: jnp.ndarray,
    weight: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    residual: jnp.ndarray | None = None,
    *,
    eps: float = 1e-6,
    kind: str = "rms",
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = interpret_default() if interpret is None else interpret
    return _kernel(
        x, weight, bias, residual, eps=eps, kind=kind, interpret=interpret
    )
