"""Jitted public wrapper for the Mamba-2 SSD kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import interpret_default
from repro.kernels.ssd.ssd import ssd as _kernel
from repro.kernels.ssd.ssd import ssd_decode_step  # noqa: F401 (re-export)


def ssd(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray | None = None,
    *,
    chunk: int = 128,
    interpret: bool | None = None,
):
    interpret = interpret_default() if interpret is None else interpret
    return _kernel(x, dt, A, B, C, D, chunk=chunk, interpret=interpret)
