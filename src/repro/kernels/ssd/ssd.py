"""Mamba-2 SSD chunked-scan Pallas kernel.

The state-space-duality algorithm splits the sequence into chunks: within a
chunk the output is a masked-decay matmul (MXU-friendly), across chunks a
small (ds, dh) state carries the recurrence.

Policy story (DESIGN.md §5): the inter-chunk state is a textbook
``RESIDENT_ACCUM`` operand — tiny, revisited every chunk, kept in VMEM
scratch for the whole sweep and never written to HBM until the final chunk.
x/B/C are pure ``STREAM`` operands (touched once each).  An attention-free
layer has no KV-policy site; this is its analogue.

Grid: (batch, heads, chunks) — chunks innermost so the state scratch
persists across the sequential TPU grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv


def _ssd_kernel(
    xdt_ref,   # (1, Q, 1, dh)
    alog_ref,  # (1, Q, 1)
    b_ref,     # (1, Q, 1, ds)
    c_ref,     # (1, Q, 1, ds)
    y_ref,     # (1, Q, 1, dh)
    sout_ref,  # (1, 1, ds, dh)
    s_ref,     # scratch (ds, dh) fp32 — the RESIDENT_ACCUM state
    *,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    xdt = xdt_ref[0, :, 0, :].astype(jnp.float32)    # (Q, dh)
    alog = alog_ref[0, :, 0].astype(jnp.float32)     # (Q,)
    bmat = b_ref[0, :, 0, :].astype(jnp.float32)     # (Q, ds)
    cmat = c_ref[0, :, 0, :].astype(jnp.float32)     # (Q, ds)

    cum = jnp.cumsum(alog)                           # inclusive decay cumsum
    q = alog.shape[0]
    ti = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    # L[t, s] = exp(cum_t - cum_s) for s <= t (decay accumulated after s).
    # Mask before exp: s>t lanes have positive diffs that overflow.
    lmat = jnp.exp(
        jnp.where(si <= ti, cum[:, None] - cum[None, :], -jnp.inf)
    )

    cb = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)  # (Q, Q)
    y_intra = jnp.dot(cb * lmat, xdt, preferred_element_type=jnp.float32)
    y_inter = jnp.exp(cum)[:, None] * jnp.dot(
        cmat, s_ref[...], preferred_element_type=jnp.float32
    )
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # State update: S <- exp(cum_Q) S + sum_s exp(cum_Q - cum_s) B_s xdt_s.
    total = cum[-1]
    b_scaled = bmat * jnp.exp(total - cum)[:, None]
    s_ref[...] = s_ref[...] * jnp.exp(total) + jnp.dot(
        b_scaled.T, xdt, preferred_element_type=jnp.float32
    )

    @pl.when(ic == n_chunks - 1)
    def _flush():
        sout_ref[0, 0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jnp.ndarray,    # (b, l, h, dh)
    dt: jnp.ndarray,   # (b, l, h)
    A: jnp.ndarray,    # (h,)
    B: jnp.ndarray,    # (b, l, g, ds)
    C: jnp.ndarray,    # (b, l, g, ds)
    D: jnp.ndarray | None = None,   # (h,)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, final_state) matching ref.ssd."""
    b, l, h, dh = x.shape
    g, ds = B.shape[2], B.shape[3]
    hpg = h // g
    chunk = min(chunk, l)
    l_pad = cdiv(l, chunk) * chunk
    if l_pad != l:
        # dt = 0 on padding => decay exp(0)=1, no state contribution.
        x = jnp.pad(x, ((0, 0), (0, l_pad - l), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, l_pad - l), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, l_pad - l), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, l_pad - l), (0, 0), (0, 0)))
    n_chunks = l_pad // chunk

    # Cheap streaming precompute (elementwise, fused by XLA).
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    alog = dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :]

    grid = (b, h, n_chunks)
    y, s_final = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, dh), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec(
                (1, chunk, 1, ds), lambda ib, ih, ic, s=hpg: (ib, ic, ih // s, 0)
            ),
            pl.BlockSpec(
                (1, chunk, 1, ds), lambda ib, ih, ic, s=hpg: (ib, ic, ih // s, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, dh), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, ds, dh), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l_pad, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, h, ds, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((ds, dh), jnp.float32)],
        interpret=interpret,
    )(xdt, alog, B, C)

    y = y[:, :l]
    if D is not None:
        y = y + D[None, None, :, None] * x[:, :l].astype(jnp.float32)
    return y.astype(x.dtype), s_final


def ssd_decode_step(
    x: jnp.ndarray,    # (b, h, dh) one token
    dt: jnp.ndarray,   # (b, h)
    A: jnp.ndarray,    # (h,)
    B: jnp.ndarray,    # (b, g, ds)
    C: jnp.ndarray,    # (b, g, ds)
    D: jnp.ndarray | None,
    state: jnp.ndarray,  # (b, h, ds, dh) fp32
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """O(1)-state single-token update (pure jnp — bandwidth-bound on state)."""
    b, h, dh = x.shape
    g = B.shape[1]
    hpg = h // g
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bx = jnp.repeat(B.astype(jnp.float32), hpg, axis=1)
    Cx = jnp.repeat(C.astype(jnp.float32), hpg, axis=1)
    decay = jnp.exp(dtf * A[None, :])[..., None, None]
    state = state * decay + (dtf[..., None] * Bx)[..., None] * xf[..., None, :]
    y = jnp.einsum("bhs,bhsd->bhd", Cx, state)
    if D is not None:
        y = y + D[None, :, None] * xf
    return y.astype(x.dtype), state
