"""Pure-jnp oracle for the Mamba-2 SSD layer: sequential state recurrence.

    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * outer(B_t, x_t)
    y_t = C_t @ S_t + D_h * x_t

with B/C shared across the heads of a group (n_groups <= n_heads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd(
    x: jnp.ndarray,    # (b, l, h, dh)
    dt: jnp.ndarray,   # (b, l, h)      positive step sizes
    A: jnp.ndarray,    # (h,)           negative decay rates
    B: jnp.ndarray,    # (b, l, g, ds)
    C: jnp.ndarray,    # (b, l, g, ds)
    D: jnp.ndarray | None = None,  # (h,) skip
    init_state: jnp.ndarray | None = None,  # (b, h, ds, dh)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, l, h, dh = x.shape
    g = B.shape[2]
    ds = B.shape[3]
    hpg = h // g
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), hpg, axis=2)  # (b, l, h, ds)
    Cf = jnp.repeat(C.astype(jnp.float32), hpg, axis=2)

    def step(S, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,dh), (b,h), (b,h,ds), (b,h,ds)
        decay = jnp.exp(dtt * A[None, :])[..., None, None]       # (b,h,1,1)
        S = S * decay + (dtt[..., None] * Bt)[..., None] * xt[..., None, :]
        y = jnp.einsum("bhs,bhsd->bhd", Ct, S)
        return S, y

    S0 = (
        jnp.zeros((b, h, ds, dh), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    S, ys = jax.lax.scan(step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (b, l, h, dh)
    if D is not None:
        y = y + D[None, None, :, None] * xf
    return y.astype(x.dtype), S
