"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each kernel package ships <name>.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jitted wrapper with policy-engine planning and padding) and
ref.py (pure-jnp oracle used by the allclose test sweeps).
"""
