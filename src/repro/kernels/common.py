"""Shared helpers for the Pallas TPU kernels.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are validated
on CPU in interpret mode.  ``interpret_default()`` picks the mode from the
backend so the same ops run on both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.cache
def interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(x: int, m: int) -> int:
    return cdiv(x, m) * m


def pad_dim(x: jnp.ndarray, axis: int, multiple: int, value=0.0) -> jnp.ndarray:
    size = x.shape[axis]
    pad = round_up(size, multiple) - size
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


NEG_INF = -1e30
