"""Deterministic, step-indexed data pipeline.

Restart-exactness is the fault-tolerance contract: batch(step) is a pure
function of (seed, step), so resuming from a checkpoint at step k replays
the identical stream with NO loader state to persist.  Sources:

* ``SyntheticLM`` — seeded token stream (plus stub vis/frames for VLM and
  enc-dec archs).
* ``MemmapLM`` — a flat uint16/uint32 token file (np.memmap), sampled at
  deterministic offsets; the standard "one big packed corpus" layout.

``Prefetcher`` overlaps host batch synthesis with device compute (a small
background thread pipeline, depth-bounded).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Iterator

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed

    def __call__(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )
        toks = rng.integers(
            0, self.cfg.vocab, size=(self.batch, self.seq + 1), dtype=np.int32
        )
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "vlm":
            batch["vis"] = rng.standard_normal(
                (self.batch, self.cfg.n_vis_tokens, self.cfg.d_model),
                dtype=np.float32,
            )
        if self.cfg.family == "encdec":
            batch["frames"] = rng.standard_normal(
                (self.batch, min(self.cfg.enc_seq, self.seq), self.cfg.d_model),
                dtype=np.float32,
            )
        return batch


class MemmapLM:
    """Packed-token corpus: deterministic strided sampling by step."""

    def __init__(self, path: str, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0):
        self.tokens = np.memmap(path, dtype=np.uint32, mode="r")
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self.n_windows = (len(self.tokens) - 1) // (seq + 1)
        assert self.n_windows >= batch, "corpus too small for batch"

    def __call__(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        idx = rng.choice(self.n_windows, size=self.batch, replace=False)
        rows = np.stack([
            self.tokens[i * (self.seq + 1):(i + 1) * (self.seq + 1)]
            for i in idx
        ]).astype(np.int32)
        rows = np.minimum(rows, self.cfg.vocab - 1)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


class Prefetcher:
    """Depth-bounded background prefetch of step-indexed batches."""

    def __init__(self, source, start_step: int, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict[str, Any]]]:
        while True:
            yield self.q.get()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
