"""Small pytree helpers used across the framework."""
from __future__ import annotations

import jax
import numpy as np


def tree_param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return total


def tree_zeros_like(tree, dtype=None):
    import jax.numpy as jnp

    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )
