"""On-device n-gram draft proposer for speculative decode (DESIGN.md §5.3).

Prompt-lookup drafting: each slot's draft is the continuation of the most
recent *earlier* occurrence of its current ``spec_ngram``-token suffix in
that slot's own history (prompt + emitted tokens).  No draft model, no
extra weights, no host sync — the proposer is a few gathers/compares over
the (slots, max_len + 1) history buffer the engine already maintains, so it
runs inside the jitted verify dispatch.

A wrong draft costs nothing but acceptance (the verify pass rolls it back);
when no earlier occurrence exists the proposer falls back to repeating the
slot's last token, which keeps the verify dispatch shape static.
"""
from __future__ import annotations

import jax.numpy as jnp


def ngram_propose(hist: jnp.ndarray, hist_len: jnp.ndarray,
                  ngram: int, k: int) -> jnp.ndarray:
    """Draft ``k`` tokens per slot by suffix match over each slot's history.

    ``hist``: (b, H) int32 token history (prompt + emitted, including the
    not-yet-consumed current token at ``hist_len - 1``); ``hist_len``: (b,)
    int32 valid prefix lengths.  Returns (b, k) int32 drafts.

    Slot b's suffix is its last ``ngram`` tokens.  A candidate start p
    matches iff ``hist[b, p:p+ngram]`` equals the suffix and the window lies
    strictly before the suffix's own occurrence (``p < hist_len - ngram``).
    The draft is the ``k`` tokens following the LAST match (most recent
    context wins); positions past the valid prefix — and slots with no
    match or with ``hist_len < ngram`` — fall back to the last token."""
    b, H = hist.shape
    idx = jnp.arange(H)[None, :]                              # (1, H)
    # Suffix tokens: hist[b, hist_len - ngram + i]; clipped gathers on
    # short histories read garbage that the validity mask below discards.
    suf_pos = hist_len[:, None] - ngram + jnp.arange(ngram)[None, :]
    suffix = jnp.take_along_axis(hist, jnp.clip(suf_pos, 0, H - 1), axis=1)
    # match[b, p] = AND_i hist[b, p+i] == suffix[b, i], via ngram static
    # shifts of a -1-padded history (token ids are >= 0, so the pad never
    # spuriously matches).
    padded = jnp.pad(hist, ((0, 0), (0, ngram)), constant_values=-1)
    match = idx < (hist_len[:, None] - ngram)                 # p strictly earlier
    for i in range(ngram):
        match = match & (padded[:, i:i + H] == suffix[:, i:i + 1])
    p_star = jnp.max(jnp.where(match, idx, -1), axis=1)       # (b,) last match
    last = jnp.take_along_axis(
        hist, jnp.clip(hist_len - 1, 0, H - 1)[:, None], axis=1
    )                                                          # (b, 1) fallback
    dpos = p_star[:, None] + ngram + jnp.arange(k)[None, :]    # (b, k)
    ok = (p_star[:, None] >= 0) & (dpos < hist_len[:, None])
    cont = jnp.take_along_axis(hist, jnp.clip(dpos, 0, H - 1), axis=1)
    return jnp.where(ok, cont, last).astype(jnp.int32)
