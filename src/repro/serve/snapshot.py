"""Crash-safe serving: snapshot files + the append-only request journal
(DESIGN.md §5.6).

The whole crash-recovery design rides one invariant the lifecycle layer
already proved at slot granularity (§5.5): every byte of device KV is a
deterministic function of host-side truth — prompt + emitted tokens,
seeds and token indices — via the recompute-prefill path, and the
`(seed, token index)` sampler keys make the regenerated stream
bit-identical regardless of scheduling.  So a snapshot serializes ONLY
host-side truth; no device buffer is ever written to disk, and restore
rebuilds all device state through ordinary re-admission.

Two artifacts cooperate:

* **Snapshot file** — a single JSON document ``{"magic", "version",
  "checksum", "payload"}`` written atomically (tmp + fsync + rename).
  The checksum is a SHA-256 over the canonical payload encoding, so a
  torn/bit-rotted snapshot is rejected with a typed ``SnapshotError``
  before any state is touched.  The payload carries a config
  fingerprint (all knobs except the chaos/strict ones — a restore may
  legitimately run with crash injection off), engine geometry, every
  request record (terminal ones keep their streams; in-flight ones
  re-enter the queue), allocator refcounts + page tables (audited for
  consistency, then rebuilt live), the quarantine set, and the journal
  offset at snapshot time.
* **Request journal** — an append-only JSON-lines file recording
  ``submit`` events (the full request payload) and ``terminal`` events
  (id, final status, emitted tokens), fsync'd at every chunk boundary.
  After an unplanned kill, ``restore`` replays the journal suffix past
  the snapshot's offset: re-submitted requests regenerate their streams
  deterministically, and journaled terminal events re-retire requests
  with the exact tokens they had emitted — recovery lands on the last
  flushed chunk boundary, bit-identical from there on.

This module is engine-agnostic on purpose (no import of
``serve.engine``): it reads/writes plain dicts, and the engine owns the
mapping to/from live ``Request`` objects.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any, Iterator

SNAPSHOT_MAGIC = "repro-serve-snapshot"
SNAPSHOT_VERSION = 1

# Knobs excluded from the config fingerprint: fault injection, the
# strict-invariant sweep, and the adaptive cache policy change no
# observable stream (that is their acceptance gate — adaptation is
# placement-only), and recovery typically runs with the crash knobs OFF
# that the crashed run had on.  Excluding the adaptive knobs also lets
# an adaptive engine restore a static engine's snapshot and vice versa.
_FINGERPRINT_EXCLUDE = (
    "chaos_alloc_fail_p", "chaos_preempt_p", "chaos_seed",
    "chaos_share_fail_p", "chaos_corrupt_p", "chaos_crash_after_wave",
    "strict_invariants", "kv_integrity",
    "adaptive", "warm_pages", "adaptive_replan_every",
)


class SnapshotError(RuntimeError):
    """A snapshot/journal that must not be restored, with a
    machine-readable ``reason``: "unreadable" (missing/torn/not JSON),
    "bad_magic", "version", "checksum" (payload bytes don't hash to the
    recorded digest), "config_mismatch", "geometry_mismatch",
    "inconsistent" (internal audit failed, e.g. refcounts vs. page
    tables), or "no_source" (restore with neither snapshot nor
    journal)."""

    def __init__(self, reason: str, message: str):
        super().__init__(message)
        self.reason = reason


def cfg_fingerprint(cfg) -> dict[str, Any]:
    """JSON-safe view of every identity-relevant config knob."""
    import dataclasses
    d = dataclasses.asdict(cfg)
    return {k: v for k, v in d.items() if k not in _FINGERPRINT_EXCLUDE}


def _canonical(payload: dict) -> str:
    return json.dumps(payload, sort_keys=True, separators=(",", ":"))


def _digest(payload: dict) -> str:
    return hashlib.sha256(_canonical(payload).encode()).hexdigest()


def write_snapshot(path: str, payload: dict) -> None:
    """Atomically write a checksummed snapshot: tmp file + fsync +
    rename, so a crash DURING snapshotting leaves either the previous
    snapshot or none — never a torn one."""
    doc = {
        "magic": SNAPSHOT_MAGIC,
        "version": SNAPSHOT_VERSION,
        "checksum": _digest(payload),
        "payload": payload,
    }
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_snapshot(path: str) -> dict:
    """Read + validate a snapshot, returning its payload.  Every failure
    mode raises a typed ``SnapshotError`` — a corrupt snapshot is
    rejected before the engine discards any live state."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        raise SnapshotError("unreadable", f"snapshot {path!r}: {e}") from e
    if not isinstance(doc, dict) or doc.get("magic") != SNAPSHOT_MAGIC:
        raise SnapshotError("bad_magic", f"{path!r} is not a serve snapshot")
    if doc.get("version") != SNAPSHOT_VERSION:
        raise SnapshotError("version", (
            f"snapshot version {doc.get('version')!r}, "
            f"engine speaks {SNAPSHOT_VERSION}"
        ))
    payload = doc.get("payload")
    if not isinstance(payload, dict) or _digest(payload) != doc.get("checksum"):
        raise SnapshotError("checksum", (
            f"snapshot {path!r} failed its integrity check "
            "(torn write or bit rot)"
        ))
    return payload


class RequestJournal:
    """Append-only JSON-lines request journal with explicit fsync.

    Events are buffered in memory and durably flushed at chunk
    boundaries (``ServeEngine.step`` calls ``flush``), so the on-disk
    journal always ends at a scheduling boundary — exactly the point
    recovery replays to.  An event that was buffered but never flushed
    when the process died is indistinguishable from the request
    finishing a moment later; determinism regenerates it.
    """

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a")
        self._pending: list[str] = []

    def append(self, event: dict) -> None:
        self._pending.append(json.dumps(event, sort_keys=True))

    def flush(self) -> None:
        if not self._pending:
            return
        self._f.write("".join(line + "\n" for line in self._pending))
        self._pending.clear()
        self._f.flush()
        os.fsync(self._f.fileno())

    def offset(self) -> int:
        """Durable byte offset after flushing — recorded in snapshots so
        replay starts exactly past the events the snapshot subsumes."""
        self.flush()
        return self._f.tell()

    def close(self) -> None:
        self.flush()
        self._f.close()

    @staticmethod
    def replay(path: str, offset: int = 0) -> Iterator[dict]:
        """Yield events from ``offset`` on.  A trailing partial line
        (the write the crash interrupted) is skipped, not an error — the
        journal is only ever appended to, so everything before it is
        intact."""
        try:
            f = open(path)
        except OSError as e:
            raise SnapshotError(
                "unreadable", f"journal {path!r}: {e}"
            ) from e
        with f:
            f.seek(offset)
            for line in f:
                if not line.endswith("\n"):
                    break
                try:
                    ev = json.loads(line)
                except ValueError:
                    break
                if isinstance(ev, dict):
                    yield ev


def request_record(r, status: str | None = None) -> dict:
    """Serialize a live ``Request`` (duck-typed) to a JSON-safe record.
    ``slot``/``admit_seq`` are deliberately absent: residency is rebuilt
    by ordinary re-admission, never deserialized."""
    return {
        "id": r.id,
        "prompt": [int(t) for t in r.prompt],
        "max_new_tokens": int(r.max_new_tokens),
        "seed": None if r.seed is None else int(r.seed),
        "deadline_s": r.deadline_s,
        "max_queue_wait_s": r.max_queue_wait_s,
        "generated": [int(t) for t in r.generated],
        "status": status or r.status,
        "preempted_n": int(r.preempted_n),
        "cancel_requested": bool(r.cancel_requested),
        "ttft_s": r.ttft_s,
        "queue_wait_s": r.queue_wait_s,
    }


def submit_event(r) -> dict:
    return {
        "ev": "submit",
        "id": r.id,
        "prompt": [int(t) for t in r.prompt],
        "max_new_tokens": int(r.max_new_tokens),
        "seed": None if r.seed is None else int(r.seed),
        "deadline_s": r.deadline_s,
        "max_queue_wait_s": r.max_queue_wait_s,
    }


def terminal_event(r) -> dict:
    return {
        "ev": "terminal",
        "id": r.id,
        "status": r.status,
        "generated": [int(t) for t in r.generated],
    }
