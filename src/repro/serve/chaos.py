"""Fault injection for the serve tier (DESIGN.md §5.5, crash/integrity §5.6).

Robustness of the engine's lifecycle state machine is only credible if
the failure paths actually run.  This module makes them run on demand:

* ``ChaosAllocator`` — a ``PageAllocator`` that, with seeded probability
  ``fail_p``, refuses an otherwise-satisfiable ``alloc``, and with
  ``share_fail_p`` an otherwise-satisfiable ``share`` (the alloc-own-
  then-share admission ordering's second failure point).  An injected
  failure is indistinguishable from genuine pool exhaustion to the
  engine, so it exercises the same gating/preemption/retry paths, while
  staying atomic (nothing popped, nothing referenced) and fully
  reproducible from the seed.
* forced preemptions — the engine consults ``cfg.chaos_preempt_p`` at
  wave boundaries and preempts a healthy resident (see
  ``ServeEngine._admit_wave``); that logic lives in the engine, this
  module only supplies the seeded RNG convention.
* crash points — ``cfg.chaos_crash_after_wave`` makes the engine raise
  ``ChaosCrash`` at the end of the step that completed admission wave N
  (journal flushed first, so on-disk state sits at a chunk boundary);
  the recovery harness restores a fresh engine from snapshot + journal.
* page corruption — ``cfg.chaos_corrupt_p`` flips a value inside a
  fingerprint-stamped KV page on device; ``verify_pages()`` must detect,
  quarantine and recompute-heal it (DESIGN.md §5.6).

Because every drop of state an injected fault perturbs is recomputed
from host-side truth (tokens, refcounts, page tables), a chaos run must
stay BIT-IDENTICAL to the fault-free run and end with zero leaked
pages — that is the acceptance gate in tests and the CI chaos leg.
"""
from __future__ import annotations

import numpy as np

from repro.serve.alloc import PageAllocator


class ChaosCrash(RuntimeError):
    """Injected process kill (``cfg.chaos_crash_after_wave``).

    Raised at the end of a step, after the request journal has been
    flushed, so the on-disk snapshot + journal state corresponds exactly
    to a chunk boundary.  The crashed engine object is dead by contract:
    recovery constructs a fresh engine and calls ``restore``.
    """

    def __init__(self, wave: int):
        super().__init__(f"injected crash after admission wave {wave}")
        self.wave = wave


class ChaosAllocator(PageAllocator):
    """``PageAllocator`` with seeded, probabilistic alloc/share failures.

    Only positive-size calls can fail (``alloc(0)``/``share([])`` are
    no-ops the engine uses for fully-shared and fully-owned prefixes;
    failing them would fabricate a gating state the real allocator can
    never produce).  ``last_injected`` lets tests distinguish an injected
    refusal from a genuine out-of-pages refusal on the immediately
    preceding call.  Both failure modes are atomic: a refused ``share``
    perturbs no refcount, exactly as a refused ``alloc`` pops nothing.
    """

    def __init__(self, n_pages: int, fail_p: float, seed: int = 0,
                 share_fail_p: float = 0.0, warm_budget: int = 0):
        super().__init__(n_pages, warm_budget=warm_budget)
        assert 0.0 <= fail_p <= 1.0, fail_p
        assert 0.0 <= share_fail_p <= 1.0, share_fail_p
        self.fail_p = fail_p
        self.share_fail_p = share_fail_p
        self._rng = np.random.default_rng(seed)
        self.injected_failures = 0
        self.injected_share_failures = 0
        self.last_injected = False

    def alloc(self, n: int) -> list[int] | None:
        self.last_injected = False
        if n > 0 and self.fail_p > 0.0 and self._rng.random() < self.fail_p:
            self.injected_failures += 1
            self.last_injected = True
            return None
        return super().alloc(n)

    def share(self, ids) -> bool:
        self.last_injected = False
        ids = list(ids)
        if (ids and self.share_fail_p > 0.0
                and self._rng.random() < self.share_fail_p):
            self.injected_share_failures += 1
            self.last_injected = True
            return False
        return super().share(ids)
