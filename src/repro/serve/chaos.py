"""Fault injection for the serve tier (DESIGN.md §5.5).

Robustness of the engine's lifecycle state machine is only credible if
the failure paths actually run.  This module makes them run on demand:

* ``ChaosAllocator`` — a ``PageAllocator`` that, with seeded probability
  ``fail_p``, refuses an otherwise-satisfiable ``alloc``.  An injected
  failure is indistinguishable from genuine pool exhaustion to the
  engine, so it exercises the same gating/preemption/retry paths, while
  staying atomic (nothing popped, nothing referenced) and fully
  reproducible from the seed.
* forced preemptions — the engine consults ``cfg.chaos_preempt_p`` at
  wave boundaries and preempts a healthy resident (see
  ``ServeEngine._admit_wave``); that logic lives in the engine, this
  module only supplies the seeded RNG convention.

Because every drop of state an injected fault perturbs is recomputed
from host-side truth (tokens, refcounts, page tables), a chaos run must
stay BIT-IDENTICAL to the fault-free run and end with zero leaked
pages — that is the acceptance gate in tests and the CI chaos leg.
"""
from __future__ import annotations

import numpy as np

from repro.serve.alloc import PageAllocator


class ChaosAllocator(PageAllocator):
    """``PageAllocator`` with seeded, probabilistic alloc failures.

    Only positive-size allocations can fail (``alloc(0)`` is a no-op the
    engine uses for fully-shared prefixes; failing it would fabricate a
    gating state the real allocator can never produce).  ``last_injected``
    lets tests distinguish an injected refusal from a genuine
    out-of-pages refusal on the immediately preceding call.
    """

    def __init__(self, n_pages: int, fail_p: float, seed: int = 0):
        super().__init__(n_pages)
        assert 0.0 <= fail_p <= 1.0, fail_p
        self.fail_p = fail_p
        self._rng = np.random.default_rng(seed)
        self.injected_failures = 0
        self.last_injected = False

    def alloc(self, n: int) -> list[int] | None:
        self.last_injected = False
        if n > 0 and self.fail_p > 0.0 and self._rng.random() < self.fail_p:
            self.injected_failures += 1
            self.last_injected = True
            return None
        return super().alloc(n)
