"""Device-side sampling for the serve engine (DESIGN.md §5.3).

``Sampler`` generalizes the engine's original ``greedy_sample`` to
temperature / top-k / top-p, all computed on device inside the jitted
prefill / chunk-scan dispatches (the PRNG key rides the scan carry as a
per-slot ``(seed, token-index)`` pair, not a key tensor).

Determinism contract: the key for token ``i`` of a request with seed ``s``
is ``fold_in(fold_in(base, s), i)`` — a pure function of the *request*, not
of the slot it landed in or of which other requests share the batch.  Two
consequences the tests pin down:

* re-ordered submissions reproduce identical token streams per request
  (``tests/test_serve.py::test_seeded_sampling_order_independent``);
* speculative verification can recompute the exact token the
  non-speculative path would have sampled at any position, which is what
  makes spec decode output-identical under every sampling mode, not just
  greedy (the verify pass samples position ``j`` with the key for token
  index ``tok_idx + j``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Folding a per-request seed and a per-request token index into this base
# key gives each (request, position) pair an independent stream.
_BASE_KEY = 0x5EED


def sample_keys(seeds: jnp.ndarray, tok_idx: jnp.ndarray) -> jnp.ndarray:
    """Per-slot PRNG keys folded from (request seed, token index).

    ``seeds``/``tok_idx``: (n,) int32 -> (n, 2) uint32 keys.  Independent of
    slot assignment and batch composition by construction."""

    def one(seed, idx):
        k = jax.random.fold_in(jax.random.PRNGKey(_BASE_KEY), seed)
        return jax.random.fold_in(k, idx)

    return jax.vmap(one)(seeds, tok_idx)


@dataclasses.dataclass(frozen=True)
class Sampler:
    """greedy | temperature | top_k | top_p over the last-position logits."""

    mode: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.mode not in ("greedy", "temperature", "top_k", "top_p"):
            raise ValueError(f"unknown sampling mode: {self.mode!r}")
        if self.mode == "top_k" and self.top_k < 1:
            raise ValueError("top_k mode needs top_k >= 1")
        if self.mode == "top_p" and not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p mode needs 0 < top_p <= 1")

    @classmethod
    def from_config(cls, cfg) -> "Sampler":
        return cls(mode=cfg.sampling, temperature=cfg.temperature,
                   top_k=cfg.top_k, top_p=cfg.top_p)

    @property
    def needs_keys(self) -> bool:
        return self.mode != "greedy"

    def _mask_logits(self, lf: jnp.ndarray) -> jnp.ndarray:
        """Apply the mode's support restriction to (n, v) fp32 logits."""
        v = lf.shape[-1]
        if self.mode == "top_k":
            k = min(self.top_k, v)
            kth = jnp.sort(lf, axis=-1)[:, v - k][:, None]
            return jnp.where(lf >= kth, lf, -jnp.inf)
        if self.mode == "top_p":
            desc = jnp.sort(lf, axis=-1)[:, ::-1]
            probs = jax.nn.softmax(desc, axis=-1)
            csum = jnp.cumsum(probs, axis=-1)
            # Keep the smallest prefix whose mass reaches top_p: a token
            # survives iff the mass strictly before it is < top_p (the
            # first token always survives).
            keep = (csum - probs) < self.top_p
            thr = jnp.min(jnp.where(keep, desc, jnp.inf), axis=-1,
                          keepdims=True)
            return jnp.where(lf >= thr, lf, -jnp.inf)
        return lf

    def __call__(self, logits: jnp.ndarray,
                 keys: jnp.ndarray | None = None) -> jnp.ndarray:
        """(n, v) or (n, s, v) logits -> (n,) int32 sampled tokens.

        3-D logits sample the last position (the engine's prefill path).
        ``keys`` ((n, 2) uint32, from :func:`sample_keys`) is required for
        the stochastic modes and ignored by greedy."""
        if logits.ndim == 3:
            logits = logits[:, -1]
        lf = logits.astype(jnp.float32)
        if self.mode == "greedy":
            return jnp.argmax(lf, axis=-1).astype(jnp.int32)
        assert keys is not None, f"{self.mode} sampling needs PRNG keys"
        # Gumbel-max over temperature-scaled, support-masked logits.  As
        # temperature -> 0 the scaled gaps dwarf the Gumbel noise, so the
        # sample converges to exact argmax (tests pin this down).
        lf = self._mask_logits(lf / max(self.temperature, 1e-8))
        return jax.vmap(jax.random.categorical)(keys, lf).astype(jnp.int32)


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    """The seed engine's sampler (kept for callers that want bare argmax)."""
    return jnp.argmax(logits[:, -1], axis=-1)
