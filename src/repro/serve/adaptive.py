"""Adaptive serve-tier cache policy (DESIGN.md §5.7).

The paper's finding — no static GPU caching policy wins across MI
workloads, while runtime adaptation matches the best static choice —
applied to the serve tier's own caches.  ``AdaptivePolicy`` consumes the
engine's runtime counters (prefix hit rate over *fresh* admissions, page
reuse distance via a bounded last-touch ring, speculative-decode
acceptance, preemption/recompute cost) and drives three decisions the
static engine hard-codes:

* **warm prefix retention** — when a slot releases its pages, trie-
  registered prefix pages may be *retained* in the allocator's bounded
  warm tier (``cfg.warm_pages``) instead of freed, so a later request
  with the same prefix revives them without re-prefilling; warm pages
  are reclaimed (reuse-distance rank) when capacity is needed;
* **cost-aware preemption** — the eviction victim is the resident with
  the lowest estimated cost-to-recompute (prefill tokens to replay,
  discounted for shared pages that stay resident anyway) instead of
  youngest-first;
* **per-workload policy selection** — at re-plan boundaries (every
  ``cfg.adaptive_replan_every`` admission waves) the observed counters
  feed ``core.sweep.serve_policy_argmin``, an exact argmin over the
  (retention fraction x eviction rank x bypass) lattice, picking the
  combo per workload class.

Workload classes are keyed by the CRC32 of a prompt's first full KV
page (same system prompt -> same class); prompts too short to fill a
page fall into ``"short"``, and a first-ever-seen prefix is decided by
the aggregate ``"novel"`` class — which is how churn traces (every
prompt unique) learn to bypass retention globally instead of paying the
optimistic default once per prefix.

Everything here is **placement-only**: retention, reclaim order, victim
choice and re-planning move pages and slots, never tokens.  Outputs are
bit-identical to the static engine by construction — recompute-restore
is bit-exact regardless of victim (the ``(seed, token index)`` sampler
keys), and a warm revive attaches pages holding exactly the KV a fresh
prefill would recompute.  The identity matrix in ``tests/test_serve.py``
and the chaos/recovery legs pin this.
"""
from __future__ import annotations

import dataclasses
import zlib

from repro.core.sweep import SERVE_COMBOS, serve_policy_argmin

# Bounded last-touch ring: page-level recency is a hint for reclaim
# ordering, not ground truth, so it is capped (and deliberately NOT
# snapshotted — the warm cache is volatile across crash-restore).
LAST_TOUCH_RING = 256

# Aggregate classes: prompts with no full page to key on, and the
# first-arrival pool whose outcomes teach the default retention stance.
CLASS_SHORT = "short"
CLASS_NOVEL = "novel"


@dataclasses.dataclass(frozen=True)
class ServeCombo:
    """One row of the serve-policy lattice (``core.sweep.SERVE_COMBOS``)."""

    warm_frac: float      # fraction of cfg.warm_pages this class may hold
    evict_rank: str       # "lru" | "reuse" — warm reclaim ordering
    bypass: bool          # never retain this class's pages

    @classmethod
    def from_row(cls, row) -> "ServeCombo":
        return cls(warm_frac=row[0], evict_rank=row[1], bypass=row[2])

    def to_json(self) -> list:
        return [self.warm_frac, self.evict_rank, self.bypass]


DEFAULT_COMBO = ServeCombo.from_row(SERVE_COMBOS[0])


def _class_stats() -> dict:
    return {
        "arrivals": 0,          # fresh admissions of this class
        "prompt_tokens": 0,     # sum of prompt lengths
        "shared_tokens": 0,     # sum of shareable (full-page prefix) tokens
        "retained": 0,          # pages parked warm on this class's behalf
        "hits": 0,              # warm pages later revived by a sharer
        "reclaimed_unhit": 0,   # warm pages reclaimed without ever reviving
        "last_wave": -1,        # wave of the most recent arrival
        "interval_ema": 0.0,    # EMA of waves between re-arrivals
        "reuse_obs": 0,         # re-arrival intervals observed
    }


class AdaptivePolicy:
    """Counter-driven policy controller for one ``ServeEngine``.

    The engine owns every mechanism (allocator warm tier, trie, victim
    preemption); this class owns only the decisions, so the static
    engine path never constructs one and pays nothing.  All state is
    host-side plain Python; :meth:`snapshot_state` emits the JSON-safe
    subset that must survive crash-restore (per-class counters and
    chosen combos — NOT the page-level recency ring, since restored
    engines start with a cold pool).
    """

    def __init__(self, warm_pages: int, replan_every: int, page_size: int,
                 spec_k: int = 0, pinned: ServeCombo | None = None):
        assert warm_pages >= 0 and replan_every >= 1 and page_size >= 1
        self.warm_pages = warm_pages
        self.replan_every = replan_every
        self.page_size = page_size
        self.spec_k = spec_k
        self.pinned = pinned          # static-baseline benches: never replan
        self.wave = 0
        self.replans = 0
        self._classes: dict[str, dict] = {}
        self._combos: dict[str, ServeCombo] = {}
        # Page-level, volatile (not snapshotted):
        self._last_touch: dict[int, int] = {}   # page -> wave, bounded ring
        self._page_class: dict[int, str] = {}   # warm page -> deciding class
        self._page_hit: set[int] = set()        # warm pages revived >= once

    # -- class taxonomy -----------------------------------------------------

    def class_key(self, chunks) -> str:
        """Workload-class key for a prompt: CRC32 of its first full KV
        page (deterministic across processes, unlike ``hash``), or
        ``"short"`` when no full page exists to key on."""
        if not chunks:
            return CLASS_SHORT
        first = chunks[0]
        data = b"".join(int(t).to_bytes(8, "little", signed=True)
                        for t in first)
        return f"c{zlib.crc32(data):08x}"

    def _cls(self, key: str) -> dict:
        st = self._classes.get(key)
        if st is None:
            st = self._classes[key] = _class_stats()
        return st

    def combo_for(self, key: str) -> ServeCombo:
        """The active combo for a retention decision on class ``key``:
        a pinned combo if set, the class's replanned combo if it has
        one, else the aggregate ``"novel"`` combo (first-seen prefixes
        inherit what churn history taught), else the optimistic
        default."""
        if self.pinned is not None:
            return self.pinned
        return self._combos.get(
            key, self._combos.get(CLASS_NOVEL, DEFAULT_COMBO)
        )

    # -- counter feed (called by the engine) --------------------------------

    def begin_wave(self) -> None:
        self.wave += 1

    def note_arrival(self, key: str, prompt_len: int,
                     shared_tokens: int) -> str:
        """Account one FRESH admission; returns the *deciding* class —
        the key itself once the class has history, else ``"novel"`` —
        which is the class retention outcomes accrue to."""
        st = self._cls(key)
        deciding = key if st["arrivals"] > 0 else CLASS_NOVEL
        if st["arrivals"] > 0 and st["last_wave"] >= 0:
            interval = max(self.wave - st["last_wave"], 1)
            st["interval_ema"] = (
                interval if st["reuse_obs"] == 0
                else 0.5 * st["interval_ema"] + 0.5 * interval
            )
            st["reuse_obs"] += 1
        st["arrivals"] += 1
        st["prompt_tokens"] += int(prompt_len)
        st["shared_tokens"] += int(shared_tokens)
        st["last_wave"] = self.wave
        if deciding == CLASS_NOVEL:
            nv = self._cls(CLASS_NOVEL)
            nv["arrivals"] += 1
            nv["prompt_tokens"] += int(prompt_len)
            nv["shared_tokens"] += int(shared_tokens)
            nv["last_wave"] = self.wave
        return deciding

    def touch(self, pages) -> None:
        """Refresh the last-touch ring for pages referenced this wave."""
        for p in pages:
            self._last_touch.pop(p, None)
            self._last_touch[p] = self.wave
        while len(self._last_touch) > LAST_TOUCH_RING:
            self._last_touch.pop(next(iter(self._last_touch)))

    def note_retained(self, page: int, deciding_class: str) -> None:
        self._cls(deciding_class)["retained"] += 1
        self._page_class[page] = deciding_class
        self._page_hit.discard(page)
        self.touch([page])

    def note_revived(self, pages) -> None:
        """Warm pages re-attached by a new sharer: the hit that justifies
        retention.  Credits each page's deciding class once per page."""
        for p in pages:
            cls = self._page_class.get(p)
            if cls is not None and p not in self._page_hit:
                self._cls(cls)["hits"] += 1
                self._page_hit.add(p)
            self._page_class.pop(p, None)
        self.touch(pages)

    def note_reclaimed(self, pages) -> None:
        """Warm pages returned to the free list: any page never revived
        since retention is churn — evidence against retaining its
        class."""
        for p in pages:
            cls = self._page_class.pop(p, None)
            if cls is not None and p not in self._page_hit:
                self._cls(cls)["reclaimed_unhit"] += 1
            self._page_hit.discard(p)
            self._last_touch.pop(p, None)

    # -- decisions (consulted by the engine) --------------------------------

    def retain_quota(self, key: str) -> int:
        """Max warm pages the deciding class of ``key`` may hold right
        now (0 = don't retain).  The allocator's global budget still
        bounds the total; this bounds one class's share of it."""
        combo = self.combo_for(key)
        if combo.bypass:
            return 0
        return int(combo.warm_frac * self.warm_pages)

    def class_warm_count(self, deciding_class: str) -> int:
        return sum(1 for c in self._page_class.values()
                   if c == deciding_class)

    def reclaim_order(self, warm_ids) -> list[int]:
        """Warm pages ordered most-reclaimable first.  LRU-ranked pages
        score by age alone; reuse-ranked pages (their class combo says
        "reuse") normalize age by the class's observed re-arrival
        interval, so a page overdue relative to its own cadence reclaims
        before a merely old page whose class re-arrives slowly.  Fully
        deterministic: ties break on page id."""
        def score(p: int) -> float:
            age = float(self.wave - self._last_touch.get(p, -1))
            cls = self._page_class.get(p)
            combo = self.combo_for(cls) if cls is not None else DEFAULT_COMBO
            if combo.evict_rank == "reuse" and cls in self._classes:
                ema = self._classes[cls]["interval_ema"]
                if ema > 0:
                    age = age / ema
            return age
        return sorted(warm_ids, key=lambda p: (-score(p), p))

    def victim_cost(self, record, allocator, page_table) -> int:
        """Estimated tokens to recompute if ``record`` is preempted:
        the full recompute-prefill length (prompt + emitted so far)
        minus one page's worth per page that other slots still share —
        those pages stay resident, so their KV isn't really lost."""
        replay = len(record.prompt) + len(record.generated)
        shared = sum(1 for p in page_table if allocator.ref_count(p) > 1)
        return replay - self.page_size * shared

    # -- re-planning --------------------------------------------------------

    def should_replan(self) -> bool:
        return (self.pinned is None
                and self.wave > 0
                and self.wave % self.replan_every == 0)

    def replan(self, engine_stats: dict) -> dict[str, list]:
        """Feed each class's counters through the exact lattice argmin
        (``core.sweep.serve_policy_argmin``) and install the winning
        combos.  Deterministic: classes visit in sorted key order.
        Returns ``{class: combo_json}`` for ``policy_report()``."""
        spec_rounds = engine_stats.get("spec_rounds", 0)
        spec_acc = (engine_stats.get("spec_accepted", 0) / spec_rounds
                    if spec_rounds else 0.0)
        for key in sorted(self._classes):
            st = self._classes[key]
            if st["arrivals"] == 0:
                continue
            # A class with no retention outcomes and no observed reuse of
            # its own has nothing to argmin over — installing a combo for
            # it would just echo the lattice tie-break AND shadow the
            # aggregate "novel" combo that holds the churn evidence its
            # first-arrival outcomes accrued to.  Keep it inheriting.
            if (key != CLASS_NOVEL and st["retained"] == 0
                    and st["hits"] == 0 and st["reuse_obs"] == 0):
                continue
            row, _cost = serve_policy_argmin({
                "prompt_mean": st["prompt_tokens"] / st["arrivals"],
                "shared_tokens": st["shared_tokens"] / st["arrivals"],
                "hit_rate": (st["hits"] / st["retained"]
                             if st["retained"] else 0.0),
                "churn": (st["reclaimed_unhit"] / st["retained"]
                          if st["retained"] else 0.0),
                "reuse_signal": 1.0 if st["reuse_obs"] > 0 else 0.0,
                "spec_acceptance": spec_acc,
                "spec_k": self.spec_k,
                "warm_budget": self.warm_pages,
                "page_size": self.page_size,
            })
            self._combos[key] = ServeCombo.from_row(row)
        self.replans += 1
        return {k: c.to_json() for k, c in sorted(self._combos.items())}

    # -- crash safety (serve.snapshot) --------------------------------------

    def snapshot_state(self) -> dict:
        """JSON-safe policy state for the checksummed snapshot payload.
        Page-level recency/attribution is deliberately absent: a
        restored engine's pool starts cold (no warm pages survive a
        crash), so only the learned per-class knowledge carries over."""
        return {
            "wave": self.wave,
            "replans": self.replans,
            "classes": {k: dict(v) for k, v in self._classes.items()},
            "combos": {k: c.to_json() for k, c in self._combos.items()},
        }

    def restore_state(self, payload: dict) -> None:
        self.wave = int(payload.get("wave", 0))
        self.replans = int(payload.get("replans", 0))
        self._classes = {
            k: {**_class_stats(), **v}
            for k, v in payload.get("classes", {}).items()
        }
        self._combos = {
            k: ServeCombo(warm_frac=float(v[0]), evict_rank=str(v[1]),
                          bypass=bool(v[2]))
            for k, v in payload.get("combos", {}).items()
        }
        self._last_touch.clear()
        self._page_class.clear()
        self._page_hit.clear()

    def report(self) -> dict:
        """Summary block for ``ServeEngine.policy_report()``."""
        return {
            "wave": self.wave,
            "replans": self.replans,
            "classes": len(self._classes),
            "combos": {k: c.to_json() for k, c in sorted(self._combos.items())},
            "warm_budget": self.warm_pages,
        }
