"""Host-side prefix index: a radix trie over full KV pages (DESIGN.md §5.4).

The paged pool (DESIGN.md §5.2) makes prompt-prefix sharing a pure
page-table operation: if the first ``m`` full pages of a new request's
prompt are already resident — written by an earlier request with the same
token prefix — the new slot's page table can simply alias those physical
pages and prefill only the unshared suffix.  This trie is the host-side
directory that answers "which resident pages hold this token prefix?".

Structure
---------
Each node represents ONE full page of tokens: its edge key is the page's
token content (a ``page_size``-tuple — the "token hash" is Python's tuple
hash in the children dict) and it records the physical page id holding
that content.  A root→node path therefore spells out a full-page token
prefix and the physical page chain backing it.

Invariants (unit-tested in ``tests/test_prefix.py``):

* **Full pages only.**  A partial page is never registered or matched: the
  trailing ``len(tokens) % page_size`` tokens of a prompt live in a
  private page (and the serve engine additionally caps sharing so the
  prompt's last token is always re-prefilled — the logits that seed
  decoding are computed fresh, never assumed resident).
* **Registered pages are immutable.**  Only pages fully covered by a
  request's *prompt* are registered; the cursor only ever advances past
  them, and the engine's scatter can't write below a slot's cursor — so a
  shared page is never mutated.  Divergence is copy-on-write by
  *allocation*: the first divergent page is always a freshly allocated
  private page, never a write into a shared one.
* **The trie holds no references.**  Residency is owned by the engine's
  refcounted ``PageAllocator`` (one reference per slot whose table maps
  the page).  When a page's refcount hits zero the allocator frees it and
  the engine calls :meth:`evict` — unless the adaptive policy *retains*
  it in the allocator's bounded warm tier (DESIGN.md §5.7), in which
  case the node stays attachable: a later request with the same prefix
  revives the page to refcount 1 without re-prefilling.  Because every
  sharer references the whole chain, a parent page can never free before
  its children — nodes evict leaf-upward (asserted) — and a warm node's
  children are never held (a held child implies a held parent).
"""
from __future__ import annotations


class _Node:
    """One full page of tokens: ``key`` is its content (page_size-tuple of
    ints) under ``parent``; ``page`` the physical page id backing it."""

    __slots__ = ("parent", "key", "page", "children", "depth")

    def __init__(self, parent, key, page: int):
        self.parent = parent
        self.key = key
        self.page = page
        self.children: dict[tuple, _Node] = {}
        self.depth = 0 if parent is None else parent.depth + 1


class PrefixIndex:
    """Radix trie mapping full-page token prefixes to resident page ids."""

    def __init__(self, page_size: int):
        assert page_size > 0, f"page_size={page_size}"
        self.page_size = page_size
        self._root = _Node(None, None, -1)
        self._by_page: dict[int, _Node] = {}

    def __len__(self) -> int:
        """Number of resident (registered, not yet evicted) trie nodes."""
        return len(self._by_page)

    def chunks(self, tokens) -> list[tuple[int, ...]]:
        """Full-page token chunks of ``tokens``; the partial tail (if any)
        is dropped — partial pages never participate in sharing.  The
        engine computes this once per admission and passes it to both
        :meth:`lookup` and :meth:`register` (the per-token tuple build is
        the only O(prompt) work on the admission host path)."""
        psz = self.page_size
        return [
            tuple(int(t) for t in tokens[i * psz:(i + 1) * psz])
            for i in range(len(tokens) // psz)
        ]

    def lookup(self, tokens, chunks=None) -> list[int]:
        """Longest-match: physical page ids of the longest resident chain
        of full pages prefixing ``tokens`` (possibly empty)."""
        node, pages = self._root, []
        for chunk in self.chunks(tokens) if chunks is None else chunks:
            node = node.children.get(chunk)
            if node is None:
                break
            pages.append(node.page)
        return pages

    def register(self, tokens, pages, chunks=None) -> list[int]:
        """Index the full-page prefix of ``tokens``, backed by physical
        ``pages`` (one id per full page — the admitting slot's page table).

        Chunks already resident keep their existing node (the caller
        shares those pages instead of duplicating them); only chunks with
        no resident node create one, and those always map pages the
        caller privately owns.  Returns the newly registered page ids.
        """
        if chunks is None:
            chunks = self.chunks(tokens)
        assert len(pages) >= len(chunks), (
            f"register: {len(chunks)} full pages of tokens but only "
            f"{len(pages)} page ids"
        )
        node, registered = self._root, []
        for chunk, pid in zip(chunks, pages):
            nxt = node.children.get(chunk)
            if nxt is None:
                pid = int(pid)
                assert pid >= 0, f"register: unmapped page id {pid}"
                assert pid not in self._by_page, (
                    f"page {pid} already registered under another prefix"
                )
                nxt = _Node(node, chunk, pid)
                node.children[chunk] = nxt
                self._by_page[pid] = nxt
                registered.append(pid)
            node = nxt
        return registered

    def evict(self, page_ids) -> int:
        """Drop the nodes backing ``page_ids`` (pages whose refcount just
        hit zero).  Unregistered ids are ignored (tail/decode pages are
        never in the trie).  Children free no later than parents — every
        sharer holds the whole chain — so eviction proceeds leaf-upward;
        a node evicted while a child is still resident is a refcount bug
        and asserts.  Returns the number of nodes evicted."""
        nodes = [
            self._by_page.pop(pid)
            for pid in page_ids if pid in self._by_page
        ]
        for node in sorted(nodes, key=lambda n: -n.depth):
            assert not node.children, (
                f"evicting trie node for page {node.page} while "
                f"{len(node.children)} child page(s) are still resident "
                "(parent freed before child — refcount invariant broken)"
            )
            del node.parent.children[node.key]
        return len(nodes)

    def depth_of(self, page_id: int) -> int:
        """1-based chain depth of a registered page (root child = 1), or
        0 if the page is not registered.  The warm-retention policy
        (DESIGN.md §5.7) retains shallowest-first so the warm set stays a
        depth-prefix of its chain — a warm page's ancestors are either
        held (some sharer still resident) or warm, never reclaimed out
        from under it."""
        node = self._by_page.get(page_id)
        return node.depth if node is not None else 0

    def parent_page(self, page_id: int) -> int | None:
        """Physical page id of a registered page's parent node, or None
        for a depth-1 page (root child) / an unregistered page."""
        node = self._by_page.get(page_id)
        if node is None or node.parent is None or node.parent.parent is None:
            return None
        return node.parent.page

    def subtree_pages(self, page_id: int) -> list[int]:
        """All registered page ids in the subtree rooted at ``page_id``
        (inclusive), parents before children — or [] if unregistered.
        Reclaiming/quarantining a warm page must close over its warm
        descendants (evicting a node whose children are still resident
        asserts); callers evict in REVERSE of this order (leaf-upward)."""
        node = self._by_page.get(page_id)
        if node is None:
            return []
        out, stack = [], [node]
        while stack:
            n = stack.pop()
            out.append(n.page)
            stack.extend(n.children.values())
        return out

    def resident_tokens(self) -> int:
        """Total prompt tokens currently indexed (nodes x page_size)."""
        return len(self._by_page) * self.page_size

    def resident_pages(self) -> set[int]:
        """Physical page ids currently indexed.  The trie holds no
        references, so every one of these MUST be held OR warm in the
        allocator — the engine's ``check_invariants`` asserts exactly
        that (a trie page outliving its last reference without warm
        retention would alias freed storage)."""
        return set(self._by_page)
