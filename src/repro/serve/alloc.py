"""Refcounted host-side page allocator for the paged KV pool.

Extracted from ``serve.engine`` so the chaos/fault-injection wrapper
(`serve.chaos.ChaosAllocator`) can subclass it without a circular import;
``serve.engine`` re-exports ``PageAllocator`` for compatibility (the
property suite and older call sites import it from there).
"""
from __future__ import annotations


class PageAllocator:
    """Refcounted host-side LIFO free-list over a fixed page pool
    (DESIGN.md §5.2, refcounts §5.4, quarantine §5.6).

    Every held page carries a reference count: ``alloc`` hands out pages
    at refcount 1, ``share`` adds a reference to already-held pages (a new
    slot's page table aliasing a resident prefix page), and ``release``
    drops one — a page returns to the free list only at refcount zero, so
    a shared prefix page survives its original owner finishing.

    ``quarantine`` takes a page out of circulation permanently (KV
    integrity, DESIGN.md §5.6): a free page leaves the free list at once,
    a held page is marked *doomed* and diverts to the quarantine set —
    never back to the free list — when its last reference drops.  ``alloc``
    can therefore never hand out a quarantined page.

    Invariants (property-tested in ``tests/test_alloc_property.py``,
    including a hypothesis state machine over alloc/share/release
    interleavings):

    * a page is never handed out twice without an intervening final
      ``release``,
    * ``alloc`` is atomic and never over-commits — when ``n`` exceeds the
      free count it returns None having popped nothing (admission
      gating; the guard predates refcounting but was untested, and is
      now pinned by a regression test),
    * no page is freed while references remain, and references are
      conserved across share/release interleavings,
    * held + free + quarantined is a partition of the pool at all times
      (no leaks; ``quarantined`` is empty until integrity quarantines).
    """

    def __init__(self, n_pages: int):
        assert n_pages >= 0
        self.n_pages = n_pages
        self._free = list(range(n_pages))
        self._refs: dict[int, int] = {}
        self._quarantined: set[int] = set()   # out of circulation, refs == 0
        self._doomed: set[int] = set()        # held; quarantine at last release

    @property
    def free_pages(self) -> list[int]:
        return list(self._free)

    @property
    def held_pages(self) -> set[int]:
        return set(self._refs)

    @property
    def quarantined_pages(self) -> set[int]:
        """Pages permanently out of circulation (refcount 0)."""
        return set(self._quarantined)

    @property
    def doomed_pages(self) -> set[int]:
        """Held pages marked for quarantine at their last release."""
        return set(self._doomed)

    def free_count(self) -> int:
        return len(self._free)

    def usable_pages(self) -> int:
        """Pool capacity excluding quarantined and doomed pages — the
        honest upper bound an admission gate may promise against."""
        return self.n_pages - len(self._quarantined) - len(self._doomed)

    def quarantine(self, page: int) -> bool:
        """Take ``page`` out of circulation (corrupt KV, DESIGN.md §5.6).

        A free page moves to the quarantine set immediately; a held page
        is marked doomed and diverts there — never back to the free
        list — when its final reference is released.  Returns False if
        the page was already quarantined/doomed (idempotent)."""
        if not (0 <= page < self.n_pages):
            raise ValueError(f"quarantine({page}) outside pool")
        if page in self._quarantined or page in self._doomed:
            return False
        if page in self._refs:
            self._doomed.add(page)
        else:
            self._free.remove(page)
            self._quarantined.add(page)
        return True

    def ref_count(self, page: int) -> int:
        """Current reference count of ``page`` (0 if free)."""
        return self._refs.get(page, 0)

    def total_refs(self) -> int:
        return sum(self._refs.values())

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` pages (LIFO) at refcount 1, or None — having popped
        NOTHING — if the pool can't cover all ``n`` (atomic failure)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        assert not any(i in self._refs for i in ids), "double-allocated page"
        for i in ids:
            self._refs[i] = 1
        return ids

    def share(self, ids) -> bool:
        """Add one reference to each held page in ``ids`` (a new sharer's
        page table now aliases them).  Sharing a free page is a bug.
        Returns True; the chaos subclass returns False on an injected
        refusal having touched no refcount (atomic, like ``alloc``)."""
        ids = list(ids)
        assert len(ids) == len(set(ids)), (
            f"duplicate page ids in share(): {ids}"
        )
        bad = [i for i in ids if i not in self._refs]
        assert not bad, f"sharing pages not held: {bad}"
        for i in ids:
            self._refs[i] += 1
        return True

    def release(self, ids) -> list[int]:
        """Drop one reference per page; pages reaching refcount zero
        return to the free list — or to quarantine if doomed.  Returns
        the ids no longer held (the engine evicts their trie nodes and
        drops their integrity stamps), whether freed or quarantined."""
        ids = list(ids)
        assert len(ids) == len(set(ids)), (
            f"duplicate page ids in free(): {ids}"
        )
        bad = [i for i in ids if i not in self._refs]
        assert not bad, f"freeing pages not held: {bad}"
        freed = []
        for i in ids:
            self._refs[i] -= 1
            if self._refs[i] == 0:
                del self._refs[i]
                if i in self._doomed:
                    self._doomed.discard(i)
                    self._quarantined.add(i)
                else:
                    self._free.append(i)
                freed.append(i)
        return freed

    # Unshared call sites (and the pre-refcount test suite) say "free":
    # with every refcount at 1 release IS free.
    free = release
