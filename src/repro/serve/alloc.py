"""Refcounted host-side page allocator for the paged KV pool.

Extracted from ``serve.engine`` so the chaos/fault-injection wrapper
(`serve.chaos.ChaosAllocator`) can subclass it without a circular import;
``serve.engine`` re-exports ``PageAllocator`` for compatibility (the
property suite and older call sites import it from there).
"""
from __future__ import annotations


class PageAllocator:
    """Refcounted host-side LIFO free-list over a fixed page pool
    (DESIGN.md §5.2, refcounts §5.4, quarantine §5.6).

    Every held page carries a reference count: ``alloc`` hands out pages
    at refcount 1, ``share`` adds a reference to already-held pages (a new
    slot's page table aliasing a resident prefix page), and ``release``
    drops one — a page returns to the free list only at refcount zero, so
    a shared prefix page survives its original owner finishing.

    ``quarantine`` takes a page out of circulation permanently (KV
    integrity, DESIGN.md §5.6): a free page leaves the free list at once,
    a held page is marked *doomed* and diverts to the quarantine set —
    never back to the free list — when its last reference drops.  ``alloc``
    can therefore never hand out a quarantined page.

    The *warm* tier (adaptive policy, DESIGN.md §5.7) is a bounded
    holding pen between free and held: ``retain`` parks a just-freed page
    (its device KV intact) so a future sharer can ``revive`` it straight
    to refcount 1 without re-prefilling, and ``reclaim`` returns warm
    pages to the free list when capacity is needed.  The allocator owns
    only the *mechanism* — which pages to retain, revive, or reclaim (and
    in what order) is the adaptive controller's policy.  ``alloc`` never
    touches warm pages: the engine reclaims explicitly first, keeping
    allocation deterministic and the chaos ``alloc`` override oblivious.

    Invariants (property-tested in ``tests/test_alloc_property.py``,
    including a hypothesis state machine over alloc/share/release
    interleavings):

    * a page is never handed out twice without an intervening final
      ``release``,
    * ``alloc`` is atomic and never over-commits — when ``n`` exceeds the
      free count it returns None having popped nothing (admission
      gating; the guard predates refcounting but was untested, and is
      now pinned by a regression test),
    * no page is freed while references remain, and references are
      conserved across share/release interleavings,
    * held + free + warm + quarantined is a partition of the pool at all
      times (no leaks; ``warm`` and ``quarantined`` are empty until
      retention/integrity use them),
    * the warm set never exceeds ``warm_budget`` and never intersects
      the free list, the refcount map, or the quarantine set.
    """

    def __init__(self, n_pages: int, warm_budget: int = 0):
        assert n_pages >= 0
        assert warm_budget >= 0
        self.n_pages = n_pages
        self.warm_budget = warm_budget
        self._free = list(range(n_pages))
        self._refs: dict[int, int] = {}
        self._warm: set[int] = set()          # retained; KV intact, refs == 0
        self._quarantined: set[int] = set()   # out of circulation, refs == 0
        self._doomed: set[int] = set()        # held; quarantine at last release

    @property
    def free_pages(self) -> list[int]:
        return list(self._free)

    @property
    def held_pages(self) -> set[int]:
        return set(self._refs)

    @property
    def warm_pages(self) -> set[int]:
        """Pages retained past refcount zero (device KV intact)."""
        return set(self._warm)

    @property
    def quarantined_pages(self) -> set[int]:
        """Pages permanently out of circulation (refcount 0)."""
        return set(self._quarantined)

    @property
    def doomed_pages(self) -> set[int]:
        """Held pages marked for quarantine at their last release."""
        return set(self._doomed)

    def free_count(self) -> int:
        return len(self._free)

    def warm_count(self) -> int:
        return len(self._warm)

    def is_warm(self, page: int) -> bool:
        return page in self._warm

    def is_free(self, page: int) -> bool:
        return page in self._free

    def retain(self, page: int) -> bool:
        """Park a FREE page in the warm tier instead of leaving it on the
        free list (its device KV stays valid until reclaimed).  Returns
        False — having changed nothing — if the warm budget is full or
        ``page`` is not currently free (atomic, like ``alloc``)."""
        if not (0 <= page < self.n_pages):
            raise ValueError(f"retain({page}) outside pool")
        if len(self._warm) >= self.warm_budget or page not in self._free:
            return False
        self._free.remove(page)
        self._warm.add(page)
        return True

    def reclaim(self, ids) -> list[int]:
        """Return warm pages to the free list (their KV is forfeit; the
        engine drops trie nodes and integrity stamps first).  Every id
        must be warm — reclaiming a free/held page is a policy bug."""
        ids = list(ids)
        assert len(ids) == len(set(ids)), f"duplicate ids in reclaim: {ids}"
        bad = [i for i in ids if i not in self._warm]
        assert not bad, f"reclaiming pages not warm: {bad}"
        for i in ids:
            self._warm.discard(i)
            self._free.append(i)
        return ids

    def revive(self, ids) -> bool:
        """Promote warm pages straight to held at refcount 1 (a new
        sharer attaches to the retained KV without re-prefilling).
        Atomic: every id must be warm or nothing moves.  Returns True —
        deliberately NOT overridden by the chaos allocator: a revive only
        happens for pages the engine just confirmed warm, so a seeded
        refusal here would model an impossible failure."""
        ids = list(ids)
        assert len(ids) == len(set(ids)), f"duplicate ids in revive: {ids}"
        bad = [i for i in ids if i not in self._warm]
        assert not bad, f"reviving pages not warm: {bad}"
        for i in ids:
            self._warm.discard(i)
            self._refs[i] = 1
        return True

    def usable_pages(self) -> int:
        """Pool capacity excluding quarantined and doomed pages — the
        honest upper bound an admission gate may promise against."""
        return self.n_pages - len(self._quarantined) - len(self._doomed)

    def quarantine(self, page: int) -> bool:
        """Take ``page`` out of circulation (corrupt KV, DESIGN.md §5.6).

        A free (or warm) page moves to the quarantine set immediately; a
        held page is marked doomed and diverts there — never back to the
        free list — when its final reference is released.  Returns False
        if the page was already quarantined/doomed (idempotent)."""
        if not (0 <= page < self.n_pages):
            raise ValueError(f"quarantine({page}) outside pool")
        if page in self._quarantined or page in self._doomed:
            return False
        if page in self._refs:
            self._doomed.add(page)
        elif page in self._warm:
            self._warm.discard(page)
            self._quarantined.add(page)
        else:
            self._free.remove(page)
            self._quarantined.add(page)
        return True

    def ref_count(self, page: int) -> int:
        """Current reference count of ``page`` (0 if free)."""
        return self._refs.get(page, 0)

    def total_refs(self) -> int:
        return sum(self._refs.values())

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` pages (LIFO) at refcount 1, or None — having popped
        NOTHING — if the pool can't cover all ``n`` (atomic failure)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        assert not any(i in self._refs for i in ids), "double-allocated page"
        for i in ids:
            self._refs[i] = 1
        return ids

    def share(self, ids) -> bool:
        """Add one reference to each held page in ``ids`` (a new sharer's
        page table now aliases them).  Sharing a free page is a bug.
        Returns True; the chaos subclass returns False on an injected
        refusal having touched no refcount (atomic, like ``alloc``)."""
        ids = list(ids)
        assert len(ids) == len(set(ids)), (
            f"duplicate page ids in share(): {ids}"
        )
        bad = [i for i in ids if i not in self._refs]
        assert not bad, f"sharing pages not held: {bad}"
        for i in ids:
            self._refs[i] += 1
        return True

    def release(self, ids) -> list[int]:
        """Drop one reference per page; pages reaching refcount zero
        return to the free list — or to quarantine if doomed.  Returns
        the ids no longer held (the engine evicts their trie nodes and
        drops their integrity stamps), whether freed or quarantined."""
        ids = list(ids)
        assert len(ids) == len(set(ids)), (
            f"duplicate page ids in free(): {ids}"
        )
        bad = [i for i in ids if i not in self._refs]
        assert not bad, f"freeing pages not held: {bad}"
        freed = []
        for i in ids:
            self._refs[i] -= 1
            if self._refs[i] == 0:
                del self._refs[i]
                if i in self._doomed:
                    self._doomed.discard(i)
                    self._quarantined.add(i)
                else:
                    self._free.append(i)
                freed.append(i)
        return freed

    # Unshared call sites (and the pre-refcount test suite) say "free":
    # with every refcount at 1 release IS free.
    free = release
