"""Batched serving engine: continuous prefill + decode over a KV cache.

The memory-policy engine drives two serving decisions (DESIGN.md §5):

* KV residency per layer (`engine.kv_policy`): decode KV is a zero-reuse
  stream (the paper's throughput-sensitive class) — STREAM via the
  split-KV decode kernel; fixed-source caches (whisper enc K/V, vision
  patch K/V) are RESIDENT (reused every step, fetched once).
* Split-count planning for flash-decoding (`kernels.decode_attention.ops`).

``ServeEngine`` keeps request slots (static batch), admits new requests by
prefilling into free slots, and steps all live slots together — simple
continuous batching.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import hw
from repro.configs.base import ModelConfig
from repro.core import CachePolicyEngine, make_engine
from repro.core.characterize import attention_op
from repro.models import build_model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits[:, -1], axis=-1)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_len: int, extras: dict[str, Any] | None = None,
                 policy_engine: CachePolicyEngine | None = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.extras = extras or {}
        self.policy = policy_engine or make_engine()
        self.kv_residency = self.policy.kv_policy(self._kv_bytes_per_layer())
        # Decode-attention plan, memoized in the policy engine's PlanCache:
        # one lattice search + allocation per serve process, a cache hit for
        # every subsequent engine (re-plans are the serve-time hot path).
        self.decode_plan = None
        if cfg.n_heads and cfg.head_dim_:
            self.decode_plan = self.policy.plan_op(attention_op(
                batch_slots, cfg.n_heads, max(1, cfg.n_kv_heads),
                1, max_len, cfg.head_dim_, causal=False, name="serve_decode",
            ))
        self.cache = self.model.init_cache(
            params, batch=batch_slots, max_len=max_len, **self.extras
        )
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(self.model.prefill)
        self.live: dict[int, Request] = {}

    def _kv_bytes_per_layer(self) -> int:
        kv_heads = max(1, self.cfg.n_kv_heads)
        return (2 * self.slots * self.max_len * kv_heads
                * self.cfg.head_dim_ * hw.dtype_bytes(self.cfg.dtype))

    def policy_report(self) -> dict:
        """Serving-side policy decisions (DESIGN.md §5) + planner counters."""
        report = {
            "kv_bytes_per_layer": self._kv_bytes_per_layer(),
            "kv_residency": self.kv_residency.value,
            "plan_cache": self.policy.plan_stats(),
        }
        if self.decode_plan is not None:
            report["decode_attention"] = {
                "assignment": {
                    k: v.value for k, v in self.decode_plan.assignment.items()
                },
                "vmem_bytes": self.decode_plan.vmem_bytes,
                "grid_order": list(self.decode_plan.grid_order),
            }
        return report

    # NOTE on the single-cursor cache: the uniform-cursor layout keeps the
    # dry-run/step functions static-shaped; slots admitted together share a
    # prompt window (padded).  Continuous batching with ragged lengths uses
    # the `lengths`-aware decode kernel at the attention level.
    def admit(self, requests: list[Request]) -> None:
        assert len(requests) <= self.slots
        pad_to = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.slots, pad_to), np.int32)
        for i, r in enumerate(requests):
            r.slot = i
            toks[i, pad_to - len(r.prompt):] = r.prompt  # left-pad
            self.live[i] = r
        logits, self.cache = self._prefill(
            self.params, self.cache, jnp.asarray(toks)
        )
        nxt = np.asarray(greedy_sample(logits))
        for r in requests:
            r.generated.append(int(nxt[r.slot]))

    def step(self) -> None:
        toks = np.zeros((self.slots, 1), np.int32)
        for slot, r in self.live.items():
            toks[slot, 0] = r.generated[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks)
        )
        nxt = np.asarray(greedy_sample(logits))
        finished = []
        for slot, r in self.live.items():
            r.generated.append(int(nxt[slot]))
            if len(r.generated) >= r.max_new_tokens:
                r.done = True
                finished.append(slot)
        for slot in finished:
            del self.live[slot]

    def run(self, requests: list[Request]) -> list[Request]:
        self.admit(requests)
        while self.live:
            self.step()
        return requests
