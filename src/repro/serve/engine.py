"""Device-resident continuous-batching serve engine.

The memory-policy engine drives two serving decisions (DESIGN.md §5):

* KV residency per layer (`engine.kv_policy`): decode KV is a zero-reuse
  stream (the paper's throughput-sensitive class) — STREAM via the
  split-KV decode kernel; fixed-source caches (whisper enc K/V, vision
  patch K/V) are RESIDENT (reused every step, fetched once).
* Split-count planning for flash-decoding (`kernels.decode_attention.ops`),
  memoized in the PlanCache and re-consulted at every admission wave.

The serving loop itself is built to run at hardware speed (the inference
loop, not the policy search, is the artifact that must be fast):

* **Chunked on-device decode** — one `lax.scan` dispatch decodes
  ``chunk_size`` tokens for every slot with on-device sampling and
  per-slot done flags; the host syncs once per *chunk* (to read the
  emitted tokens), not once per token.
* **Ragged slots** — the cache carries a per-slot ``lengths`` cursor
  vector, so slots free and re-admit independently: finished slots park
  (``seg_lens == 0`` leaves their state untouched) while live slots keep
  decoding, and freed slots take new prompts mid-stream via a ragged
  right-padded prefill (`models.common.append_kv` drops padding on the
  scatter, so mixed-length prompts never cross-contaminate).
* **Donated buffers** — the cache (and the per-slot token/budget vectors)
  are donated to each dispatch, so KV updates are in-place on device.
* **Paged KV pool** (``cfg.cache_layout == "paged"``, DESIGN.md §5.2) —
  K/V capacity is pooled into fixed-size pages shared across slots; a
  host-side free-list (`PageAllocator`) assigns each admitted request
  exactly the pages its worst case needs and admission gates on free
  pages, so a pool smaller than ``slots x max_len`` serves mixed
  long/short traffic while staying bit-identical to the contiguous ring.
* **Prefix sharing** (``cfg.prefix_sharing``, DESIGN.md §5.4) — a
  host-side radix trie over full prompt pages (`serve.prefix`) lets
  admission attach a new request to already-resident prefix pages: the
  slot's page table aliases the shared pages (refcounted in the
  `PageAllocator`; a page frees only at refcount zero) and prefill runs
  only over the unshared suffix at a page-aligned nonzero cursor.
  Divergence is copy-on-write by allocation — the first divergent page is
  always a private page, shared pages are never written.  Requires the
  paged layout and a pure-KV decoder family (dense/moe); other engines
  fall back to unshared bookkeeping.
* **Speculative decode** (``cfg.spec_k > 0``, DESIGN.md §5.3) — an
  on-device n-gram proposer (`serve.draft`) drafts ``spec_k`` tokens per
  slot from the slot's own history; ONE multi-token verify dispatch
  scores every draft position via the model's ragged ``prefill`` path,
  accepts each slot's matching prefix (1..spec_k+1 tokens per round) and
  rolls the rejected suffix back — a per-slot cursor rewind for KV
  families, a seg-gated replay for recurrent state (mamba2/zamba2).
  Output-identical to the non-speculative path under every sampling mode
  because acceptance replays the exact `(seed, token-index)`-keyed
  sampler decision the sequential loop would have made.
* **Sampling** (`serve.sampling.Sampler`) — greedy / temperature / top-k
  / top-p on device inside the chunk scan; per-request seeds fold into
  per-token keys so streams are independent of slot assignment order.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import hw
from repro.configs.base import ModelConfig
from repro.core import CachePolicyEngine, make_engine
from repro.core.characterize import attention_op
from repro.models import build_model
from repro.models.common import paged_kv_spec
from repro.serve.draft import ngram_propose
from repro.serve.prefix import PrefixIndex
from repro.serve.sampling import (  # noqa: F401  (greedy_sample re-export)
    Sampler,
    greedy_sample,
    sample_keys,
)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 16
    seed: int | None = None       # per-request sampling seed (None -> 0):
                                  # streams depend on (seed, token index)
                                  # only, never on slot assignment order
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    prefix_tokens: int = 0        # prompt tokens attached from shared pages
                                  # at admission (0 = fully prefilled)
    ttft_s: float | None = None        # admission -> first token (prefill)
    queue_wait_s: float | None = None  # submit -> admission (queueing only)
    submit_t: float | None = None
    admit_t: float | None = None


def _pad_bucket(n: int, cap: int) -> int:
    """Round a prefill width up to a power of two (>= 8) so the number of
    distinct prefill compilations is O(log max_len), not O(#prompt-lens)."""
    b = 8
    while b < n:
        b *= 2
    return min(b, cap)


class PageAllocator:
    """Refcounted host-side LIFO free-list over a fixed page pool
    (DESIGN.md §5.2, refcounts §5.4).

    Every held page carries a reference count: ``alloc`` hands out pages
    at refcount 1, ``share`` adds a reference to already-held pages (a new
    slot's page table aliasing a resident prefix page), and ``release``
    drops one — a page returns to the free list only at refcount zero, so
    a shared prefix page survives its original owner finishing.

    Invariants (property-tested in ``tests/test_alloc_property.py``,
    including a hypothesis state machine over alloc/share/release
    interleavings):

    * a page is never handed out twice without an intervening final
      ``release``,
    * ``alloc`` is atomic and never over-commits — when ``n`` exceeds the
      free count it returns None having popped nothing (admission
      gating; the guard predates refcounting but was untested, and is
      now pinned by a regression test),
    * no page is freed while references remain, and references are
      conserved across share/release interleavings,
    * held + free is a partition of the pool at all times (no leaks).
    """

    def __init__(self, n_pages: int):
        assert n_pages >= 0
        self.n_pages = n_pages
        self._free = list(range(n_pages))
        self._refs: dict[int, int] = {}

    @property
    def free_pages(self) -> list[int]:
        return list(self._free)

    @property
    def held_pages(self) -> set[int]:
        return set(self._refs)

    def free_count(self) -> int:
        return len(self._free)

    def ref_count(self, page: int) -> int:
        """Current reference count of ``page`` (0 if free)."""
        return self._refs.get(page, 0)

    def total_refs(self) -> int:
        return sum(self._refs.values())

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` pages (LIFO) at refcount 1, or None — having popped
        NOTHING — if the pool can't cover all ``n`` (atomic failure)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        assert not any(i in self._refs for i in ids), "double-allocated page"
        for i in ids:
            self._refs[i] = 1
        return ids

    def share(self, ids) -> None:
        """Add one reference to each held page in ``ids`` (a new sharer's
        page table now aliases them).  Sharing a free page is a bug."""
        ids = list(ids)
        assert len(ids) == len(set(ids)), (
            f"duplicate page ids in share(): {ids}"
        )
        bad = [i for i in ids if i not in self._refs]
        assert not bad, f"sharing pages not held: {bad}"
        for i in ids:
            self._refs[i] += 1

    def release(self, ids) -> list[int]:
        """Drop one reference per page; pages reaching refcount zero
        return to the free list.  Returns the ids actually freed (the
        engine evicts their trie nodes)."""
        ids = list(ids)
        assert len(ids) == len(set(ids)), (
            f"duplicate page ids in free(): {ids}"
        )
        bad = [i for i in ids if i not in self._refs]
        assert not bad, f"freeing pages not held: {bad}"
        freed = []
        for i in ids:
            self._refs[i] -= 1
            if self._refs[i] == 0:
                del self._refs[i]
                self._free.append(i)
                freed.append(i)
        return freed

    # Unshared call sites (and the pre-refcount test suite) say "free":
    # with every refcount at 1 release IS free.
    free = release


class ServeEngine:
    """Continuous-batching engine over a fixed pool of request slots.

    ``run(requests)`` (or ``submit`` + ``drain``) pushes requests through a
    queue: free slots are prefilled (ragged, right-padded), live slots
    decode in device-resident chunks — plain chunked decode, or draft/
    verify/rollback rounds when ``cfg.spec_k > 0`` — finished slots free at
    chunk boundaries and are immediately re-admitted from the queue.
    """

    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_len: int, extras: dict[str, Any] | None = None,
                 policy_engine: CachePolicyEngine | None = None,
                 chunk_size: int = 8, n_pages: int | None = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.chunk_size = max(1, chunk_size)
        self.extras = extras or {}
        self.sampler = Sampler.from_config(cfg)
        # Speculative decode (DESIGN.md §5.3): k drafts verified per round,
        # emitting 1..k+1 tokens; a chunk packs enough rounds to target
        # ~chunk_size tokens per host sync at full acceptance.
        self.spec = cfg.spec_k > 0
        self.spec_k = cfg.spec_k
        self.spec_ngram = cfg.spec_ngram
        self.spec_rounds = max(1, self.chunk_size // (cfg.spec_k + 1))
        # Paged KV layout (DESIGN.md §5.2): K/V capacity is pooled into
        # fixed-size pages shared across slots; the host-side free-list
        # assigns each admitted request exactly the pages its worst case
        # needs (prompt + budget), so a pool smaller than slots x max_len
        # serves mixed long/short traffic.  ``n_pages`` None sizes the pool
        # to full contiguous capacity.
        self.paged = cfg.cache_layout == "paged"
        cache_kwargs = dict(self.extras)
        if self.paged:
            psz = cfg.kv_page_size
            assert max_len % psz == 0, (
                f"max_len={max_len} must be a multiple of kv_page_size={psz} "
                "so the gathered page view is bit-identical to the "
                "contiguous ring"
            )
            self.page_size = psz
            self.pages_per_slot, self.n_pages = paged_kv_spec(
                batch_slots, max_len, psz, n_pages
            )
            self.allocator = PageAllocator(self.n_pages)
            self.page_table = np.full(
                (batch_slots, self.pages_per_slot), -1, np.int32
            )
            self._slot_pages: list[list[int]] = [[] for _ in range(batch_slots)]
            cache_kwargs["n_pages"] = self.n_pages
        self._cache_kwargs = cache_kwargs
        # Capacity-based MoE dispatch lets right-pad/parked garbage tokens
        # compete with valid tokens for expert capacity (silent drops);
        # serving requires the per-token dense dispatch (DESIGN.md §5.1).
        assert not cfg.n_experts or cfg.moe_dispatch == "dense", (
            "ServeEngine requires moe_dispatch='dense' (ragged slots would "
            "let padding contend for expert capacity under 'sorted')"
        )
        self.policy = policy_engine or make_engine()
        self.kv_residency = self.policy.kv_policy(self._kv_bytes_per_layer())
        # Decode-attention plan, memoized in the policy engine's PlanCache:
        # one lattice search + allocation per serve process, a cache hit for
        # every subsequent admission wave (re-plans are the admission-time
        # hot path).
        self.decode_plan = self._plan_decode()
        self.cache = self.model.init_cache(
            params, batch=batch_slots, max_len=max_len, **self._cache_kwargs
        )
        if self.paged and "pages" not in self.cache:
            # Cache family with no KV to page (mamba2's decode state is
            # O(1) per slot): fall back to contiguous bookkeeping rather
            # than gating admission on a phantom page pool.
            self.paged = False
            self.kv_residency = self.policy.kv_policy(
                self._kv_bytes_per_layer()
            )
        # Prefix sharing (DESIGN.md §5.4) rides the paged pool: the trie
        # indexes resident full prompt pages and admission attaches new
        # requests to them.  Pure-KV decoder families only — recurrent
        # state (mamba2/zamba2 SSM/conv) is not page-shareable, and
        # encdec/vlm prefix KV depends on per-slot source context (frames/
        # vision tokens), so those fall back to unshared bookkeeping.
        self.prefix_sharing = (
            bool(cfg.prefix_sharing) and self.paged
            and cfg.family in ("dense", "moe")
        )
        self.prefix = (
            PrefixIndex(self.page_size) if self.prefix_sharing else None
        )
        # Recurrent state (SSM/conv) has no per-position validity mask, so
        # the speculative rollback cannot be a cursor rewind: those
        # families re-run the verify block from the pre-verify cache with
        # ``seg_lens = accepted`` (the dt/conv gating makes the replay
        # consume exactly the accepted prefix).  KV-only families rewind.
        self._spec_replay = "ssm" in self.cache or "conv" in self.cache
        self._reset_slots = self.model.reset_slots
        self._prefill = jax.jit(
            self._prefill_fn, donate_argnums=(1, 6, 7, 9, 10, 11, 13)
        )
        self._decode_chunk = jax.jit(
            self._spec_chunk_fn if self.spec else self._chunk_fn,
            donate_argnums=(1, 2, 3, 4, 5, 6),
        )
        # Device-resident per-slot loop state: last sampled token, remaining
        # token budget (0 == slot parked/free), per-request token index and
        # sampling seed, and the token history the n-gram proposer mines
        # (prompt + emitted, including the not-yet-consumed current token —
        # at most max_len + 1 entries since prompt + budget <= max_len + 1).
        self.cur_tok = jnp.zeros((batch_slots,), jnp.int32)
        self.remaining = jnp.zeros((batch_slots,), jnp.int32)
        self.tok_idx = jnp.zeros((batch_slots,), jnp.int32)
        self.seeds = jnp.zeros((batch_slots,), jnp.int32)
        self.hist = jnp.zeros((batch_slots, max_len + 1), jnp.int32)
        self.hist_len = jnp.zeros((batch_slots,), jnp.int32)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.queue: collections.deque[Request] = collections.deque()
        self.stats = {
            "host_syncs": 0,          # total device->host barriers
            "decode_syncs": 0,        # one per decode chunk
            "decode_tokens": 0,       # tokens emitted by decode chunks
            "prefill_tokens": 0,      # first tokens emitted by prefill
            "chunks": 0,
            "admission_waves": 0,
            "spec_rounds": 0,         # active draft/verify rounds
            "draft_proposed": 0,      # spec_k per active round
            "draft_accepted": 0,      # matching draft prefix per round
            "prefix_hits": 0,         # admissions that attached shared pages
            "prefix_pages_shared": 0,  # shared-page references taken
            "prefix_tokens_shared": 0,  # prompt tokens not re-prefilled
            "peak_pages_held": 0,     # max concurrent pool usage (paged)
        }

    # -- policy ------------------------------------------------------------

    @property
    def free_pages(self) -> list[int]:
        """Free-list view (paged only) — delegated to the PageAllocator."""
        return self.allocator.free_pages

    def _kv_bytes_per_layer(self) -> int:
        """Real per-layer KV footprint, so residency planning sees the bytes
        actually allocated: the paged pool's n_pages x page_size positions,
        not the contiguous worst case of slots x max_len."""
        kv_heads = max(1, self.cfg.n_kv_heads)
        positions = (self.n_pages * self.page_size if self.paged
                     else self.slots * self.max_len)
        return (2 * positions * kv_heads
                * self.cfg.head_dim_ * hw.dtype_bytes(self.cfg.dtype))

    def _plan_decode(self):
        if not (self.cfg.n_heads and self.cfg.head_dim_):
            return None
        return self.policy.plan_op(attention_op(
            self.slots, self.cfg.n_heads, max(1, self.cfg.n_kv_heads),
            1, self.max_len, self.cfg.head_dim_, causal=False,
            name="serve_decode",
        ))

    def policy_report(self) -> dict:
        """Serving-side policy decisions (DESIGN.md §5) + planner counters."""
        report = {
            "kv_bytes_per_layer": self._kv_bytes_per_layer(),
            "kv_residency": self.kv_residency.value,
            # Effective layout: "contiguous" when a paged request met a
            # cache family with no KV to page (see __init__ fallback).
            "cache_layout": "paged" if self.paged else "contiguous",
            "sampling": self.sampler.mode,
            "plan_cache": self.policy.plan_stats(),
        }
        if self.spec:
            report["speculative"] = {
                "spec_k": self.spec_k,
                "spec_ngram": self.spec_ngram,
                "rounds_per_chunk": self.spec_rounds,
                "rollback": "replay" if self._spec_replay else "rewind",
            }
        if self.paged:
            report["paged_kv"] = {
                "n_pages": self.n_pages,
                "page_size": self.page_size,
                "free_pages": self.allocator.free_count(),
                "pool_positions": self.n_pages * self.page_size,
                "contiguous_positions": self.slots * self.max_len,
            }
        # "requested but not enabled" is the graceful-fallback signal
        # (contiguous layout, KV-free or source-conditioned families).
        report["prefix_sharing"] = {
            "requested": bool(self.cfg.prefix_sharing),
            "enabled": self.prefix_sharing,
        }
        if self.prefix is not None:
            report["prefix_sharing"].update({
                "trie_nodes": len(self.prefix),
                "resident_prefix_tokens": self.prefix.resident_tokens(),
            })
        if self.decode_plan is not None:
            report["decode_attention"] = {
                "assignment": {
                    k: v.value for k, v in self.decode_plan.assignment.items()
                },
                "vmem_bytes": self.decode_plan.vmem_bytes,
                "grid_order": list(self.decode_plan.grid_order),
            }
        return report

    def serve_stats(self) -> dict:
        """Host-sync + speculative-acceptance accounting for the loop."""
        out = dict(self.stats)
        total = out["decode_tokens"] + out["prefill_tokens"]
        out["host_syncs_per_token"] = (
            out["host_syncs"] / total if total else 0.0
        )
        out["decode_syncs_per_token"] = (
            out["decode_syncs"] / out["decode_tokens"]
            if out["decode_tokens"] else 0.0
        )
        out["spec_acceptance_rate"] = (
            out["draft_accepted"] / out["draft_proposed"]
            if out["draft_proposed"] else 0.0
        )
        out["spec_tokens_per_round"] = (
            out["decode_tokens"] / out["spec_rounds"]
            if out["spec_rounds"] else 0.0
        )
        # Every admitted request emits exactly one prefill token, so
        # prefill_tokens doubles as the admission count.
        out["prefix_hit_rate"] = (
            out["prefix_hits"] / out["prefill_tokens"]
            if out["prefill_tokens"] else 0.0
        )
        return out

    # -- device-side step functions (jitted once) --------------------------

    def _sample(self, logits, seeds, tok_idx):
        """Sampler dispatch: per-slot keys folded from (request seed, token
        index) — a pure function of the request, so streams are independent
        of slot assignment and batch composition."""
        keys = (sample_keys(seeds, tok_idx)
                if self.sampler.needs_keys else None)
        return self.sampler(logits, keys).astype(jnp.int32)

    def _hist_append(self, hist, positions, tokens):
        """Scatter ``tokens`` into per-slot history at ``positions``;
        out-of-range positions (parked slots pass H) drop."""
        b = hist.shape[0]
        return hist.at[jnp.arange(b)[:, None] if positions.ndim == 2
                       else jnp.arange(b), positions].set(tokens, mode="drop")

    def _prefill_fn(self, params, cache, tokens, seg_lens, start_lens,
                    hist_toks, cur_tok, remaining, new_remaining, tok_idx,
                    hist, hist_len, new_seeds, seeds):
        """Ragged admission prefill: reset re-admitted slots, prefill their
        prompts (seg_lens == 0 parks continuing slots), sample each admitted
        slot's first token on device, and (re)seed the slot's history /
        token-index / seed state.

        ``start_lens`` is the per-slot attach cursor: 0 for a full prefill,
        a page-aligned shared-prefix length when the slot rides resident
        prefix pages (DESIGN.md §5.4) — ``tokens`` then holds only the
        unshared suffix, positioned (RoPE and scatter) at start + i.
        ``hist_toks`` always carries the FULL prompt, so the n-gram history
        an attached slot's drafts mine is identical to the unshared
        engine's (the full prompt length is start + seg — no extra arg)."""
        b, pad = tokens.shape
        fpad = hist_toks.shape[1]
        H = hist.shape[1]
        admitted = seg_lens > 0
        if self._reset_slots is not None:
            cache = self._reset_slots(cache, admitted)
        cache = dict(cache)
        cache["lengths"] = jnp.where(
            admitted, start_lens, cache["lengths"]
        ).astype(jnp.int32)
        logits, cache = self.model.prefill(
            params, cache, tokens, seg_lens=seg_lens
        )
        # The first token of a request is token index 0 of its stream.
        nxt = self._sample(logits, new_seeds, jnp.zeros((b,), jnp.int32))
        cur_tok = jnp.where(admitted, nxt, cur_tok)
        remaining = jnp.where(admitted, new_remaining, remaining)
        seeds = jnp.where(admitted, new_seeds, seeds)
        tok_idx = jnp.where(admitted, 1, tok_idx)
        # History: full-prompt rows land at 0..full-1, the first token at
        # full; parked slots redirect to H and drop.
        full_seg = start_lens + seg_lens
        pos = jnp.broadcast_to(jnp.arange(fpad)[None, :], (b, fpad))
        pos = jnp.where(
            admitted[:, None] & (pos < full_seg[:, None]), pos, H
        )
        hist = self._hist_append(hist, pos, hist_toks)
        hist = self._hist_append(
            hist, jnp.where(admitted, full_seg, H), nxt
        )
        hist_len = jnp.where(admitted, full_seg + 1, hist_len)
        return cache, cur_tok, remaining, tok_idx, hist, hist_len, seeds, nxt

    def _chunk_fn(self, params, cache, cur_tok, remaining, tok_idx, hist,
                  hist_len, seeds):
        """Decode ``chunk_size`` tokens per slot in one dispatch: scan of
        single-token steps with on-device sampling; slots whose budget hits
        zero park (seg_lens == 0 -> state untouched).

        Only the speculative path consumes the n-gram history, so this
        (non-spec) chunk passes ``hist``/``hist_len`` through untouched —
        no per-token scatter or carry traffic on the hot loop."""

        def step(carry, _):
            cache, tok, rem, tidx = carry
            active = rem > 0
            logits, cache = self.model.decode_step(
                params, cache, tok[:, None],
                seg_lens=active.astype(jnp.int32),
            )
            nxt = self._sample(logits, seeds, tidx)
            tok = jnp.where(active, nxt, tok)
            tidx = jnp.where(active, tidx + 1, tidx)
            rem = jnp.where(active, rem - 1, rem)
            return (cache, tok, rem, tidx), (tok, active)

        (cache, tok, rem, tidx), (toks, actives) = jax.lax.scan(
            step, (cache, cur_tok, remaining, tok_idx),
            None, length=self.chunk_size,
        )
        return cache, tok, rem, tidx, hist, hist_len, toks, actives

    def _spec_chunk_fn(self, params, cache, cur_tok, remaining, tok_idx,
                       hist, hist_len, seeds):
        """``spec_rounds`` draft/verify/rollback rounds in one dispatch
        (DESIGN.md §5.3).  Each round, per active slot:

        1. *Draft*: ``ngram_propose`` mines the slot's history for spec_k
           draft tokens.
        2. *Verify*: ONE ragged multi-token ``prefill`` over
           ``[cur_tok, d_1..d_k]`` returns logits for every position;
           position j's sampler decision (keyed by token index
           ``tok_idx + j``) is exactly the token the sequential loop would
           emit there, so the target tokens double as the emissions.
        3. *Accept*: the emitted count is ``min(matching prefix + 1,
           remaining)`` — always >= 1 (the sampler's own token at the first
           mismatch), at most spec_k + 1 (all drafts + the bonus token).
        4. *Rollback*: KV families keep the verify-pass cache and rewind
           ``lengths`` to base + accepted (rejected KV is stale-but-masked,
           overwritten as the cursor advances — the ring invariant);
           recurrent families replay the block from the pre-verify cache
           with ``seg_lens = accepted`` (dt/conv gating consumes exactly
           the accepted prefix).
        """
        b = self.slots
        k, k1 = self.spec_k, self.spec_k + 1
        H = hist.shape[1]

        def round_fn(carry, _):
            cache, tok, rem, tidx, hist, hlen = carry
            active = rem > 0
            base_len = cache["lengths"]
            drafts = ngram_propose(hist, hlen, self.spec_ngram, k)
            vt = jnp.concatenate([tok[:, None], drafts], axis=1)  # (b, k1)
            seg_v = jnp.where(active, k1, 0).astype(jnp.int32)
            logits_all, cache_v = self.model.prefill(
                params, cache, vt, seg_lens=seg_v, all_logits=True
            )
            # Target token at position j = sampler decision for token index
            # tidx + j: identical to what sequential decode would sample.
            if self.sampler.needs_keys:
                keys = sample_keys(
                    jnp.broadcast_to(seeds[:, None], (b, k1)).reshape(-1),
                    (tidx[:, None] + jnp.arange(k1)[None, :]).reshape(-1),
                )
            else:
                keys = None
            targets = self.sampler(
                logits_all.reshape(b * k1, -1), keys
            ).astype(jnp.int32).reshape(b, k1)
            match = (drafts == targets[:, :k]).astype(jnp.int32)
            accepted = jnp.sum(jnp.cumprod(match, axis=1), axis=1)   # (b,)
            m = jnp.where(active, jnp.minimum(accepted + 1, rem), 0)
            # Acceptance accounting reflects USABLE drafts only: a slot
            # with rem remaining tokens can consume at most rem - 1 drafts
            # this round, so matches past the budget clip neither count as
            # accepted nor as proposed (they produced no tokens).
            usable = jnp.where(
                active, jnp.minimum(jnp.int32(k), rem - 1), 0
            )
            acc_used = jnp.maximum(m - 1, 0)
            if self._spec_replay:
                # Recurrent rollback: consume exactly the accepted prefix
                # from the pre-verify cache (discard the polluted verify
                # state).  Also rewrites the accepted KV — same bytes.
                _, cache = self.model.prefill(
                    params, cache, vt, seg_lens=m
                )
            else:
                # KV rollback: rejected positions are beyond the rewound
                # cursor — stale-but-masked, overwritten as it advances.
                cache = dict(cache_v)
                cache["lengths"] = base_len + m
            emit = jnp.arange(k1)[None, :] < m[:, None]              # (b, k1)
            hist = self._hist_append(
                hist,
                jnp.where(emit, hlen[:, None] + jnp.arange(k1)[None, :], H),
                targets,
            )
            last = jnp.take_along_axis(
                targets, jnp.clip(m - 1, 0, k)[:, None], axis=1
            )[:, 0]
            tok = jnp.where(active, last, tok)
            hlen = hlen + m
            tidx = tidx + m
            rem = rem - m
            return (cache, tok, rem, tidx, hist, hlen), (
                targets, emit, acc_used, usable, active
            )

        carry = (cache, cur_tok, remaining, tok_idx, hist, hist_len)
        (cache, tok, rem, tidx, hist, hlen), ys = jax.lax.scan(
            round_fn, carry, None, length=self.spec_rounds
        )
        toks, emits, accepts, proposed, actives = ys
        return (cache, tok, rem, tidx, hist, hlen,
                toks, emits, accepts, proposed, actives)

    # -- host-side scheduling ----------------------------------------------

    def _positions_needed(self, r: Request) -> int:
        """Worst-case cache positions: the prompt plus every decoded token
        except the last sampled one (which is never written back)."""
        return len(r.prompt) + r.max_new_tokens - 1

    def _pages_needed(self, r: Request) -> int:
        return -(-self._positions_needed(r) // self.page_size)

    def _shared_prefix(self, r: Request, chunks) -> tuple[list[int], int]:
        """(pages, tokens): the longest resident full-page prefix of
        ``r.prompt`` (pre-chunked into ``chunks``) this request can attach
        to (DESIGN.md §5.4).

        Capped below the prompt's full-page count so the prompt's last
        token is ALWAYS re-prefilled: the logits seeding decode are
        computed fresh, never assumed resident — a prompt that is exactly
        its shared pages would otherwise have an empty suffix and park
        forever.  The cap also makes the COW case concrete: a prompt
        ending exactly at a shared-page boundary re-materializes that last
        page's K/V into a private page (same bytes, private residency)."""
        pages = self.prefix.lookup(r.prompt, chunks=chunks)
        cap = (len(r.prompt) - 1) // self.page_size
        pages = pages[:cap]
        return pages, len(pages) * self.page_size

    def submit(self, requests: list[Request]) -> None:
        # Validate the whole batch before enqueuing any of it, so a
        # rejected request doesn't leave earlier ones half-submitted.
        for r in requests:
            if r.max_new_tokens < 1:
                # Admission always emits the prefill-sampled first token, so
                # a zero budget would generate one token anyway — reject
                # instead of silently over-generating.
                raise ValueError(
                    f"max_new_tokens must be >= 1, got {r.max_new_tokens} "
                    "(prefill emits the first token at admission)"
                )
            assert len(r.prompt) > 0, (
                "empty prompt: seg_lens==0 marks a parked slot, so a "
                "zero-length admission would never start decoding"
            )
            need = self._positions_needed(r)
            assert need <= self.max_len, (
                f"request needs {need} cache positions, max_len={self.max_len}"
            )
            if self.paged:
                assert self._pages_needed(r) <= self.n_pages, (
                    f"request needs {self._pages_needed(r)} pages, pool has "
                    f"{self.n_pages} — it could never be admitted"
                )
        now = time.perf_counter()
        for r in requests:
            r.submit_t = now
            self.queue.append(r)

    def _live(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slot_req) if r is not None]

    def _finish(self, r: Request) -> None:
        r.done = True
        self.slot_req[r.slot] = None
        if self.paged:
            # Drop the slot's references.  Pages shared with live slots
            # survive (refcount > 0); pages reaching zero return to the
            # pool and their trie nodes evict.  The device page table is
            # refreshed lazily at the next admission wave; until then the
            # stale row is harmless — the parked slot neither writes KV
            # (seg_lens == 0 drops the scatter) nor has its output read.
            freed = self.allocator.release(self._slot_pages[r.slot])
            if self.prefix is not None and freed:
                self.prefix.evict(freed)
            self._slot_pages[r.slot] = []
            self.page_table[r.slot] = -1

    def _admit_wave(self) -> None:
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        now = time.perf_counter()
        wave: list[tuple[int, Request]] = []
        for slot in free:
            if not self.queue:
                break
            if self.paged:
                # Admission gates on free pages (FIFO head-of-line: a
                # request that doesn't fit waits for pages to free rather
                # than being overtaken).  With prefix sharing the head
                # only needs pages for its UNSHARED suffix; the shared
                # prefix rides resident pages via a refcount bump.  Alloc
                # first, share only on success — a gated head must leave
                # every refcount untouched.
                head = self.queue[0]
                shared, shared_len = [], 0
                chunks = None
                if self.prefix is not None:
                    # Chunk the prompt once per REQUEST (memoized on it):
                    # lookup and register reuse the list, and a page-gated
                    # head re-tried every chunk boundary doesn't rebuild
                    # it.  The lookup itself must re-run per attempt — the
                    # resident chain can grow/shrink while the head waits.
                    chunks = getattr(head, "_prefix_chunks", None)
                    if chunks is None:
                        chunks = self.prefix.chunks(head.prompt)
                        head._prefix_chunks = chunks
                    shared, shared_len = self._shared_prefix(head, chunks)
                ids = self.allocator.alloc(self._pages_needed(head)
                                           - len(shared))
                if ids is None:
                    break
                if shared:
                    self.allocator.share(shared)
                r = self.queue.popleft()
                # The chunk memo exists only to amortize head-of-line
                # retries; drop it at admission so engine-private (and
                # page-size-dependent) state never outlives the queue.
                r.__dict__.pop("_prefix_chunks", None)
                r.prefix_tokens = shared_len
                table = shared + ids
                self._slot_pages[slot] = table
                self.page_table[slot] = -1
                self.page_table[slot, :len(table)] = table
                if self.prefix is not None:
                    # Index this prompt's own full pages so later requests
                    # can attach; already-resident chunks keep their
                    # existing (shared) nodes.
                    self.prefix.register(r.prompt, table[:len(chunks)],
                                         chunks=chunks)
                    if shared:
                        self.stats["prefix_hits"] += 1
                        self.stats["prefix_pages_shared"] += len(shared)
                        self.stats["prefix_tokens_shared"] += shared_len
            else:
                r = self.queue.popleft()
                r.prefix_tokens = 0    # contiguous: always a full prefill
            r.admit_t = now
            if r.submit_t is not None:
                r.queue_wait_s = now - r.submit_t
            wave.append((slot, r))
        if not wave:
            return
        # Attached slots prefill only their unshared suffix (prefix_tokens
        # is 0 without sharing), so the pad bucket — and the prefill's
        # compute — shrinks to the widest *suffix* in the wave.  The
        # n-gram history still seeds from the FULL prompt via a separate
        # (cheap, scatter-only) buffer, so drafting under sharing matches
        # the unshared engine.
        pad = _pad_bucket(
            max(len(r.prompt) - r.prefix_tokens for _, r in wave),
            self.max_len,
        )
        # The full-prompt history buffer only differs from the prefill
        # buffer when some wave member attached a prefix; otherwise the
        # suffix IS the prompt and one buffer serves both arguments.
        attached = any(r.prefix_tokens for _, r in wave)
        toks = np.zeros((self.slots, pad), np.int32)
        if attached:
            hpad = _pad_bucket(
                max(len(r.prompt) for _, r in wave), self.max_len
            )
            htoks = np.zeros((self.slots, hpad), np.int32)
        else:
            htoks = toks
        seg = np.zeros((self.slots,), np.int32)
        start = np.zeros((self.slots,), np.int32)
        new_rem = np.zeros((self.slots,), np.int32)
        new_seeds = np.zeros((self.slots,), np.int32)
        for slot, r in wave:
            n = len(r.prompt) - r.prefix_tokens
            toks[slot, :n] = r.prompt[r.prefix_tokens:]   # right-pad; drops
            if attached:
                htoks[slot, :len(r.prompt)] = r.prompt
            seg[slot] = n
            start[slot] = r.prefix_tokens      # page-aligned attach cursor
            new_rem[slot] = r.max_new_tokens - 1
            # Fold arbitrary Python ints (64-bit hashes, negatives) into
            # int32 range: still a pure function of the request's seed, so
            # determinism and order-independence are preserved.
            new_seeds[slot] = (0 if r.seed is None else r.seed) % (2 ** 31)
            r.slot = slot
            self.slot_req[slot] = r
        if self.paged:
            # Push the host free-list's view of the page table to device.
            # The table is tiny; replacing the leaf keeps the jitted prefill
            # signature layout-independent (donation still applies).
            self.cache = {**self.cache, "pages": jnp.asarray(self.page_table)}
        # Admission consults the policy engine: KV residency for the current
        # occupancy and the (PlanCache-memoized) decode-attention plan.
        self.decode_plan = self._plan_decode()
        toks_d = jnp.asarray(toks)
        htoks_d = jnp.asarray(htoks) if attached else toks_d
        (self.cache, self.cur_tok, self.remaining, self.tok_idx, self.hist,
         self.hist_len, self.seeds, nxt) = self._prefill(
            self.params, self.cache, toks_d, jnp.asarray(seg),
            jnp.asarray(start), htoks_d, self.cur_tok,
            self.remaining, jnp.asarray(new_rem), self.tok_idx, self.hist,
            self.hist_len, jnp.asarray(new_seeds), self.seeds,
        )
        first = np.asarray(nxt)                # host sync: 1 per wave
        self.stats["host_syncs"] += 1
        self.stats["admission_waves"] += 1
        if self.paged:
            self.stats["peak_pages_held"] = max(
                self.stats["peak_pages_held"],
                self.n_pages - self.allocator.free_count(),
            )
        now = time.perf_counter()
        for _, r in wave:
            r.generated.append(int(first[r.slot]))
            self.stats["prefill_tokens"] += 1
            if r.ttft_s is None and r.admit_t is not None:
                # True TTFT: admission -> first token (prefill compute);
                # queueing is reported separately as queue_wait_s.
                r.ttft_s = now - r.admit_t
            if len(r.generated) >= r.max_new_tokens:
                self._finish(r)

    def _run_chunk(self) -> None:
        (self.cache, self.cur_tok, self.remaining, self.tok_idx, self.hist,
         self.hist_len, toks, actives) = self._decode_chunk(
            self.params, self.cache, self.cur_tok, self.remaining,
            self.tok_idx, self.hist, self.hist_len, self.seeds,
        )
        t_np, a_np = jax.device_get((toks, actives))   # host sync: 1 per chunk
        self.stats["host_syncs"] += 1
        self.stats["decode_syncs"] += 1
        self.stats["chunks"] += 1
        for slot, r in self._live():
            emitted = a_np[:, slot]
            for i in np.nonzero(emitted)[0]:
                r.generated.append(int(t_np[i, slot]))
            self.stats["decode_tokens"] += int(emitted.sum())
            if len(r.generated) >= r.max_new_tokens:
                self._finish(r)

    def _run_spec_chunk(self) -> None:
        (self.cache, self.cur_tok, self.remaining, self.tok_idx, self.hist,
         self.hist_len, toks, emits, accepts, proposed,
         actives) = self._decode_chunk(
            self.params, self.cache, self.cur_tok, self.remaining,
            self.tok_idx, self.hist, self.hist_len, self.seeds,
        )
        # toks/emits: (rounds, b, k+1); accepts/proposed/actives: (rounds, b).
        t_np, e_np, acc_np, prop_np, act_np = jax.device_get(
            (toks, emits, accepts, proposed, actives)
        )                                              # host sync: 1 per chunk
        self.stats["host_syncs"] += 1
        self.stats["decode_syncs"] += 1
        self.stats["chunks"] += 1
        for slot, r in self._live():
            for j in range(t_np.shape[0]):
                if not act_np[j, slot]:
                    continue
                row = e_np[j, slot]
                for t in t_np[j, slot][row]:
                    r.generated.append(int(t))
                self.stats["decode_tokens"] += int(row.sum())
                self.stats["spec_rounds"] += 1
                self.stats["draft_proposed"] += int(prop_np[j, slot])
                self.stats["draft_accepted"] += int(acc_np[j, slot])
            if len(r.generated) >= r.max_new_tokens:
                self._finish(r)

    def drain(self) -> None:
        """Run admission + chunked decode until queue and slots are empty."""
        run = self._run_spec_chunk if self.spec else self._run_chunk
        while self.queue or self.slot_req.count(None) < self.slots:
            self._admit_wave()
            if self.slot_req.count(None) < self.slots:
                run()

    def run(self, requests: list[Request]) -> list[Request]:
        self.submit(requests)
        self.drain()
        return requests
