"""Device-resident continuous-batching serve engine.

The memory-policy engine drives two serving decisions (DESIGN.md §5):

* KV residency per layer (`engine.kv_policy`): decode KV is a zero-reuse
  stream (the paper's throughput-sensitive class) — STREAM via the
  split-KV decode kernel; fixed-source caches (whisper enc K/V, vision
  patch K/V) are RESIDENT (reused every step, fetched once).
* Split-count planning for flash-decoding (`kernels.decode_attention.ops`),
  memoized in the PlanCache and re-consulted at every admission wave.

The serving loop itself is built to run at hardware speed (the inference
loop, not the policy search, is the artifact that must be fast):

* **Chunked on-device decode** — one `lax.scan` dispatch decodes
  ``chunk_size`` tokens for every slot with on-device greedy sampling and
  per-slot done flags; the host syncs once per *chunk* (to read the
  emitted tokens), not once per token.
* **Ragged slots** — the cache carries a per-slot ``lengths`` cursor
  vector, so slots free and re-admit independently: finished slots park
  (``seg_lens == 0`` leaves their state untouched) while live slots keep
  decoding, and freed slots take new prompts mid-stream via a ragged
  right-padded prefill (`models.common.append_kv` drops padding on the
  scatter, so mixed-length prompts never cross-contaminate).
* **Donated buffers** — the cache (and the per-slot token/budget vectors)
  are donated to each dispatch, so KV updates are in-place on device.
* **Paged KV pool** (``cfg.cache_layout == "paged"``, DESIGN.md §5.2) —
  K/V capacity is pooled into fixed-size pages shared across slots; a
  host-side free-list assigns each admitted request exactly the pages its
  worst case needs and admission gates on free pages, so a pool smaller
  than ``slots x max_len`` serves mixed long/short traffic while staying
  bit-identical to the contiguous ring.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import hw
from repro.configs.base import ModelConfig
from repro.core import CachePolicyEngine, make_engine
from repro.core.characterize import attention_op
from repro.models import build_model
from repro.models.common import paged_kv_spec


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    ttft_s: float | None = None        # admission -> first token (prefill)
    queue_wait_s: float | None = None  # submit -> admission (queueing only)
    submit_t: float | None = None
    admit_t: float | None = None


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits[:, -1], axis=-1)


def _pad_bucket(n: int, cap: int) -> int:
    """Round a prefill width up to a power of two (>= 8) so the number of
    distinct prefill compilations is O(log max_len), not O(#prompt-lens)."""
    b = 8
    while b < n:
        b *= 2
    return min(b, cap)


class ServeEngine:
    """Continuous-batching engine over a fixed pool of request slots.

    ``run(requests)`` (or ``submit`` + ``drain``) pushes requests through a
    queue: free slots are prefilled (ragged, right-padded), live slots
    decode in device-resident chunks, finished slots free at chunk
    boundaries and are immediately re-admitted from the queue.
    """

    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_len: int, extras: dict[str, Any] | None = None,
                 policy_engine: CachePolicyEngine | None = None,
                 chunk_size: int = 8, n_pages: int | None = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.chunk_size = max(1, chunk_size)
        self.extras = extras or {}
        # Paged KV layout (DESIGN.md §5.2): K/V capacity is pooled into
        # fixed-size pages shared across slots; this host-side free-list
        # assigns each admitted request exactly the pages its worst case
        # needs (prompt + budget), so a pool smaller than slots x max_len
        # serves mixed long/short traffic.  ``n_pages`` None sizes the pool
        # to full contiguous capacity.
        self.paged = cfg.cache_layout == "paged"
        cache_kwargs = dict(self.extras)
        if self.paged:
            psz = cfg.kv_page_size
            assert max_len % psz == 0, (
                f"max_len={max_len} must be a multiple of kv_page_size={psz} "
                "so the gathered page view is bit-identical to the "
                "contiguous ring"
            )
            self.page_size = psz
            self.pages_per_slot, self.n_pages = paged_kv_spec(
                batch_slots, max_len, psz, n_pages
            )
            self.free_pages = list(range(self.n_pages))
            self.page_table = np.full(
                (batch_slots, self.pages_per_slot), -1, np.int32
            )
            self._slot_pages: list[list[int]] = [[] for _ in range(batch_slots)]
            cache_kwargs["n_pages"] = self.n_pages
        self._cache_kwargs = cache_kwargs
        # Capacity-based MoE dispatch lets right-pad/parked garbage tokens
        # compete with valid tokens for expert capacity (silent drops);
        # serving requires the per-token dense dispatch (DESIGN.md §5.1).
        assert not cfg.n_experts or cfg.moe_dispatch == "dense", (
            "ServeEngine requires moe_dispatch='dense' (ragged slots would "
            "let padding contend for expert capacity under 'sorted')"
        )
        self.policy = policy_engine or make_engine()
        self.kv_residency = self.policy.kv_policy(self._kv_bytes_per_layer())
        # Decode-attention plan, memoized in the policy engine's PlanCache:
        # one lattice search + allocation per serve process, a cache hit for
        # every subsequent admission wave (re-plans are the admission-time
        # hot path).
        self.decode_plan = self._plan_decode()
        self.cache = self.model.init_cache(
            params, batch=batch_slots, max_len=max_len, **self._cache_kwargs
        )
        if self.paged and "pages" not in self.cache:
            # Cache family with no KV to page (mamba2's decode state is
            # O(1) per slot): fall back to contiguous bookkeeping rather
            # than gating admission on a phantom page pool.
            self.paged = False
            self.kv_residency = self.policy.kv_policy(
                self._kv_bytes_per_layer()
            )
        self._reset_slots = self.model.reset_slots
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(1, 4, 5))
        self._decode_chunk = jax.jit(self._chunk_fn, donate_argnums=(1, 2, 3))
        # Device-resident per-slot loop state: last sampled token and the
        # remaining token budget (0 == slot parked/free).
        self.cur_tok = jnp.zeros((batch_slots,), jnp.int32)
        self.remaining = jnp.zeros((batch_slots,), jnp.int32)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.queue: collections.deque[Request] = collections.deque()
        self.stats = {
            "host_syncs": 0,          # total device->host barriers
            "decode_syncs": 0,        # one per decode chunk
            "decode_tokens": 0,       # tokens emitted by decode chunks
            "prefill_tokens": 0,      # first tokens emitted by prefill
            "chunks": 0,
            "admission_waves": 0,
        }

    # -- policy ------------------------------------------------------------

    def _kv_bytes_per_layer(self) -> int:
        """Real per-layer KV footprint, so residency planning sees the bytes
        actually allocated: the paged pool's n_pages x page_size positions,
        not the contiguous worst case of slots x max_len."""
        kv_heads = max(1, self.cfg.n_kv_heads)
        positions = (self.n_pages * self.page_size if self.paged
                     else self.slots * self.max_len)
        return (2 * positions * kv_heads
                * self.cfg.head_dim_ * hw.dtype_bytes(self.cfg.dtype))

    def _plan_decode(self):
        if not (self.cfg.n_heads and self.cfg.head_dim_):
            return None
        return self.policy.plan_op(attention_op(
            self.slots, self.cfg.n_heads, max(1, self.cfg.n_kv_heads),
            1, self.max_len, self.cfg.head_dim_, causal=False,
            name="serve_decode",
        ))

    def policy_report(self) -> dict:
        """Serving-side policy decisions (DESIGN.md §5) + planner counters."""
        report = {
            "kv_bytes_per_layer": self._kv_bytes_per_layer(),
            "kv_residency": self.kv_residency.value,
            # Effective layout: "contiguous" when a paged request met a
            # cache family with no KV to page (see __init__ fallback).
            "cache_layout": "paged" if self.paged else "contiguous",
            "plan_cache": self.policy.plan_stats(),
        }
        if self.paged:
            report["paged_kv"] = {
                "n_pages": self.n_pages,
                "page_size": self.page_size,
                "free_pages": len(self.free_pages),
                "pool_positions": self.n_pages * self.page_size,
                "contiguous_positions": self.slots * self.max_len,
            }
        if self.decode_plan is not None:
            report["decode_attention"] = {
                "assignment": {
                    k: v.value for k, v in self.decode_plan.assignment.items()
                },
                "vmem_bytes": self.decode_plan.vmem_bytes,
                "grid_order": list(self.decode_plan.grid_order),
            }
        return report

    def serve_stats(self) -> dict:
        """Host-sync accounting for the decode loop."""
        out = dict(self.stats)
        total = out["decode_tokens"] + out["prefill_tokens"]
        out["host_syncs_per_token"] = (
            out["host_syncs"] / total if total else 0.0
        )
        out["decode_syncs_per_token"] = (
            out["decode_syncs"] / out["decode_tokens"]
            if out["decode_tokens"] else 0.0
        )
        return out

    # -- device-side step functions (jitted once) --------------------------

    def _prefill_fn(self, params, cache, tokens, seg_lens, cur_tok,
                    remaining, new_remaining):
        """Ragged admission prefill: reset re-admitted slots, prefill their
        prompts (seg_lens == 0 parks continuing slots), sample each admitted
        slot's first token on device."""
        admitted = seg_lens > 0
        if self._reset_slots is not None:
            cache = self._reset_slots(cache, admitted)
        logits, cache = self.model.prefill(
            params, cache, tokens, seg_lens=seg_lens
        )
        nxt = greedy_sample(logits).astype(jnp.int32)
        cur_tok = jnp.where(admitted, nxt, cur_tok)
        remaining = jnp.where(admitted, new_remaining, remaining)
        return cache, cur_tok, remaining, nxt

    def _chunk_fn(self, params, cache, cur_tok, remaining):
        """Decode ``chunk_size`` tokens per slot in one dispatch: scan of
        single-token steps with on-device greedy sampling; slots whose
        budget hits zero park (seg_lens == 0 -> state untouched)."""
        def step(carry, _):
            cache, tok, rem = carry
            active = rem > 0
            logits, cache = self.model.decode_step(
                params, cache, tok[:, None],
                seg_lens=active.astype(jnp.int32),
            )
            nxt = greedy_sample(logits).astype(jnp.int32)
            tok = jnp.where(active, nxt, tok)
            rem = jnp.where(active, rem - 1, rem)
            return (cache, tok, rem), (tok, active)

        (cache, tok, rem), (toks, actives) = jax.lax.scan(
            step, (cache, cur_tok, remaining), None, length=self.chunk_size
        )
        return cache, tok, rem, toks, actives

    # -- host-side scheduling ----------------------------------------------

    def _positions_needed(self, r: Request) -> int:
        """Worst-case cache positions: the prompt plus every decoded token
        except the last sampled one (which is never written back)."""
        return len(r.prompt) + r.max_new_tokens - 1

    def _pages_needed(self, r: Request) -> int:
        return -(-self._positions_needed(r) // self.page_size)

    def submit(self, requests: list[Request]) -> None:
        # Validate the whole batch before enqueuing any of it, so a
        # rejected request doesn't leave earlier ones half-submitted.
        for r in requests:
            if r.max_new_tokens < 1:
                # Admission always emits the prefill-sampled first token, so
                # a zero budget would generate one token anyway — reject
                # instead of silently over-generating.
                raise ValueError(
                    f"max_new_tokens must be >= 1, got {r.max_new_tokens} "
                    "(prefill emits the first token at admission)"
                )
            assert len(r.prompt) > 0, (
                "empty prompt: seg_lens==0 marks a parked slot, so a "
                "zero-length admission would never start decoding"
            )
            need = self._positions_needed(r)
            assert need <= self.max_len, (
                f"request needs {need} cache positions, max_len={self.max_len}"
            )
            if self.paged:
                assert self._pages_needed(r) <= self.n_pages, (
                    f"request needs {self._pages_needed(r)} pages, pool has "
                    f"{self.n_pages} — it could never be admitted"
                )
        now = time.perf_counter()
        for r in requests:
            r.submit_t = now
            self.queue.append(r)

    def _live(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slot_req) if r is not None]

    def _finish(self, r: Request) -> None:
        r.done = True
        self.slot_req[r.slot] = None
        if self.paged:
            # Return the slot's pages to the pool.  The device page table is
            # refreshed lazily at the next admission wave; until then the
            # stale row is harmless — the parked slot neither writes KV
            # (seg_lens == 0 drops the scatter) nor has its output read.
            self.free_pages.extend(self._slot_pages[r.slot])
            self._slot_pages[r.slot] = []
            self.page_table[r.slot] = -1

    def _admit_wave(self) -> None:
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        now = time.perf_counter()
        wave: list[tuple[int, Request]] = []
        for slot in free:
            if not self.queue:
                break
            if self.paged:
                # Admission gates on free pages (FIFO head-of-line: a
                # request that doesn't fit waits for pages to free rather
                # than being overtaken).
                need = self._pages_needed(self.queue[0])
                if need > len(self.free_pages):
                    break
                r = self.queue.popleft()
                ids = [self.free_pages.pop() for _ in range(need)]
                self._slot_pages[slot] = ids
                self.page_table[slot] = -1
                self.page_table[slot, :need] = ids
            else:
                r = self.queue.popleft()
            r.admit_t = now
            if r.submit_t is not None:
                r.queue_wait_s = now - r.submit_t
            wave.append((slot, r))
        if not wave:
            return
        pad = _pad_bucket(max(len(r.prompt) for _, r in wave), self.max_len)
        toks = np.zeros((self.slots, pad), np.int32)
        seg = np.zeros((self.slots,), np.int32)
        new_rem = np.zeros((self.slots,), np.int32)
        for slot, r in wave:
            n = len(r.prompt)
            toks[slot, :n] = r.prompt          # right-pad; scatter drops tail
            seg[slot] = n
            new_rem[slot] = r.max_new_tokens - 1
            r.slot = slot
            self.slot_req[slot] = r
        if self.paged:
            # Push the host free-list's view of the page table to device.
            # The table is tiny; replacing the leaf keeps the jitted prefill
            # signature layout-independent (donation still applies).
            self.cache = {**self.cache, "pages": jnp.asarray(self.page_table)}
        # Admission consults the policy engine: KV residency for the current
        # occupancy and the (PlanCache-memoized) decode-attention plan.
        self.decode_plan = self._plan_decode()
        self.cache, self.cur_tok, self.remaining, nxt = self._prefill(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(seg),
            self.cur_tok, self.remaining, jnp.asarray(new_rem),
        )
        first = np.asarray(nxt)                # host sync: 1 per wave
        self.stats["host_syncs"] += 1
        self.stats["admission_waves"] += 1
        now = time.perf_counter()
        for _, r in wave:
            r.generated.append(int(first[r.slot]))
            self.stats["prefill_tokens"] += 1
            if r.ttft_s is None and r.admit_t is not None:
                # True TTFT: admission -> first token (prefill compute);
                # queueing is reported separately as queue_wait_s.
                r.ttft_s = now - r.admit_t
            if len(r.generated) >= r.max_new_tokens:
                self._finish(r)

    def _run_chunk(self) -> None:
        self.cache, self.cur_tok, self.remaining, toks, actives = (
            self._decode_chunk(
                self.params, self.cache, self.cur_tok, self.remaining
            )
        )
        t_np, a_np = jax.device_get((toks, actives))   # host sync: 1 per chunk
        self.stats["host_syncs"] += 1
        self.stats["decode_syncs"] += 1
        self.stats["chunks"] += 1
        for slot, r in self._live():
            emitted = a_np[:, slot]
            for i in np.nonzero(emitted)[0]:
                r.generated.append(int(t_np[i, slot]))
            self.stats["decode_tokens"] += int(emitted.sum())
            if len(r.generated) >= r.max_new_tokens:
                self._finish(r)

    def drain(self) -> None:
        """Run admission + chunked decode until queue and slots are empty."""
        while self.queue or self.slot_req.count(None) < self.slots:
            self._admit_wave()
            if self.slot_req.count(None) < self.slots:
                self._run_chunk()

    def run(self, requests: list[Request]) -> list[Request]:
        self.submit(requests)
        self.drain()
        return requests
