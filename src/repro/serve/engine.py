"""Device-resident continuous-batching serve engine.

The memory-policy engine drives two serving decisions (DESIGN.md §5):

* KV residency per layer (`engine.kv_policy`): decode KV is a zero-reuse
  stream (the paper's throughput-sensitive class) — STREAM via the
  split-KV decode kernel; fixed-source caches (whisper enc K/V, vision
  patch K/V) are RESIDENT (reused every step, fetched once).
* Split-count planning for flash-decoding (`kernels.decode_attention.ops`),
  memoized in the PlanCache and re-consulted at every admission wave.

The serving loop itself is built to run at hardware speed (the inference
loop, not the policy search, is the artifact that must be fast):

* **Chunked on-device decode** — one `lax.scan` dispatch decodes
  ``chunk_size`` tokens for every slot with on-device greedy sampling and
  per-slot done flags; the host syncs once per *chunk* (to read the
  emitted tokens), not once per token.
* **Ragged slots** — the cache carries a per-slot ``lengths`` cursor
  vector, so slots free and re-admit independently: finished slots park
  (``seg_lens == 0`` leaves their state untouched) while live slots keep
  decoding, and freed slots take new prompts mid-stream via a ragged
  right-padded prefill (`models.common.append_kv` drops padding on the
  scatter, so mixed-length prompts never cross-contaminate).
* **Donated buffers** — the cache (and the per-slot token/budget vectors)
  are donated to each dispatch, so KV updates are in-place on device.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import hw
from repro.configs.base import ModelConfig
from repro.core import CachePolicyEngine, make_engine
from repro.core.characterize import attention_op
from repro.models import build_model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    ttft_s: float | None = None   # submit -> first token wall time
    submit_t: float | None = None


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits[:, -1], axis=-1)


def _pad_bucket(n: int, cap: int) -> int:
    """Round a prefill width up to a power of two (>= 8) so the number of
    distinct prefill compilations is O(log max_len), not O(#prompt-lens)."""
    b = 8
    while b < n:
        b *= 2
    return min(b, cap)


class ServeEngine:
    """Continuous-batching engine over a fixed pool of request slots.

    ``run(requests)`` (or ``submit`` + ``drain``) pushes requests through a
    queue: free slots are prefilled (ragged, right-padded), live slots
    decode in device-resident chunks, finished slots free at chunk
    boundaries and are immediately re-admitted from the queue.
    """

    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_len: int, extras: dict[str, Any] | None = None,
                 policy_engine: CachePolicyEngine | None = None,
                 chunk_size: int = 8):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.chunk_size = max(1, chunk_size)
        self.extras = extras or {}
        # Capacity-based MoE dispatch lets right-pad/parked garbage tokens
        # compete with valid tokens for expert capacity (silent drops);
        # serving requires the per-token dense dispatch (DESIGN.md §5.1).
        assert not cfg.n_experts or cfg.moe_dispatch == "dense", (
            "ServeEngine requires moe_dispatch='dense' (ragged slots would "
            "let padding contend for expert capacity under 'sorted')"
        )
        self.policy = policy_engine or make_engine()
        self.kv_residency = self.policy.kv_policy(self._kv_bytes_per_layer())
        # Decode-attention plan, memoized in the policy engine's PlanCache:
        # one lattice search + allocation per serve process, a cache hit for
        # every subsequent admission wave (re-plans are the admission-time
        # hot path).
        self.decode_plan = self._plan_decode()
        self.cache = self.model.init_cache(
            params, batch=batch_slots, max_len=max_len, **self.extras
        )
        self._reset_slots = self.model.reset_slots
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(1, 4, 5))
        self._decode_chunk = jax.jit(self._chunk_fn, donate_argnums=(1, 2, 3))
        # Device-resident per-slot loop state: last sampled token and the
        # remaining token budget (0 == slot parked/free).
        self.cur_tok = jnp.zeros((batch_slots,), jnp.int32)
        self.remaining = jnp.zeros((batch_slots,), jnp.int32)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.queue: collections.deque[Request] = collections.deque()
        self.stats = {
            "host_syncs": 0,          # total device->host barriers
            "decode_syncs": 0,        # one per decode chunk
            "decode_tokens": 0,       # tokens emitted by decode chunks
            "prefill_tokens": 0,      # first tokens emitted by prefill
            "chunks": 0,
            "admission_waves": 0,
        }

    # -- policy ------------------------------------------------------------

    def _kv_bytes_per_layer(self) -> int:
        kv_heads = max(1, self.cfg.n_kv_heads)
        return (2 * self.slots * self.max_len * kv_heads
                * self.cfg.head_dim_ * hw.dtype_bytes(self.cfg.dtype))

    def _plan_decode(self):
        if not (self.cfg.n_heads and self.cfg.head_dim_):
            return None
        return self.policy.plan_op(attention_op(
            self.slots, self.cfg.n_heads, max(1, self.cfg.n_kv_heads),
            1, self.max_len, self.cfg.head_dim_, causal=False,
            name="serve_decode",
        ))

    def policy_report(self) -> dict:
        """Serving-side policy decisions (DESIGN.md §5) + planner counters."""
        report = {
            "kv_bytes_per_layer": self._kv_bytes_per_layer(),
            "kv_residency": self.kv_residency.value,
            "plan_cache": self.policy.plan_stats(),
        }
        if self.decode_plan is not None:
            report["decode_attention"] = {
                "assignment": {
                    k: v.value for k, v in self.decode_plan.assignment.items()
                },
                "vmem_bytes": self.decode_plan.vmem_bytes,
                "grid_order": list(self.decode_plan.grid_order),
            }
        return report

    def serve_stats(self) -> dict:
        """Host-sync accounting for the decode loop."""
        out = dict(self.stats)
        total = out["decode_tokens"] + out["prefill_tokens"]
        out["host_syncs_per_token"] = (
            out["host_syncs"] / total if total else 0.0
        )
        out["decode_syncs_per_token"] = (
            out["decode_syncs"] / out["decode_tokens"]
            if out["decode_tokens"] else 0.0
        )
        return out

    # -- device-side step functions (jitted once) --------------------------

    def _prefill_fn(self, params, cache, tokens, seg_lens, cur_tok,
                    remaining, new_remaining):
        """Ragged admission prefill: reset re-admitted slots, prefill their
        prompts (seg_lens == 0 parks continuing slots), sample each admitted
        slot's first token on device."""
        admitted = seg_lens > 0
        if self._reset_slots is not None:
            cache = self._reset_slots(cache, admitted)
        logits, cache = self.model.prefill(
            params, cache, tokens, seg_lens=seg_lens
        )
        nxt = greedy_sample(logits).astype(jnp.int32)
        cur_tok = jnp.where(admitted, nxt, cur_tok)
        remaining = jnp.where(admitted, new_remaining, remaining)
        return cache, cur_tok, remaining, nxt

    def _chunk_fn(self, params, cache, cur_tok, remaining):
        """Decode ``chunk_size`` tokens per slot in one dispatch: scan of
        single-token steps with on-device greedy sampling; slots whose
        budget hits zero park (seg_lens == 0 -> state untouched)."""
        def step(carry, _):
            cache, tok, rem = carry
            active = rem > 0
            logits, cache = self.model.decode_step(
                params, cache, tok[:, None],
                seg_lens=active.astype(jnp.int32),
            )
            nxt = greedy_sample(logits).astype(jnp.int32)
            tok = jnp.where(active, nxt, tok)
            rem = jnp.where(active, rem - 1, rem)
            return (cache, tok, rem), (tok, active)

        (cache, tok, rem), (toks, actives) = jax.lax.scan(
            step, (cache, cur_tok, remaining), None, length=self.chunk_size
        )
        return cache, tok, rem, toks, actives

    # -- host-side scheduling ----------------------------------------------

    def submit(self, requests: list[Request]) -> None:
        for r in requests:
            assert len(r.prompt) > 0, (
                "empty prompt: seg_lens==0 marks a parked slot, so a "
                "zero-length admission would never start decoding"
            )
            need = len(r.prompt) + max(r.max_new_tokens - 1, 0)
            assert need <= self.max_len, (
                f"request needs {need} cache positions, max_len={self.max_len}"
            )
            r.submit_t = time.perf_counter()
            self.queue.append(r)

    def _live(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slot_req) if r is not None]

    def _finish(self, r: Request) -> None:
        r.done = True
        self.slot_req[r.slot] = None

    def _admit_wave(self) -> None:
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        take = min(len(free), len(self.queue))
        if take == 0:
            return
        wave = [self.queue.popleft() for _ in range(take)]
        pad = _pad_bucket(max(len(r.prompt) for r in wave), self.max_len)
        toks = np.zeros((self.slots, pad), np.int32)
        seg = np.zeros((self.slots,), np.int32)
        new_rem = np.zeros((self.slots,), np.int32)
        for slot, r in zip(free, wave):
            n = len(r.prompt)
            toks[slot, :n] = r.prompt          # right-pad; scatter drops tail
            seg[slot] = n
            new_rem[slot] = max(r.max_new_tokens - 1, 0)
            r.slot = slot
            self.slot_req[slot] = r
        # Admission consults the policy engine: KV residency for the current
        # occupancy and the (PlanCache-memoized) decode-attention plan.
        self.decode_plan = self._plan_decode()
        self.cache, self.cur_tok, self.remaining, nxt = self._prefill(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(seg),
            self.cur_tok, self.remaining, jnp.asarray(new_rem),
        )
        first = np.asarray(nxt)                # host sync: 1 per wave
        self.stats["host_syncs"] += 1
        self.stats["admission_waves"] += 1
        now = time.perf_counter()
        for r in wave:
            r.generated.append(int(first[r.slot]))
            self.stats["prefill_tokens"] += 1
            if r.ttft_s is None and r.submit_t is not None:
                r.ttft_s = now - r.submit_t
            if len(r.generated) >= r.max_new_tokens:
                self._finish(r)

    def _run_chunk(self) -> None:
        self.cache, self.cur_tok, self.remaining, toks, actives = (
            self._decode_chunk(
                self.params, self.cache, self.cur_tok, self.remaining
            )
        )
        t_np, a_np = jax.device_get((toks, actives))   # host sync: 1 per chunk
        self.stats["host_syncs"] += 1
        self.stats["decode_syncs"] += 1
        self.stats["chunks"] += 1
        for slot, r in self._live():
            emitted = a_np[:, slot]
            for i in np.nonzero(emitted)[0]:
                r.generated.append(int(t_np[i, slot]))
            self.stats["decode_tokens"] += int(emitted.sum())
            if len(r.generated) >= r.max_new_tokens:
                self._finish(r)

    def drain(self) -> None:
        """Run admission + chunked decode until queue and slots are empty."""
        while self.queue or self.slot_req.count(None) < self.slots:
            self._admit_wave()
            if self.slot_req.count(None) < self.slots:
                self._run_chunk()

    def run(self, requests: list[Request]) -> list[Request]:
        self.submit(requests)
        self.drain()
        return requests
