"""Device-resident continuous-batching serve engine.

The memory-policy engine drives two serving decisions (DESIGN.md §5):

* KV residency per layer (`engine.kv_policy`): decode KV is a zero-reuse
  stream (the paper's throughput-sensitive class) — STREAM via the
  split-KV decode kernel; fixed-source caches (whisper enc K/V, vision
  patch K/V) are RESIDENT (reused every step, fetched once).
* Split-count planning for flash-decoding (`kernels.decode_attention.ops`),
  memoized in the PlanCache and re-consulted at every admission wave.

The serving loop itself is built to run at hardware speed (the inference
loop, not the policy search, is the artifact that must be fast):

* **Chunked on-device decode** — one `lax.scan` dispatch decodes
  ``chunk_size`` tokens for every slot with on-device sampling and
  per-slot done flags; the host syncs once per *chunk* (to read the
  emitted tokens), not once per token.
* **Ragged slots** — the cache carries a per-slot ``lengths`` cursor
  vector, so slots free and re-admit independently: finished slots park
  (``seg_lens == 0`` leaves their state untouched) while live slots keep
  decoding, and freed slots take new prompts mid-stream via a ragged
  right-padded prefill (`models.common.append_kv` drops padding on the
  scatter, so mixed-length prompts never cross-contaminate).
* **Donated buffers** — the cache (and the per-slot token/budget vectors)
  are donated to each dispatch, so KV updates are in-place on device.
* **Paged KV pool** (``cfg.cache_layout == "paged"``, DESIGN.md §5.2) —
  K/V capacity is pooled into fixed-size pages shared across slots; a
  host-side free-list (`PageAllocator`) assigns each admitted request
  exactly the pages its worst case needs and admission gates on free
  pages, so a pool smaller than ``slots x max_len`` serves mixed
  long/short traffic while staying bit-identical to the contiguous ring.
* **Prefix sharing** (``cfg.prefix_sharing``, DESIGN.md §5.4) — a
  host-side radix trie over full prompt pages (`serve.prefix`) lets
  admission attach a new request to already-resident prefix pages: the
  slot's page table aliases the shared pages (refcounted in the
  `PageAllocator`; a page frees only at refcount zero) and prefill runs
  only over the unshared suffix at a page-aligned nonzero cursor.
  Divergence is copy-on-write by allocation — the first divergent page is
  always a private page, shared pages are never written.  Requires the
  paged layout and a pure-KV decoder family (dense/moe); other engines
  fall back to unshared bookkeeping.
* **Speculative decode** (``cfg.spec_k > 0``, DESIGN.md §5.3) — an
  on-device n-gram proposer (`serve.draft`) drafts ``spec_k`` tokens per
  slot from the slot's own history; ONE multi-token verify dispatch
  scores every draft position via the model's ragged ``prefill`` path,
  accepts each slot's matching prefix (1..spec_k+1 tokens per round) and
  rolls the rejected suffix back — a per-slot cursor rewind for KV
  families, a seg-gated replay for recurrent state (mamba2/zamba2).
  Output-identical to the non-speculative path under every sampling mode
  because acceptance replays the exact `(seed, token-index)`-keyed
  sampler decision the sequential loop would have made.
* **Sampling** (`serve.sampling.Sampler`) — greedy / temperature / top-k
  / top-p on device inside the chunk scan; per-request seeds fold into
  per-token keys so streams are independent of slot assignment order.
* **Request lifecycle** (DESIGN.md §5.5) — requests move through
  queued -> resident -> {finished, preempted -> re-queued, cancelled,
  expired}.  When paged admission is gated on an empty free list the
  engine *preempts* the youngest resident: its pages are released
  refcount-aware (prefix-shared pages are only dereferenced, never freed
  under sharers), its emitted tokens are already host-side, and it
  re-enqueues for a recompute-prefill over prompt + emitted — the
  `(seed, token index)` sampler keys make the restored stream
  bit-identical to the uninterrupted one by construction.  `cancel()`
  and per-request deadlines are swept between decode chunks (slots,
  pages and trie refs free mid-stream), submission is bounded with
  reject-with-reason backpressure (`AdmissionReject`), and
  `check_invariants()` + `serve.chaos` fault injection prove the
  allocator/trie/engine state machine survives all of it.
* **Crash safety + KV integrity** (DESIGN.md §5.6) — ``snapshot(path)``
  serializes host-side truth only (requests, tokens, seeds, refcounts,
  quarantine) and ``restore(path)`` rebuilds all device KV bit-identically
  through ordinary re-admission; an optional fsync'd request journal
  (``journal_path``) replays submissions/terminations past the snapshot
  after an unplanned kill.  With ``cfg.kv_integrity`` the engine stamps
  per-page fingerprints at chunk boundaries and ``verify_pages()``
  detects silent corruption, quarantines the page in the allocator
  (refcount-aware: every prefix sharer is repaired) and self-heals the
  mapped slots by recompute-restore.  ``drain()`` carries a livelock
  watchdog (``NoProgressError``) so a starved pool fails loudly.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import time
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import hw
from repro.configs.base import ModelConfig
from repro.core import CachePolicyEngine, make_engine
from repro.core.characterize import attention_op
from repro.models import build_model
from repro.models.common import paged_kv_spec
from repro.serve import snapshot as snap
from repro.serve.adaptive import AdaptivePolicy
from repro.serve.alloc import PageAllocator  # noqa: F401  (re-export: the
# allocator lives in serve.alloc since the chaos wrapper subclasses it;
# property tests and older call sites import it from there)
from repro.serve.chaos import ChaosAllocator, ChaosCrash
from repro.serve.draft import ngram_propose
from repro.serve.prefix import PrefixIndex
from repro.serve.snapshot import SnapshotError  # noqa: F401  (re-export:
# engine callers catch restore failures without importing serve.snapshot)
from repro.serve.sampling import (  # noqa: F401  (greedy_sample re-export)
    Sampler,
    greedy_sample,
    sample_keys,
)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 16
    seed: int | None = None       # per-request sampling seed (None -> 0):
                                  # streams depend on (seed, token index)
                                  # only, never on slot assignment order
    id: str | None = None         # cancellation handle; auto-assigned at
                                  # submit when None ("req-<n>")
    deadline_s: float | None = None       # submit -> finish SLO; a resident
                                          # request past it is expired
                                          # mid-stream at the next sweep
    max_queue_wait_s: float | None = None  # submit -> admission bound
                                           # (queued requests only)
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False            # terminal: finished, cancelled or expired
                                  # (``status`` says which)
    status: str = "new"           # new -> queued -> resident -> {finished,
                                  # preempted (re-queued), cancelled, expired}
    cancel_requested: bool = False  # set by engine.cancel(); honored at the
                                    # next lifecycle sweep (chunk boundary)
    preempted_n: int = 0          # times evicted mid-stream; natural
                                  # preemption only ever victimizes
                                  # never-preempted residents, so it is
                                  # bounded by the request count
    admit_seq: int = -1           # admission order; the preemption victim
                                  # is the youngest (max) resident
    prefix_tokens: int = 0        # prompt tokens attached from shared pages
                                  # at admission (0 = fully prefilled)
    ttft_s: float | None = None        # admission -> first token (prefill)
    queue_wait_s: float | None = None  # submit -> FIRST admission (queueing
                                       # only; preemption re-queues don't
                                       # overwrite it)
    submit_t: float | None = None
    admit_t: float | None = None


class AdmissionReject(ValueError):
    """A request the engine refuses to enqueue, with a machine-readable
    ``reason``: backpressure ("queue_full") or a request that could never
    be served ("pool_too_small", "max_len", "empty_prompt", "zero_budget",
    "duplicate_id").  Raised by ``submit`` BEFORE anything in the batch is
    enqueued, so a rejection never leaves the batch half-submitted."""

    def __init__(self, reason: str, message: str):
        super().__init__(message)
        self.reason = reason


class NoProgressError(RuntimeError):
    """``drain()`` livelock watchdog (DESIGN.md §5.6): raised after
    ``no_progress_limit`` consecutive steps in which work remained but
    zero tokens were emitted and zero lifecycle transitions happened —
    e.g. a queue gated behind a fully quarantined pool, or pathological
    injected alloc-failure rates.  Failing loudly beats spinning forever;
    the message carries the gating state so the operator can tell a
    shrunk pool from a chaos knob."""


def _pad_bucket(n: int, cap: int) -> int:
    """Round a prefill width up to a power of two (>= 8) so the number of
    distinct prefill compilations is O(log max_len), not O(#prompt-lens)."""
    b = 8
    while b < n:
        b *= 2
    return min(b, cap)


class ServeEngine:
    """Continuous-batching engine over a fixed pool of request slots.

    ``run(requests)`` (or ``submit`` + ``drain``) pushes requests through a
    queue: free slots are prefilled (ragged, right-padded), live slots
    decode in device-resident chunks — plain chunked decode, or draft/
    verify/rollback rounds when ``cfg.spec_k > 0`` — finished slots free at
    chunk boundaries and are immediately re-admitted from the queue.
    """

    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_len: int, extras: dict[str, Any] | None = None,
                 policy_engine: CachePolicyEngine | None = None,
                 chunk_size: int = 8, n_pages: int | None = None,
                 max_queue: int | None = None,
                 journal_path: str | None = None,
                 no_progress_limit: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.chunk_size = max(1, chunk_size)
        self.extras = extras or {}
        self.sampler = Sampler.from_config(cfg)
        # Speculative decode (DESIGN.md §5.3): k drafts verified per round,
        # emitting 1..k+1 tokens; a chunk packs enough rounds to target
        # ~chunk_size tokens per host sync at full acceptance.
        self.spec = cfg.spec_k > 0
        self.spec_k = cfg.spec_k
        self.spec_ngram = cfg.spec_ngram
        self.spec_rounds = max(1, self.chunk_size // (cfg.spec_k + 1))
        # Paged KV layout (DESIGN.md §5.2): K/V capacity is pooled into
        # fixed-size pages shared across slots; the host-side free-list
        # assigns each admitted request exactly the pages its worst case
        # needs (prompt + budget), so a pool smaller than slots x max_len
        # serves mixed long/short traffic.  ``n_pages`` None sizes the pool
        # to full contiguous capacity.
        self.paged = cfg.cache_layout == "paged"
        cache_kwargs = dict(self.extras)
        if self.paged:
            psz = cfg.kv_page_size
            assert max_len % psz == 0, (
                f"max_len={max_len} must be a multiple of kv_page_size={psz} "
                "so the gathered page view is bit-identical to the "
                "contiguous ring"
            )
            self.page_size = psz
            self.pages_per_slot, self.n_pages = paged_kv_spec(
                batch_slots, max_len, psz, n_pages
            )
            self.allocator: PageAllocator = self._make_allocator()
            self.page_table = np.full(
                (batch_slots, self.pages_per_slot), -1, np.int32
            )
            self._slot_pages: list[list[int]] = [[] for _ in range(batch_slots)]
            cache_kwargs["n_pages"] = self.n_pages
        self._cache_kwargs = cache_kwargs
        # Capacity-based MoE dispatch lets right-pad/parked garbage tokens
        # compete with valid tokens for expert capacity (silent drops);
        # serving requires the per-token dense dispatch (DESIGN.md §5.1).
        assert not cfg.n_experts or cfg.moe_dispatch == "dense", (
            "ServeEngine requires moe_dispatch='dense' (ragged slots would "
            "let padding contend for expert capacity under 'sorted')"
        )
        self.policy = policy_engine or make_engine()
        self.kv_residency = self.policy.kv_policy(self._kv_bytes_per_layer())
        # Decode-attention plan, memoized in the policy engine's PlanCache:
        # one lattice search + allocation per serve process, a cache hit for
        # every subsequent admission wave (re-plans are the admission-time
        # hot path).
        self.decode_plan = self._plan_decode()
        # Paged split-KV decode kernel (DESIGN.md §5.2): the engine's
        # decode plan decides the kernel's split-K parallelism, and jitted
        # model traces need that count static — so it is baked into the
        # config the model is built with.  cfg.decode_splits == 0 means
        # "let the decode plan decide"; an explicit count wins.
        self.decode_splits = self._decode_kernel_splits()
        if cfg.decode_kernel != "xla" and cfg.decode_splits == 0:
            cfg = dataclasses.replace(cfg, decode_splits=self.decode_splits)
            self.cfg = cfg
        self.model = build_model(cfg)
        self.cache = self.model.init_cache(
            params, batch=batch_slots, max_len=max_len, **self._cache_kwargs
        )
        if self.paged and "pages" not in self.cache:
            # Cache family with no KV to page (mamba2's decode state is
            # O(1) per slot): fall back to contiguous bookkeeping rather
            # than gating admission on a phantom page pool.
            self.paged = False
            self.kv_residency = self.policy.kv_policy(
                self._kv_bytes_per_layer()
            )
        # Prefix sharing (DESIGN.md §5.4) rides the paged pool: the trie
        # indexes resident full prompt pages and admission attaches new
        # requests to them.  Pure-KV decoder families only — recurrent
        # state (mamba2/zamba2 SSM/conv) is not page-shareable, and
        # encdec/vlm prefix KV depends on per-slot source context (frames/
        # vision tokens), so those fall back to unshared bookkeeping.
        self.prefix_sharing = (
            bool(cfg.prefix_sharing) and self.paged
            and cfg.family in ("dense", "moe")
        )
        self.prefix = (
            PrefixIndex(self.page_size) if self.prefix_sharing else None
        )
        # Adaptive serve-tier cache policy (DESIGN.md §5.7): runtime
        # counters drive warm prefix retention (bounded by
        # cfg.warm_pages), cost-aware preemption victims, and per-class
        # policy re-planning through core.sweep's exact lattice argmin.
        # Placement-only by construction — the static path pays nothing.
        # The warm tier needs re-attachable page KV (paged + prefix
        # sharing); other engines keep victim costing + replans only.
        self.adaptive: AdaptivePolicy | None = None
        if cfg.adaptive:
            self.adaptive = AdaptivePolicy(
                warm_pages=(cfg.warm_pages
                            if self.prefix is not None else 0),
                replan_every=cfg.adaptive_replan_every,
                page_size=self.page_size if self.paged else 1,
                spec_k=self.spec_k if self.spec else 0,
            )
        self._warm_tier = (
            self.adaptive is not None and self.adaptive.warm_pages > 0
            and self.prefix is not None
        )
        # Recurrent state (SSM/conv) has no per-position validity mask, so
        # the speculative rollback cannot be a cursor rewind: those
        # families re-run the verify block from the pre-verify cache with
        # ``seg_lens = accepted`` (the dt/conv gating makes the replay
        # consume exactly the accepted prefix).  KV-only families rewind.
        self._spec_replay = "ssm" in self.cache or "conv" in self.cache
        self._reset_slots = self.model.reset_slots
        self._prefill = jax.jit(
            self._prefill_fn, donate_argnums=(1, 6, 7, 10, 11, 12, 14)
        )
        self._decode_chunk = jax.jit(
            self._spec_chunk_fn if self.spec else self._chunk_fn,
            donate_argnums=(1, 2, 3, 4, 5, 6),
        )
        # Device-resident per-slot loop state: last sampled token, remaining
        # token budget (0 == slot parked/free), per-request token index and
        # sampling seed, and the token history the n-gram proposer mines
        # (prompt + emitted, including the not-yet-consumed current token —
        # at most max_len + 1 entries since prompt + budget <= max_len + 1).
        self.cur_tok = jnp.zeros((batch_slots,), jnp.int32)
        self.remaining = jnp.zeros((batch_slots,), jnp.int32)
        self.tok_idx = jnp.zeros((batch_slots,), jnp.int32)
        self.seeds = jnp.zeros((batch_slots,), jnp.int32)
        self.hist = jnp.zeros((batch_slots, max_len + 1), jnp.int32)
        self.hist_len = jnp.zeros((batch_slots,), jnp.int32)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.queue: collections.deque[Request] = collections.deque()
        # Request lifecycle (DESIGN.md §5.5).
        self.preemption = bool(cfg.preemption)
        self.max_queue = max_queue          # None = unbounded submission
        self._by_id: dict[str, Request] = {}   # cancellation handles
        self._next_id = 0
        self._admit_seq = 0                 # victim choice: youngest = max
        # Slots vacated mid-stream (preempt/cancel/expire) whose device
        # budget must be zeroed before the next decode chunk — a stale
        # ``remaining`` would decode into pages now owned by others.
        self._dirty_slots: set[int] = set()
        if cfg.chaos_preempt_p > 0.0:
            assert cfg.chaos_preempt_p < 1.0, (
                "chaos_preempt_p must be < 1.0 or the loop preempts forever"
            )
        self._chaos_rng = (
            np.random.default_rng(cfg.chaos_seed)
            if cfg.chaos_preempt_p > 0.0 else None
        )
        # Any chaos knob arms the per-wave invariant check: fault paths
        # must leave allocator/trie/page-table state exactly conserved.
        self._chaos = (
            cfg.chaos_preempt_p > 0.0
            or (self.paged and (cfg.chaos_alloc_fail_p > 0.0
                                or cfg.chaos_share_fail_p > 0.0
                                or cfg.chaos_corrupt_p > 0.0))
        )
        # Strict mode (DESIGN.md §5.6) arms the same per-wave sweep with
        # no fault injection — CI tier-1 sets the env var so every test
        # run audits conservation, not just the chaos legs.
        self._strict = cfg.strict_invariants or (
            os.environ.get("REPRO_STRICT_INVARIANTS", "") not in ("", "0")
        )
        # KV page integrity (DESIGN.md §5.6): fingerprints stamped at
        # chunk boundaries over pages sealed below their slot's
        # host-computed cursor; verify_pages() sweeps them every step.
        self.integrity = self.paged and (
            cfg.kv_integrity or cfg.chaos_corrupt_p > 0.0
        )
        self._page_fp: dict[int, int] = {}
        self._corrupt_rng = (
            np.random.default_rng(cfg.chaos_seed + 0x5EED)
            if self.paged and cfg.chaos_corrupt_p > 0.0 else None
        )
        # Crash safety (DESIGN.md §5.6): optional fsync'd request journal;
        # _replaying suppresses journal writes while restore re-enqueues.
        self.journal_path = journal_path
        self.journal = (
            snap.RequestJournal(journal_path)
            if journal_path is not None else None
        )
        self._replaying = False
        self.no_progress_limit = max(1, no_progress_limit)
        self.stats = {
            "host_syncs": 0,          # total device->host barriers
            "decode_syncs": 0,        # one per decode chunk
            "decode_tokens": 0,       # tokens emitted by decode chunks
            "prefill_tokens": 0,      # first tokens emitted by prefill
            "chunks": 0,
            "admission_waves": 0,
            "spec_rounds": 0,         # active draft/verify rounds
            "draft_proposed": 0,      # spec_k per active round
            "draft_accepted": 0,      # matching draft prefix per round
            "admitted_fresh": 0,      # first-time admissions (no tokens yet)
            "readmitted": 0,          # preemption-restore re-admissions
            "prefill_work_tokens": 0,  # suffix tokens actually prefilled
            "spec_tokens": 0,         # tokens emitted by draft/verify rounds
            "prefix_hits": 0,         # admissions that attached shared pages
            "prefix_hits_fresh": 0,   # ... the fresh-admission subset
            "prefix_pages_shared": 0,  # shared-page references taken
            "prefix_tokens_shared": 0,  # prompt tokens not re-prefilled
            "warm_retained": 0,       # pages parked in the warm tier
            "warm_reclaimed": 0,      # warm pages returned to the free list
            "warm_hits": 0,           # admissions that revived warm pages
            "warm_tokens_saved": 0,   # prompt tokens attached from warm pages
            "replans": 0,             # adaptive lattice re-plans run
            "peak_pages_held": 0,     # max concurrent pool usage (paged)
            "preempted": 0,           # mid-stream evictions (incl. forced)
            "preempted_forced": 0,    # chaos-forced subset
            "recompute_tokens": 0,    # emitted tokens re-prefilled at restore
            "cancelled": 0,           # terminal via engine.cancel()
            "expired": 0,             # terminal via deadline/queue-wait
            "rejected": 0,            # submissions refused (AdmissionReject)
            "deadline_total": 0,      # deadlined requests reaching terminal
            "deadline_met": 0,        # ... that finished within deadline
            "invariant_checks": 0,    # check_invariants() sweeps run
            "integrity_sweeps": 0,    # fingerprint stamp+verify passes
            "corrupted_pages": 0,     # fingerprint mismatches detected
            "healed_requests": 0,     # slots recompute-restored after
                                      # mapping a corrupted page
            "injected_corruptions": 0,  # chaos_corrupt_p bit flips landed
            "snapshots": 0,           # snapshot() calls
            "restores": 0,            # restore() calls completed
        }

    def _make_allocator(self) -> PageAllocator:
        """Fresh pool allocator; the chaos wrapper when any injection knob
        is armed (DESIGN.md §5.5) — with cfg.chaos_alloc_fail_p /
        chaos_share_fail_p > 0 the pool refuses otherwise-satisfiable
        calls with seeded probability, driving the same gating/preemption
        paths genuine exhaustion would.  Also the restore path's reset
        (``_hard_reset``), so a restored engine re-arms identically."""
        cfg = self.cfg
        warm = cfg.warm_pages if cfg.adaptive else 0
        if cfg.chaos_alloc_fail_p > 0.0 or cfg.chaos_share_fail_p > 0.0:
            assert cfg.chaos_alloc_fail_p < 1.0, (
                "chaos_alloc_fail_p must be < 1.0 or admission can "
                "never succeed"
            )
            assert cfg.chaos_share_fail_p < 1.0, (
                "chaos_share_fail_p must be < 1.0 or attaching heads can "
                "never admit"
            )
            return ChaosAllocator(
                self.n_pages, cfg.chaos_alloc_fail_p, cfg.chaos_seed,
                share_fail_p=cfg.chaos_share_fail_p, warm_budget=warm,
            )
        return PageAllocator(self.n_pages, warm_budget=warm)

    # -- policy ------------------------------------------------------------

    @property
    def free_pages(self) -> list[int]:
        """Free-list view (paged only) — delegated to the PageAllocator."""
        return self.allocator.free_pages

    def _kv_bytes_per_layer(self) -> int:
        """Real per-layer KV footprint, so residency planning sees the bytes
        actually allocated: the paged pool's n_pages x page_size positions,
        not the contiguous worst case of slots x max_len."""
        kv_heads = max(1, self.cfg.n_kv_heads)
        positions = (self.n_pages * self.page_size if self.paged
                     else self.slots * self.max_len)
        return (2 * positions * kv_heads
                * self.cfg.head_dim_ * hw.dtype_bytes(self.cfg.dtype))

    def _plan_decode(self):
        if not (self.cfg.n_heads and self.cfg.head_dim_):
            return None
        return self.policy.plan_op(attention_op(
            self.slots, self.cfg.n_heads, max(1, self.cfg.n_kv_heads),
            1, self.max_len, self.cfg.head_dim_, causal=False,
            name="serve_decode",
        ))

    def _decode_kernel_splits(self) -> int:
        """Split-K parallelism for the Pallas decode kernels, planned from
        ``decode_plan`` (one split per engine-planned KV block) unless the
        config pins an explicit count.  Paged engines split over logical
        pages (the kernel's KV block is one page); contiguous ones over
        the ring."""
        from repro.kernels.decode_attention.ops import plan_splits

        if self.cfg.decode_splits:
            return self.cfg.decode_splits
        if self.paged:
            s, bkv = self.pages_per_slot * self.page_size, self.page_size
        else:
            s, bkv = self.max_len, min(512, self.max_len)
        return plan_splits(s, bkv, plan=self.decode_plan)

    def policy_report(self) -> dict:
        """Serving-side policy decisions (DESIGN.md §5) + planner counters."""
        report = {
            "kv_bytes_per_layer": self._kv_bytes_per_layer(),
            "kv_residency": self.kv_residency.value,
            # Effective layout: "contiguous" when a paged request met a
            # cache family with no KV to page (see __init__ fallback).
            "cache_layout": "paged" if self.paged else "contiguous",
            "sampling": self.sampler.mode,
            "plan_cache": self.policy.plan_stats(),
        }
        if self.spec:
            report["speculative"] = {
                "spec_k": self.spec_k,
                "spec_ngram": self.spec_ngram,
                "rounds_per_chunk": self.spec_rounds,
                "rollback": "replay" if self._spec_replay else "rewind",
            }
        if self.paged:
            report["paged_kv"] = {
                "n_pages": self.n_pages,
                "page_size": self.page_size,
                "free_pages": self.allocator.free_count(),
                "pool_positions": self.n_pages * self.page_size,
                "contiguous_positions": self.slots * self.max_len,
            }
        # "requested but not enabled" is the graceful-fallback signal
        # (contiguous layout, KV-free or source-conditioned families).
        report["prefix_sharing"] = {
            "requested": bool(self.cfg.prefix_sharing),
            "enabled": self.prefix_sharing,
        }
        if self.prefix is not None:
            report["prefix_sharing"].update({
                "trie_nodes": len(self.prefix),
                "resident_prefix_tokens": self.prefix.resident_tokens(),
            })
        # Adaptive serve-tier policy (DESIGN.md §5.7) — a NEW top-level
        # section so the schema-stable "lifecycle"/"integrity" blocks
        # stay byte-compatible for their pinned consumers.
        report["adaptive"] = {"enabled": self.adaptive is not None}
        if self.adaptive is not None:
            report["adaptive"].update({
                "warm_tier": self._warm_tier,
                "warm_pages_now": (
                    self.allocator.warm_count() if self.paged else 0
                ),
                **{k: self.stats[k] for k in (
                    "warm_retained", "warm_reclaimed", "warm_hits",
                    "warm_tokens_saved", "replans",
                )},
                **self.adaptive.report(),
            })
        # Lifecycle / robustness (DESIGN.md §5.5).  Schema is stable —
        # benches and CI parse it; tests pin the full key set.
        report["lifecycle"] = {
            "preemption_enabled": self.preemption,
            "max_queue": self.max_queue,
            "preempted": self.stats["preempted"],
            "preempted_forced": self.stats["preempted_forced"],
            "recompute_tokens": self.stats["recompute_tokens"],
            "cancelled": self.stats["cancelled"],
            "expired": self.stats["expired"],
            "rejected": self.stats["rejected"],
            "goodput_under_deadline": self._goodput(),
            "chaos": {
                "alloc_fail_p": self.cfg.chaos_alloc_fail_p,
                "preempt_p": self.cfg.chaos_preempt_p,
                "share_fail_p": self.cfg.chaos_share_fail_p,
                "corrupt_p": self.cfg.chaos_corrupt_p,
                "crash_after_wave": self.cfg.chaos_crash_after_wave,
                "seed": self.cfg.chaos_seed,
                "injected_alloc_failures": (
                    self.allocator.injected_failures
                    if self.paged
                    and isinstance(self.allocator, ChaosAllocator) else 0
                ),
                "injected_share_failures": (
                    self.allocator.injected_share_failures
                    if self.paged
                    and isinstance(self.allocator, ChaosAllocator) else 0
                ),
                "injected_corruptions": self.stats["injected_corruptions"],
            },
        }
        # Crash safety + KV integrity (DESIGN.md §5.6) — same stability
        # contract as "lifecycle": benches/CI parse it, tests pin keys.
        report["integrity"] = {
            "enabled": self.integrity,
            "strict_invariants": self._strict,
            "journal": self.journal_path is not None,
            "stamped_pages": len(self._page_fp),
            "quarantined_pages": (
                len(self.allocator.quarantined_pages)
                + len(self.allocator.doomed_pages)
                if self.paged else 0
            ),
            "corrupted_pages": self.stats["corrupted_pages"],
            "healed_requests": self.stats["healed_requests"],
            "snapshots": self.stats["snapshots"],
            "restores": self.stats["restores"],
        }
        if self.decode_plan is not None:
            report["decode_attention"] = {
                "assignment": {
                    k: v.value for k, v in self.decode_plan.assignment.items()
                },
                "vmem_bytes": self.decode_plan.vmem_bytes,
                "grid_order": list(self.decode_plan.grid_order),
                # Which decode-step kernel the model was traced with, and
                # the split-K count baked from decode_plan (== grid
                # parallelism of the Pallas kernels when != "xla").
                "kernel": self.cfg.decode_kernel,
                "planned_splits": self.decode_splits,
                "kernel_bkv": (self.page_size if self.paged
                               else min(512, self.max_len)),
            }
        return report

    def serve_stats(self) -> dict:
        """Host-sync + speculative-acceptance accounting for the loop."""
        out = dict(self.stats)
        total = out["decode_tokens"] + out["prefill_tokens"]
        out["host_syncs_per_token"] = (
            out["host_syncs"] / total if total else 0.0
        )
        out["decode_syncs_per_token"] = (
            out["decode_syncs"] / out["decode_tokens"]
            if out["decode_tokens"] else 0.0
        )
        out["spec_acceptance_rate"] = (
            out["draft_accepted"] / out["draft_proposed"]
            if out["draft_proposed"] else 0.0
        )
        # Spec-round-emitted tokens only: decode_tokens also counts plain
        # chunks (spec disabled mid-run, non-spec phases), which would
        # inflate the per-round figure.
        out["spec_tokens_per_round"] = (
            out["spec_tokens"] / out["spec_rounds"]
            if out["spec_rounds"] else 0.0
        )
        # Hit rate over FRESH admissions: prefill_tokens also counts
        # preemption-restore recompute prefills, which deflated the rate
        # under memory pressure (and a restore re-attach is not a new
        # hit, so the numerator is the fresh subset too).
        out["prefix_hit_rate"] = (
            out["prefix_hits_fresh"] / out["admitted_fresh"]
            if out["admitted_fresh"] else 0.0
        )
        out["goodput_under_deadline"] = self._goodput()
        return out

    def _goodput(self) -> float:
        """Fraction of deadlined requests that reached terminal state
        within their deadline; 1.0 when no request carried one (an
        SLO-free run is vacuously good)."""
        total = self.stats["deadline_total"]
        return self.stats["deadline_met"] / total if total else 1.0

    # -- device-side step functions (jitted once) --------------------------

    def _sample(self, logits, seeds, tok_idx):
        """Sampler dispatch: per-slot keys folded from (request seed, token
        index) — a pure function of the request, so streams are independent
        of slot assignment and batch composition."""
        keys = (sample_keys(seeds, tok_idx)
                if self.sampler.needs_keys else None)
        return self.sampler(logits, keys).astype(jnp.int32)

    def _hist_append(self, hist, positions, tokens):
        """Scatter ``tokens`` into per-slot history at ``positions``;
        out-of-range positions (parked slots pass H) drop."""
        b = hist.shape[0]
        return hist.at[jnp.arange(b)[:, None] if positions.ndim == 2
                       else jnp.arange(b), positions].set(tokens, mode="drop")

    def _prefill_fn(self, params, cache, tokens, seg_lens, start_lens,
                    hist_toks, cur_tok, remaining, new_remaining,
                    new_tok_idx, tok_idx, hist, hist_len, new_seeds, seeds):
        """Ragged admission prefill: reset re-admitted slots, prefill their
        prompts (seg_lens == 0 parks continuing slots), sample each admitted
        slot's first token on device, and (re)seed the slot's history /
        token-index / seed state.

        ``start_lens`` is the per-slot attach cursor: 0 for a full prefill,
        a page-aligned shared-prefix length when the slot rides resident
        prefix pages (DESIGN.md §5.4) — ``tokens`` then holds only the
        unshared suffix, positioned (RoPE and scatter) at start + i.
        ``hist_toks`` always carries the FULL prompt, so the n-gram history
        an attached slot's drafts mine is identical to the unshared
        engine's (the full prompt length is start + seg — no extra arg).

        ``new_tok_idx`` is the stream index of the token this prefill
        samples: 0 for a fresh admission, m for a preempted request being
        restored with m tokens already emitted (its "prompt" is then
        prompt + emitted, and the sampler key for index m reproduces
        exactly the token the uninterrupted run emitted there — the whole
        bit-identical-restore argument, DESIGN.md §5.5)."""
        b, pad = tokens.shape
        fpad = hist_toks.shape[1]
        H = hist.shape[1]
        admitted = seg_lens > 0
        if self._reset_slots is not None:
            cache = self._reset_slots(cache, admitted)
        cache = dict(cache)
        cache["lengths"] = jnp.where(
            admitted, start_lens, cache["lengths"]
        ).astype(jnp.int32)
        logits, cache = self.model.prefill(
            params, cache, tokens, seg_lens=seg_lens
        )
        nxt = self._sample(logits, new_seeds, new_tok_idx)
        cur_tok = jnp.where(admitted, nxt, cur_tok)
        remaining = jnp.where(admitted, new_remaining, remaining)
        seeds = jnp.where(admitted, new_seeds, seeds)
        tok_idx = jnp.where(admitted, new_tok_idx + 1, tok_idx)
        # History: full-prompt rows land at 0..full-1, the first token at
        # full; parked slots redirect to H and drop.
        full_seg = start_lens + seg_lens
        pos = jnp.broadcast_to(jnp.arange(fpad)[None, :], (b, fpad))
        pos = jnp.where(
            admitted[:, None] & (pos < full_seg[:, None]), pos, H
        )
        hist = self._hist_append(hist, pos, hist_toks)
        hist = self._hist_append(
            hist, jnp.where(admitted, full_seg, H), nxt
        )
        hist_len = jnp.where(admitted, full_seg + 1, hist_len)
        return cache, cur_tok, remaining, tok_idx, hist, hist_len, seeds, nxt

    def _chunk_fn(self, params, cache, cur_tok, remaining, tok_idx, hist,
                  hist_len, seeds):
        """Decode ``chunk_size`` tokens per slot in one dispatch: scan of
        single-token steps with on-device sampling; slots whose budget hits
        zero park (seg_lens == 0 -> state untouched).

        Only the speculative path consumes the n-gram history, so this
        (non-spec) chunk passes ``hist``/``hist_len`` through untouched —
        no per-token scatter or carry traffic on the hot loop."""

        def step(carry, _):
            cache, tok, rem, tidx = carry
            active = rem > 0
            logits, cache = self.model.decode_step(
                params, cache, tok[:, None],
                seg_lens=active.astype(jnp.int32),
            )
            nxt = self._sample(logits, seeds, tidx)
            tok = jnp.where(active, nxt, tok)
            tidx = jnp.where(active, tidx + 1, tidx)
            rem = jnp.where(active, rem - 1, rem)
            return (cache, tok, rem, tidx), (tok, active)

        (cache, tok, rem, tidx), (toks, actives) = jax.lax.scan(
            step, (cache, cur_tok, remaining, tok_idx),
            None, length=self.chunk_size,
        )
        return cache, tok, rem, tidx, hist, hist_len, toks, actives

    def _spec_chunk_fn(self, params, cache, cur_tok, remaining, tok_idx,
                       hist, hist_len, seeds):
        """``spec_rounds`` draft/verify/rollback rounds in one dispatch
        (DESIGN.md §5.3).  Each round, per active slot:

        1. *Draft*: ``ngram_propose`` mines the slot's history for spec_k
           draft tokens.
        2. *Verify*: ONE ragged multi-token ``prefill`` over
           ``[cur_tok, d_1..d_k]`` returns logits for every position;
           position j's sampler decision (keyed by token index
           ``tok_idx + j``) is exactly the token the sequential loop would
           emit there, so the target tokens double as the emissions.
        3. *Accept*: the emitted count is ``min(matching prefix + 1,
           remaining)`` — always >= 1 (the sampler's own token at the first
           mismatch), at most spec_k + 1 (all drafts + the bonus token).
        4. *Rollback*: KV families keep the verify-pass cache and rewind
           ``lengths`` to base + accepted (rejected KV is stale-but-masked,
           overwritten as the cursor advances — the ring invariant);
           recurrent families replay the block from the pre-verify cache
           with ``seg_lens = accepted`` (dt/conv gating consumes exactly
           the accepted prefix).
        """
        b = self.slots
        k, k1 = self.spec_k, self.spec_k + 1
        H = hist.shape[1]

        def round_fn(carry, _):
            cache, tok, rem, tidx, hist, hlen = carry
            active = rem > 0
            base_len = cache["lengths"]
            drafts = ngram_propose(hist, hlen, self.spec_ngram, k)
            vt = jnp.concatenate([tok[:, None], drafts], axis=1)  # (b, k1)
            seg_v = jnp.where(active, k1, 0).astype(jnp.int32)
            logits_all, cache_v = self.model.prefill(
                params, cache, vt, seg_lens=seg_v, all_logits=True
            )
            # Target token at position j = sampler decision for token index
            # tidx + j: identical to what sequential decode would sample.
            if self.sampler.needs_keys:
                keys = sample_keys(
                    jnp.broadcast_to(seeds[:, None], (b, k1)).reshape(-1),
                    (tidx[:, None] + jnp.arange(k1)[None, :]).reshape(-1),
                )
            else:
                keys = None
            targets = self.sampler(
                logits_all.reshape(b * k1, -1), keys
            ).astype(jnp.int32).reshape(b, k1)
            match = (drafts == targets[:, :k]).astype(jnp.int32)
            accepted = jnp.sum(jnp.cumprod(match, axis=1), axis=1)   # (b,)
            m = jnp.where(active, jnp.minimum(accepted + 1, rem), 0)
            # Acceptance accounting reflects USABLE drafts only: a slot
            # with rem remaining tokens can consume at most rem - 1 drafts
            # this round, so matches past the budget clip neither count as
            # accepted nor as proposed (they produced no tokens).
            usable = jnp.where(
                active, jnp.minimum(jnp.int32(k), rem - 1), 0
            )
            acc_used = jnp.maximum(m - 1, 0)
            if self._spec_replay:
                # Recurrent rollback: consume exactly the accepted prefix
                # from the pre-verify cache (discard the polluted verify
                # state).  Also rewrites the accepted KV — same bytes.
                _, cache = self.model.prefill(
                    params, cache, vt, seg_lens=m
                )
            else:
                # KV rollback: rejected positions are beyond the rewound
                # cursor — stale-but-masked, overwritten as it advances.
                cache = dict(cache_v)
                cache["lengths"] = base_len + m
            emit = jnp.arange(k1)[None, :] < m[:, None]              # (b, k1)
            hist = self._hist_append(
                hist,
                jnp.where(emit, hlen[:, None] + jnp.arange(k1)[None, :], H),
                targets,
            )
            last = jnp.take_along_axis(
                targets, jnp.clip(m - 1, 0, k)[:, None], axis=1
            )[:, 0]
            tok = jnp.where(active, last, tok)
            hlen = hlen + m
            tidx = tidx + m
            rem = rem - m
            return (cache, tok, rem, tidx, hist, hlen), (
                targets, emit, acc_used, usable, active
            )

        carry = (cache, cur_tok, remaining, tok_idx, hist, hist_len)
        (cache, tok, rem, tidx, hist, hlen), ys = jax.lax.scan(
            round_fn, carry, None, length=self.spec_rounds
        )
        toks, emits, accepts, proposed, actives = ys
        return (cache, tok, rem, tidx, hist, hlen,
                toks, emits, accepts, proposed, actives)

    # -- host-side scheduling ----------------------------------------------

    def _positions_needed(self, r: Request) -> int:
        """Worst-case cache positions: the prompt plus every decoded token
        except the last sampled one (which is never written back)."""
        return len(r.prompt) + r.max_new_tokens - 1

    def _pages_needed(self, r: Request) -> int:
        return -(-self._positions_needed(r) // self.page_size)

    def _effective_prompt(self, r: Request) -> np.ndarray:
        """The token stream admission must prefill: the prompt — plus, for
        a preempted request being restored, every token it had already
        emitted (including the last: its prefill logits are what sample
        the restored stream's next token, see ``_prefill_fn``).  Its
        worst-case positions equal the original's (prompt + budget - 1),
        so ``_positions_needed``/``_pages_needed`` need no restore case."""
        if not r.generated:
            return np.asarray(r.prompt, np.int32)
        return np.concatenate([
            np.asarray(r.prompt, np.int32),
            np.asarray(r.generated, np.int32),
        ])

    def _shared_prefix(self, eff: np.ndarray, chunks) -> tuple[list[int], int]:
        """(pages, tokens): the longest resident full-page prefix of the
        effective prompt ``eff`` (pre-chunked into ``chunks``) this
        request can attach to (DESIGN.md §5.4).

        Capped below the prompt's full-page count so the prompt's last
        token is ALWAYS re-prefilled: the logits seeding decode are
        computed fresh, never assumed resident — a prompt that is exactly
        its shared pages would otherwise have an empty suffix and park
        forever.  The cap also makes the COW case concrete: a prompt
        ending exactly at a shared-page boundary re-materializes that last
        page's K/V into a private page (same bytes, private residency)."""
        pages = self.prefix.lookup(eff, chunks=chunks)
        cap = (len(eff) - 1) // self.page_size
        pages = pages[:cap]
        return pages, len(pages) * self.page_size

    def _reject(self, reason: str, message: str, n: int = 1):
        self.stats["rejected"] += n
        raise AdmissionReject(reason, message)

    def submit(self, requests: list[Request]) -> None:
        # Validate the whole batch before enqueuing any of it, so a
        # rejected request doesn't leave earlier ones half-submitted.
        for r in requests:
            if r.max_new_tokens < 1:
                # Admission always emits the prefill-sampled first token, so
                # a zero budget would generate one token anyway — reject
                # instead of silently over-generating.
                self._reject("zero_budget", (
                    f"max_new_tokens must be >= 1, got {r.max_new_tokens} "
                    "(prefill emits the first token at admission)"
                ))
            if len(r.prompt) == 0:
                self._reject("empty_prompt", (
                    "empty prompt: seg_lens==0 marks a parked slot, so a "
                    "zero-length admission would never start decoding"
                ))
            need = self._positions_needed(r)
            if need > self.max_len:
                self._reject("max_len", (
                    f"request needs {need} cache positions, "
                    f"max_len={self.max_len}"
                ))
            if self.paged and self._pages_needed(r) > self.allocator.usable_pages():
                # An over-pool request can NEVER be admitted; under the
                # FIFO head-of-line gate it would queue forever and wedge
                # everything behind it — reject at submit instead.  The
                # bound is USABLE capacity: quarantined pages (DESIGN.md
                # §5.6) never return to circulation.  (A pool that shrinks
                # below an already-queued request's demand is the drain()
                # watchdog's business.)
                self._reject("pool_too_small", (
                    f"request needs {self._pages_needed(r)} pages, pool "
                    f"has {self.allocator.usable_pages()} usable of "
                    f"{self.n_pages} — it could never be admitted and "
                    "would block the FIFO queue forever"
                ))
            if r.id is not None:
                # Identity check, not ==: dataclass equality on array
                # fields is both wrong and throwing.
                prev = self._by_id.get(r.id)
                if prev is not None and prev is not r:
                    self._reject("duplicate_id", (
                        f"request id {r.id!r} already submitted to "
                        "this engine"
                    ))
        if (self.max_queue is not None
                and len(self.queue) + len(requests) > self.max_queue):
            # Backpressure: the bounded queue rejects the WHOLE batch with
            # a machine-readable reason; the caller retries after a drain.
            self._reject("queue_full", (
                f"submitting {len(requests)} request(s) would exceed "
                f"max_queue={self.max_queue} ({len(self.queue)} queued)"
            ), n=len(requests))
        now = time.perf_counter()
        for r in requests:
            if r.id is None:
                r.id = f"req-{self._next_id}"
                self._next_id += 1
            self._by_id[r.id] = r
            r.submit_t = now
            r.status = "queued"
            self.queue.append(r)
            if self.journal is not None and not self._replaying:
                self.journal.append(snap.submit_event(r))
        if self.journal is not None and not self._replaying:
            # One fsync per submit batch: an accepted request is durable
            # before the caller regains control.
            self.journal.flush()

    def cancel(self, request_id: str) -> bool:
        """Request cancellation of a queued or resident request.  Takes
        effect at the next lifecycle sweep (a chunk boundary): the slot,
        pages and trie refs free mid-stream, ``generated`` keeps whatever
        was emitted.  Returns False for unknown or already-terminal ids
        (cancellation raced completion) — never raises."""
        r = self._by_id.get(request_id)
        if r is None or r.done:
            return False
        r.cancel_requested = True
        return True

    def _live(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slot_req) if r is not None]

    def _release_slot(self, r: Request) -> None:
        """Vacate ``r``'s slot host-side (finish, preempt, cancel, expire).
        Drops the slot's page references — pages shared with live slots
        survive (refcount > 0); pages reaching zero return to the pool and
        their trie nodes evict.  The device page table is refreshed lazily
        at the next admission wave; until then the stale row is harmless —
        the parked slot neither writes KV (seg_lens == 0 drops the
        scatter) nor has its output read.  The slot lands in
        ``_dirty_slots`` so its device budget is zeroed before the next
        chunk (moot for natural finishes, where it already hit zero)."""
        slot = r.slot
        assert slot >= 0 and self.slot_req[slot] is r
        self.slot_req[slot] = None
        r.slot = -1
        r.__dict__.pop("_prefix_chunks", None)
        if self.paged:
            freed = self.allocator.release(self._slot_pages[slot])
            if self._warm_tier and freed:
                # Adaptive retention (DESIGN.md §5.7): trie-registered
                # prefix pages may park in the warm tier instead of
                # freeing; what survives comes back shorn of its trie
                # eviction and stamp drop below.
                freed = self._maybe_retain(r, freed)
            if self.prefix is not None and freed:
                self.prefix.evict(freed)
            for p in freed:
                # A page leaving circulation (freed or quarantined) sheds
                # its integrity stamp; its next holder re-stamps fresh
                # bytes.  Pages still held by sharers keep theirs — their
                # content is immutable below every sharer's cursor.
                self._page_fp.pop(p, None)
            self._slot_pages[slot] = []
            self.page_table[slot] = -1
        self._dirty_slots.add(slot)

    def _maybe_retain(self, r: Request, freed: list[int]) -> list[int]:
        """Warm-retention pass over pages that just reached refcount zero
        (DESIGN.md §5.7).  Returns the pages that must still be evicted
        (trie node dropped, stamp shed); retained pages keep both — a
        warm page's KV stays attachable until reclaimed.

        Closure rules that keep the trie's leaf-upward eviction sound:

        * retention goes shallowest-first and a page is retained only if
          its parent is held, warm, or retained in this same pass — so
          the warm set stays a depth-prefix of each chain;
        * evicting a page whose descendants were retained EARLIER (by a
          shorter sharer that finished first) reclaims that warm subtree
          along with it — a trie node never outlives its parent.
        """
        key = getattr(r, "_adaptive_key", None)
        kept: set[int] = set()
        if key is not None:
            deciding = getattr(r, "_adaptive_class", key)
            quota = self.adaptive.retain_quota(key)
            for p in sorted(freed,
                            key=lambda q: (self.prefix.depth_of(q), q)):
                depth = self.prefix.depth_of(p)
                if depth <= 0:
                    continue          # tail/decode page: never in the trie
                if self.adaptive.class_warm_count(deciding) >= quota:
                    break             # class share of the budget exhausted
                parent = self.prefix.parent_page(p)
                if depth > 1 and not (
                        parent in kept
                        or self.allocator.is_warm(parent)
                        or self.allocator.ref_count(parent) > 0):
                    continue          # chain cut above: stay a prefix
                if self.allocator.retain(p):
                    self.adaptive.note_retained(p, deciding)
                    self.stats["warm_retained"] += 1
                    kept.add(p)
        evict = [p for p in freed if p not in kept]
        # Warm-subtree closure on the evict side: descendants of an
        # evicted page can only be warm (a held child implies a held
        # parent) or in this same freed batch.
        extra: list[int] = []
        for p in evict:
            for q in self.prefix.subtree_pages(p):
                if (q != p and self.allocator.is_warm(q)
                        and q not in extra):
                    extra.append(q)
        if extra:
            self.allocator.reclaim(extra)
            self.adaptive.note_reclaimed(extra)
            self.stats["warm_reclaimed"] += len(extra)
        return evict + extra

    def _reclaim_warm(self, n_needed: int, protect: set[int]) -> int:
        """Return up to ``n_needed`` warm pages to the free list so a
        gated admission can allocate (reclaim-before-preempt).  The
        adaptive rank orders candidates; each candidate takes its warm
        subtree along (closure).  ``protect`` is the shared chain the
        admission is about to revive — never reclaimed out from under
        it.  Not policy-gated: capacity pressure always wins over
        retention, so the warm tier can never starve admission."""
        taken: list[int] = []
        warm = sorted(self.allocator.warm_pages)
        for p in self.adaptive.reclaim_order(warm):
            if len(taken) >= n_needed:
                break
            if p in protect or p in taken:
                continue
            sub = [q for q in self.prefix.subtree_pages(p)
                   if q not in taken]
            if any(q in protect for q in sub):
                continue
            taken.extend(sub)
        if taken:
            self.prefix.evict(taken)
            for q in taken:
                self._page_fp.pop(q, None)
            self.allocator.reclaim(taken)
            self.adaptive.note_reclaimed(taken)
            self.stats["warm_reclaimed"] += len(taken)
        return len(taken)

    def _retire(self, r: Request, status: str) -> None:
        """Terminal transition for a non-finish exit (cancelled/expired)."""
        r.status = status
        r.done = True
        r.cancel_requested = False
        r.__dict__.pop("_prefix_chunks", None)
        self.stats[status] += 1
        if r.deadline_s is not None:
            # An expired/cancelled deadlined request counts against
            # goodput: it reached terminal state without finishing.
            self.stats["deadline_total"] += 1
        if self.journal is not None and not self._replaying:
            self.journal.append(snap.terminal_event(r))

    def _finish(self, r: Request) -> None:
        r.done = True
        r.status = "finished"
        if self.journal is not None and not self._replaying:
            self.journal.append(snap.terminal_event(r))
        if r.deadline_s is not None:
            self.stats["deadline_total"] += 1
            if (r.submit_t is None
                    or time.perf_counter() - r.submit_t <= r.deadline_s):
                self.stats["deadline_met"] += 1
        slot = r.slot
        self._release_slot(r)
        # Budget exhausted on device (len(generated) == max_new_tokens
        # implies remaining == 0): no zeroing needed for a natural finish.
        self._dirty_slots.discard(slot)

    def _pick_victim(self, head: Request, wave_slots: set[int]
                     ) -> Request | None:
        """Choose a preemption victim for the page-gated ``head``.

        Static engine: the YOUNGEST (most recently admitted) resident.
        Adaptive engine (DESIGN.md §5.7): the CHEAPEST to recompute —
        estimated replay tokens (prompt + emitted) discounted one page's
        worth per page other slots still share (those pages stay
        resident either way), ties youngest-first.  Victim choice is
        placement-only: recompute-restore is bit-identical regardless of
        who gets evicted, so the two engines may pick different victims
        and still emit identical streams.

        Anti-livelock double guard (both engines): a head that was
        itself preempted never triggers another preemption, and only
        never-preempted residents are eligible victims — so natural
        preemptions are bounded by the request count and a
        preempt/restore ping-pong cannot form.  Slots admitted earlier
        in the current wave are off-limits (their prefill hasn't run;
        evicting them would corrupt the wave's buffers)."""
        if not self.preemption or head.preempted_n > 0:
            return None
        cands = [
            r for i, r in enumerate(self.slot_req)
            if r is not None and i not in wave_slots and r.preempted_n == 0
        ]
        if not cands:
            return None
        if self.adaptive is not None:
            return min(cands, key=lambda r: (
                self.adaptive.victim_cost(
                    r, self.allocator, self._slot_pages[r.slot]
                ),
                -r.admit_seq,
            ))
        return max(cands, key=lambda r: r.admit_seq)

    def _preempt(self, victim: Request, forced: bool = False) -> None:
        """Evict a resident mid-stream and re-enqueue it for restore.
        Pages release refcount-aware (shared pages are only dereferenced);
        emitted tokens are already host-side in ``victim.generated``, and
        re-admission prefills prompt + emitted (``_effective_prompt``) so
        the restored stream is bit-identical by construction.  The victim
        re-enters at the queue FRONT: residents are always older than
        anything queued (FIFO admission), so appendleft preserves global
        arrival order."""
        self._release_slot(victim)
        victim.status = "preempted"
        victim.preempted_n += 1
        self.queue.appendleft(victim)
        self.stats["preempted"] += 1
        if forced:
            self.stats["preempted_forced"] += 1

    def _chaos_forced_preempt(self) -> None:
        """Chaos knob: with seeded probability cfg.chaos_preempt_p, force-
        preempt the youngest resident at a wave boundary — exercising the
        preempt/restore path even when the pool never gates (and for
        non-paged layouts, where genuine page pressure can't arise)."""
        if self._chaos_rng.random() >= self.cfg.chaos_preempt_p:
            return
        cands = [r for r in self.slot_req if r is not None]
        if not cands:
            return
        self._preempt(max(cands, key=lambda r: r.admit_seq), forced=True)

    def _deadline_hit(self, r: Request, now: float) -> bool:
        return (r.deadline_s is not None and r.submit_t is not None
                and now - r.submit_t > r.deadline_s)

    def _sweep_lifecycle(self) -> None:
        """Chunk-boundary sweep: retire cancelled/expired requests, queued
        or resident.  Resident exits free the slot/pages/trie refs
        mid-stream and keep the partial ``generated``."""
        now = time.perf_counter()
        if self.queue:
            keep = []
            for r in self.queue:
                if r.cancel_requested:
                    self._retire(r, "cancelled")
                elif self._deadline_hit(r, now) or (
                    r.max_queue_wait_s is not None
                    and r.submit_t is not None
                    and now - r.submit_t > r.max_queue_wait_s
                ):
                    self._retire(r, "expired")
                else:
                    keep.append(r)
            if len(keep) != len(self.queue):
                self.queue = collections.deque(keep)
        for _, r in self._live():
            if r.cancel_requested:
                self._release_slot(r)
                self._retire(r, "cancelled")
            elif self._deadline_hit(r, now):
                self._release_slot(r)
                self._retire(r, "expired")

    def _acquire_pages(self, head: Request, eff: np.ndarray,
                       wave_slots: set[int]):
        """Allocate the page table for the queue head (paged only):
        shared resident prefix pages (refcount bump) + freshly allocated
        private pages.  While the pool is short, preempt one eligible
        victim per retry — each iteration either admits or removes a
        resident, so the loop terminates.  The prefix lookup re-runs
        every attempt (releasing a victim can shrink the resident chain);
        alloc goes first and share only on success, so a gated head
        leaves every refcount untouched.  Returns
        ``(table, chunks, shared_tokens)`` or ``(None, None, 0)``."""
        need = self._pages_needed(head)
        while True:
            shared, shared_len = [], 0
            chunks = None
            if self.prefix is not None:
                # Chunk the effective prompt once per queue stint
                # (memoized on the request): a page-gated head re-tried
                # every chunk boundary doesn't rebuild it.  Preemption
                # invalidates the memo (the effective prompt grows).
                chunks = getattr(head, "_prefix_chunks", None)
                if chunks is None:
                    chunks = self.prefix.chunks(eff)
                    head._prefix_chunks = chunks
                shared, shared_len = self._shared_prefix(eff, chunks)
            n_fresh = need - len(shared)
            if self._warm_tier:
                # Capacity beats retention: before letting a short alloc
                # gate (or preempt for) this head, reclaim warm pages the
                # policy is merely speculating on.  The head's own shared
                # chain is protected — reclaiming it would evict trie
                # nodes we are about to attach.
                short = n_fresh - self.allocator.free_count()
                if short > 0 and self.allocator.warm_count():
                    self._reclaim_warm(short, protect=set(shared))
            ids = self.allocator.alloc(n_fresh)
            if ids is not None:
                # A shared chain may end in WARM pages (retained at
                # refcount zero): those are revived to refcount 1, not
                # share()d.  Held pages are always a chain prefix and
                # warm ones a suffix (a held child implies a held
                # parent), but membership — not position — is what the
                # allocator cares about.
                warm_set = (
                    {p for p in shared if self.allocator.is_warm(p)}
                    if self._warm_tier else set()
                )
                held_part = [p for p in shared if p not in warm_set]
                if not held_part or self.allocator.share(held_part):
                    if warm_set:
                        warm_part = [p for p in shared if p in warm_set]
                        self.allocator.revive(warm_part)
                        self.adaptive.note_revived(warm_part)
                        self.stats["warm_hits"] += 1
                        self.stats["warm_tokens_saved"] += (
                            len(warm_part) * self.page_size
                        )
                    return shared + ids, chunks, shared_len
                # Injected share refusal (ChaosAllocator): roll back the
                # fresh alloc so the gated head leaves every refcount
                # untouched — the same atomicity a failed alloc gives.
                # The pages were never trie-registered or stamped, so the
                # bare allocator release is the whole rollback.  Warm
                # pages were not revived yet, so they need no rollback.
                self.allocator.release(ids)
            victim = self._pick_victim(head, wave_slots)
            if victim is None:
                return None, None, 0
            self._preempt(victim)

    def _admit_wave(self) -> None:
        if self._chaos_rng is not None:
            self._chaos_forced_preempt()
        if self.adaptive is not None:
            self.adaptive.begin_wave()
        # Wave entries carry the request's EFFECTIVE prompt (prompt +
        # previously emitted tokens for a preempted request being
        # restored, DESIGN.md §5.5) — everything downstream (page demand,
        # prefix chunks, prefill buffers, history) treats it as the
        # prompt.
        wave: list[tuple[int, Request, np.ndarray]] = []
        wave_slots: set[int] = set()
        now = time.perf_counter()
        while self.queue:
            slot = next(
                (i for i, q in enumerate(self.slot_req) if q is None), None
            )
            if slot is None:
                break
            # Pop the head BEFORE any preemption retry: victims re-enter
            # at the queue front (appendleft), which would displace a head
            # still sitting at queue[0].
            head = self.queue.popleft()
            eff = self._effective_prompt(head)
            if self.paged:
                # Admission gates on free pages (FIFO head-of-line: a
                # request that doesn't fit waits — or preempts — rather
                # than being overtaken).  With prefix sharing the head
                # only needs pages for its UNSHARED suffix; the shared
                # prefix rides resident pages via a refcount bump.
                table, chunks, shared_len = self._acquire_pages(
                    head, eff, wave_slots
                )
                if table is None:
                    self.queue.appendleft(head)
                    break
                head.prefix_tokens = shared_len
                self._slot_pages[slot] = table
                self.page_table[slot] = -1
                self.page_table[slot, :len(table)] = table
                if self.prefix is not None:
                    # Index this prompt's own full pages so later requests
                    # can attach; already-resident chunks keep their
                    # existing (shared) nodes.
                    self.prefix.register(eff, table[:len(chunks)],
                                         chunks=chunks)
                    if self.adaptive is not None:
                        # Classify by prompt content (first full page) and
                        # remember which class DECIDES this request's
                        # retention at release time.  A readmission keeps
                        # its original deciding class — its effective
                        # prompt grew, so re-hashing would re-classify.
                        key = self.adaptive.class_key(chunks)
                        head._adaptive_key = key
                        if head.generated:
                            head._adaptive_class = getattr(
                                head, "_adaptive_class", key
                            )
                        else:
                            head._adaptive_class = self.adaptive.note_arrival(
                                key, len(eff),
                                ((len(eff) - 1) // self.page_size)
                                * self.page_size,
                            )
                        self.adaptive.touch(table)
                    if shared_len:
                        self.stats["prefix_hits"] += 1
                        if not head.generated:
                            self.stats["prefix_hits_fresh"] += 1
                        self.stats["prefix_pages_shared"] += (
                            shared_len // self.page_size
                        )
                        self.stats["prefix_tokens_shared"] += shared_len
            else:
                head.prefix_tokens = 0    # contiguous: always a full prefill
            # The chunk memo exists only to amortize head-of-line retries;
            # drop it at admission so engine-private (and page-size-
            # dependent) state never outlives the queue.
            head.__dict__.pop("_prefix_chunks", None)
            head.admit_t = now
            if head.submit_t is not None and head.queue_wait_s is None:
                head.queue_wait_s = now - head.submit_t
            head.status = "resident"
            head.slot = slot
            head.admit_seq = self._admit_seq
            self._admit_seq += 1
            self.slot_req[slot] = head
            if head.generated:
                # Preemption restore: its prefill replays work already
                # done once, so it must NOT dilute fresh-admission rates
                # (the serve_stats prefix_hit_rate bug this split fixes).
                self.stats["readmitted"] += 1
                self.stats["recompute_tokens"] += len(head.generated)
            else:
                self.stats["admitted_fresh"] += 1
            wave.append((slot, head, eff))
            wave_slots.add(slot)
        # Park slots vacated mid-stream (preempt/cancel/expire) that this
        # wave did not refill: their device budget must hit zero before
        # the next chunk, or they would keep decoding into pages now
        # owned by others.  (Wave slots are re-armed by the prefill's
        # admitted mask, so they need no zeroing.)
        stale = sorted(self._dirty_slots - wave_slots)
        self._dirty_slots.clear()
        if stale:
            self.remaining = self.remaining.at[jnp.asarray(stale)].set(0)
        if not wave:
            if self._chaos or self._strict:
                self.check_invariants()
            return
        # Attached slots prefill only their unshared suffix (prefix_tokens
        # is 0 without sharing), so the pad bucket — and the prefill's
        # compute — shrinks to the widest *suffix* in the wave.  The
        # n-gram history still seeds from the FULL prompt via a separate
        # (cheap, scatter-only) buffer, so drafting under sharing matches
        # the unshared engine.
        pad = _pad_bucket(
            max(len(eff) - r.prefix_tokens for _, r, eff in wave),
            self.max_len,
        )
        # The full-prompt history buffer only differs from the prefill
        # buffer when some wave member attached a prefix; otherwise the
        # suffix IS the prompt and one buffer serves both arguments.
        attached = any(r.prefix_tokens for _, r, _ in wave)
        toks = np.zeros((self.slots, pad), np.int32)
        if attached:
            hpad = _pad_bucket(
                max(len(eff) for _, _, eff in wave), self.max_len
            )
            htoks = np.zeros((self.slots, hpad), np.int32)
        else:
            htoks = toks
        seg = np.zeros((self.slots,), np.int32)
        start = np.zeros((self.slots,), np.int32)
        new_rem = np.zeros((self.slots,), np.int32)
        new_tidx = np.zeros((self.slots,), np.int32)
        new_seeds = np.zeros((self.slots,), np.int32)
        for slot, r, eff in wave:
            n = len(eff) - r.prefix_tokens
            # Actual prefill compute demand (suffix tokens only — shared
            # or warm-revived prefixes cost nothing).  Unlike
            # prefill_tokens (emitted first tokens) this measures WORK,
            # which is what the adaptive-vs-static bench compares.
            self.stats["prefill_work_tokens"] += n
            toks[slot, :n] = eff[r.prefix_tokens:]    # right-pad; drops
            if attached:
                htoks[slot, :len(eff)] = eff
            seg[slot] = n
            start[slot] = r.prefix_tokens      # page-aligned attach cursor
            # Restore-aware seeding: a fresh request samples stream index
            # 0 with a full budget; a restored one samples index
            # len(generated) with the unconsumed remainder (its last
            # emitted token is part of the prefill, whose final logits
            # reproduce the uninterrupted run's next sample).
            new_rem[slot] = r.max_new_tokens - len(r.generated) - 1
            new_tidx[slot] = len(r.generated)
            # Fold arbitrary Python ints (64-bit hashes, negatives) into
            # int32 range: still a pure function of the request's seed, so
            # determinism and order-independence are preserved.
            new_seeds[slot] = (0 if r.seed is None else r.seed) % (2 ** 31)
        if self.paged:
            # Push the host free-list's view of the page table to device.
            # The table is tiny; replacing the leaf keeps the jitted prefill
            # signature layout-independent (donation still applies).
            self.cache = {**self.cache, "pages": jnp.asarray(self.page_table)}
        # Admission consults the policy engine: KV residency for the current
        # occupancy and the (PlanCache-memoized) decode-attention plan.
        self.decode_plan = self._plan_decode()
        toks_d = jnp.asarray(toks)
        htoks_d = jnp.asarray(htoks) if attached else toks_d
        (self.cache, self.cur_tok, self.remaining, self.tok_idx, self.hist,
         self.hist_len, self.seeds, nxt) = self._prefill(
            self.params, self.cache, toks_d, jnp.asarray(seg),
            jnp.asarray(start), htoks_d, self.cur_tok,
            self.remaining, jnp.asarray(new_rem), jnp.asarray(new_tidx),
            self.tok_idx, self.hist, self.hist_len, jnp.asarray(new_seeds),
            self.seeds,
        )
        first = np.asarray(nxt)                # host sync: 1 per wave
        self.stats["host_syncs"] += 1
        self.stats["admission_waves"] += 1
        if (self.adaptive is not None and self.adaptive.pinned is None
                and self.stats["admission_waves"]
                % self.adaptive.replan_every == 0):
            # Re-plan boundary: feed the counters through the serve-policy
            # lattice (core/sweep.py) and install per-class combos.
            # Placement-only — outputs are bit-identical either way.
            self.adaptive.replan(self.stats)
            self.stats["replans"] += 1
        if self.paged:
            self.stats["peak_pages_held"] = max(
                self.stats["peak_pages_held"],
                self.n_pages - self.allocator.free_count(),
            )
        now = time.perf_counter()
        for _, r, _ in wave:
            r.generated.append(int(first[r.slot]))
            self.stats["prefill_tokens"] += 1
            if r.ttft_s is None and r.admit_t is not None:
                # True TTFT: admission -> first token (prefill compute);
                # queueing is reported separately as queue_wait_s.
                r.ttft_s = now - r.admit_t
            if len(r.generated) >= r.max_new_tokens:
                self._finish(r)
        if self._chaos or self._strict:
            self.check_invariants()

    def _run_chunk(self) -> None:
        (self.cache, self.cur_tok, self.remaining, self.tok_idx, self.hist,
         self.hist_len, toks, actives) = self._decode_chunk(
            self.params, self.cache, self.cur_tok, self.remaining,
            self.tok_idx, self.hist, self.hist_len, self.seeds,
        )
        t_np, a_np = jax.device_get((toks, actives))   # host sync: 1 per chunk
        self.stats["host_syncs"] += 1
        self.stats["decode_syncs"] += 1
        self.stats["chunks"] += 1
        for slot, r in self._live():
            emitted = a_np[:, slot]
            for i in np.nonzero(emitted)[0]:
                r.generated.append(int(t_np[i, slot]))
            self.stats["decode_tokens"] += int(emitted.sum())
            if len(r.generated) >= r.max_new_tokens:
                self._finish(r)

    def _run_spec_chunk(self) -> None:
        (self.cache, self.cur_tok, self.remaining, self.tok_idx, self.hist,
         self.hist_len, toks, emits, accepts, proposed,
         actives) = self._decode_chunk(
            self.params, self.cache, self.cur_tok, self.remaining,
            self.tok_idx, self.hist, self.hist_len, self.seeds,
        )
        # toks/emits: (rounds, b, k+1); accepts/proposed/actives: (rounds, b).
        t_np, e_np, acc_np, prop_np, act_np = jax.device_get(
            (toks, emits, accepts, proposed, actives)
        )                                              # host sync: 1 per chunk
        self.stats["host_syncs"] += 1
        self.stats["decode_syncs"] += 1
        self.stats["chunks"] += 1
        for slot, r in self._live():
            for j in range(t_np.shape[0]):
                if not act_np[j, slot]:
                    continue
                row = e_np[j, slot]
                for t in t_np[j, slot][row]:
                    r.generated.append(int(t))
                self.stats["decode_tokens"] += int(row.sum())
                # Spec-round-emitted tokens in their OWN counter: the old
                # spec_tokens_per_round divided ALL decode tokens (non-
                # spec chunks included) by spec_rounds, inflating the
                # ratio whenever plain decode ran in the same session.
                self.stats["spec_tokens"] += int(row.sum())
                self.stats["spec_rounds"] += 1
                self.stats["draft_proposed"] += int(prop_np[j, slot])
                self.stats["draft_accepted"] += int(acc_np[j, slot])
            if len(r.generated) >= r.max_new_tokens:
                self._finish(r)

    def check_invariants(self) -> None:
        """Assert engine/allocator/trie conservation (DESIGN.md §5.5);
        called after every wave under chaos and by the fault-injection
        tests.  Uses identity (never ``==``) for request membership —
        dataclass equality on array fields is both wrong and throwing.

        * slot/queue partition: a request is resident in exactly the slot
          that maps it, never also queued, and never terminal;
        * pages held ≡ slot page tables: the allocator's held set is
          exactly the union of resident slots' pages, refcounts equal the
          number of slot tables mapping each page (the trie holds no
          references), and free + held partitions the pool — zero leaks;
        * the device-visible page-table rows mirror the host tables;
        * trie residency ⊆ held pages (no node outlives its storage).

        With quarantine (DESIGN.md §5.6) the pool partition is
        free + held + quarantined, and doomed pages are always held.
        With the adaptive warm tier (DESIGN.md §5.7) it is
        free + held + warm + quarantined; warm pages stay within budget,
        are always trie-registered (warm retention exists only to keep
        prefix nodes attachable), and keep their integrity stamps (their
        content is live KV a future request may attach to).
        """
        self.stats["invariant_checks"] += 1
        queued = list(self.queue)
        for slot, r in enumerate(self.slot_req):
            if r is None:
                continue
            assert r.slot == slot, f"slot {slot} maps request at {r.slot}"
            assert not r.done and r.status == "resident", (
                f"slot {slot} holds a {r.status!r} request"
            )
            assert not any(q is r for q in queued), (
                f"request {r.id!r} is both resident and queued"
            )
            assert len(r.generated) < r.max_new_tokens
        for q in queued:
            assert not q.done and q.status in ("queued", "preempted"), (
                f"queued request {q.id!r} has status {q.status!r}"
            )
        if not self.paged:
            return
        slot_refs: collections.Counter[int] = collections.Counter()
        for slot in range(self.slots):
            pages = self._slot_pages[slot]
            row = self.page_table[slot]
            if self.slot_req[slot] is None:
                assert pages == [], f"vacant slot {slot} leaks pages {pages}"
                assert (row == -1).all(), f"vacant slot {slot} maps {row}"
                continue
            assert len(pages) == len(set(pages)), (
                f"slot {slot} maps a page twice: {pages}"
            )
            assert list(row[:len(pages)]) == pages, (
                f"device/host page-table drift in slot {slot}"
            )
            assert (row[len(pages):] == -1).all()
            slot_refs.update(pages)
        held = self.allocator.held_pages
        assert held == set(slot_refs), (
            f"held/mapped drift: leaked={sorted(held - set(slot_refs))} "
            f"phantom={sorted(set(slot_refs) - held)}"
        )
        for page, refs in slot_refs.items():
            assert self.allocator.ref_count(page) == refs, (
                f"page {page}: allocator refcount "
                f"{self.allocator.ref_count(page)} != {refs} mapping slots"
            )
        free = self.allocator.free_pages
        quar = self.allocator.quarantined_pages
        warm = self.allocator.warm_pages
        assert len(free) == len(set(free)) and not held & set(free)
        assert not quar & held and not quar & set(free), (
            f"quarantined pages back in circulation: "
            f"{sorted(quar & (held | set(free)))}"
        )
        assert self.allocator.doomed_pages <= held, (
            "doomed (pending-quarantine) pages must still be held"
        )
        assert len(warm) <= self.allocator.warm_budget, (
            f"warm tier over budget: {len(warm)} > "
            f"{self.allocator.warm_budget}"
        )
        assert not warm & held and not warm & set(free) and not warm & quar, (
            f"warm pages double-booked: {sorted(warm & (held | set(free) | quar))}"
        )
        assert (sorted(list(free) + list(held) + list(warm) + list(quar))
                == list(range(self.n_pages))), (
            "free + held + warm + quarantined is not a partition of the pool"
        )
        assert not set(self._page_fp) - held - warm, (
            f"integrity stamps outlive their pages: "
            f"{sorted(set(self._page_fp) - held - warm)}"
        )
        if self.prefix is not None:
            resident = self.prefix.resident_pages()
            stray = resident - held - warm
            assert not stray, f"trie nodes outlive their pages: {stray}"
            assert warm <= resident, (
                f"warm pages outside the trie (retention exists only to "
                f"keep prefix nodes attachable): {sorted(warm - resident)}"
            )
        else:
            assert not warm, f"warm pages without a prefix index: {warm}"

    # -- KV page integrity (DESIGN.md §5.6) --------------------------------

    def _pool_leaf_ids(self, leaves: list) -> list[int]:
        """Indices of the paged K/V pool leaves in the flattened cache:
        the arrays whose trailing axes are (n_pages, page_size, heads,
        head_dim).  Slot-indexed leaves (contiguous cross K/V, recurrent
        state, the page table itself) never carry that pair of axes."""
        return [
            i for i, x in enumerate(leaves)
            if hasattr(x, "ndim") and x.ndim >= 4
            and x.shape[-4] == self.n_pages
            and x.shape[-3] == self.page_size
            and jnp.issubdtype(x.dtype, jnp.floating)
        ]

    def _fingerprint_pages(self, pages, pools=None) -> dict[int, int]:
        """CRC32 per page over the concatenated bytes of every pool leaf's
        page slice — cheap, deterministic, and sensitive to any single
        flipped value.  One host sync pulls the pools unless the caller
        already did (``pools``)."""
        if pools is None:
            leaves = jax.tree_util.tree_leaves(self.cache)
            # One batched transfer for every pool leaf (R001): per-leaf
            # np.asarray would pay one blocking round-trip per leaf.
            pools = jax.device_get(
                [leaves[i] for i in self._pool_leaf_ids(leaves)]
            )
            self.stats["host_syncs"] += 1
        out = {}
        for p in pages:
            c = 0
            for pool in pools:
                c = zlib.crc32(
                    np.ascontiguousarray(pool[..., p, :, :, :]).tobytes(), c
                )
            out[p] = c
        return out

    def _sealed_pages(self) -> set[int]:
        """Pages wholly below some resident slot's host-computed write
        cursor (len(prompt) + len(generated) - 1 — the §5.5 cursor
        identity).  Sealed content is immutable: per-slot cursors are
        monotone for the life of a residency (spec rollback rewinds only
        within the current round's window, never below a chunk boundary),
        and shared pages sit below EVERY sharer's cursor by construction."""
        sealed: set[int] = set()
        for slot, r in self._live():
            cur = len(r.prompt) + len(r.generated) - 1
            sealed.update(self._slot_pages[slot][: cur // self.page_size])
        return sealed

    def _corrupt_page(self, page: int) -> None:
        """Chaos bit-flip: perturb one element of ``page`` in the first
        pool leaf (every leading stack entry, so any layer's read would
        expose it).  Device-side, exactly like real HBM corruption."""
        leaves, treedef = jax.tree_util.tree_flatten(self.cache)
        i = self._pool_leaf_ids(leaves)[0]
        leaves[i] = leaves[i].at[..., page, 0, 0, 0].add(1)
        self.cache = jax.tree_util.tree_unflatten(treedef, leaves)

    def _integrity_sweep(self) -> list[int]:
        """Chunk-boundary integrity pass: stamp newly sealed pages, land
        any injected corruption (chaos_corrupt_p), then verify every
        stamp.  Ordering matters: corruption is injected AFTER stamping
        and BEFORE verification, so a flipped page is detected and healed
        before any subsequent chunk could read it — which is what keeps
        chaos corruption runs bit-identical."""
        self.stats["integrity_sweeps"] += 1
        leaves = jax.tree_util.tree_leaves(self.cache)
        # Batched pull (R001): the sweep's "one host sync" accounting was
        # only honest when the pool had a single leaf; per-leaf
        # np.asarray paid one blocking round-trip per pool leaf.
        pools = jax.device_get(
            [leaves[i] for i in self._pool_leaf_ids(leaves)]
        )
        self.stats["host_syncs"] += 1
        new = self._sealed_pages() - self._page_fp.keys()
        if new:
            self._page_fp.update(
                self._fingerprint_pages(sorted(new), pools=pools)
            )
        if (self._corrupt_rng is not None and self._page_fp
                and self._corrupt_rng.random() < self.cfg.chaos_corrupt_p):
            stamped = sorted(self._page_fp)
            victim = stamped[int(self._corrupt_rng.integers(len(stamped)))]
            self._corrupt_page(victim)
            self.stats["injected_corruptions"] += 1
            pools = None     # device bytes changed; verify must re-pull
        return self.verify_pages(_pools=pools)

    def verify_pages(self, _pools=None) -> list[int]:
        """Re-fingerprint every stamped page; quarantine mismatches and
        self-heal by recompute-restore (DESIGN.md §5.6).

        A corrupted page is quarantined in the allocator (a held page is
        doomed: it leaves circulation at its last release, never the free
        list), then EVERY slot whose table maps it is preempted — the
        refcount-aware release tears all sharers off the bad page, and
        re-admission recomputes their KV into healthy pages from host
        truth, bit-identically.  Victims re-enter the queue oldest-first
        (descending-admit_seq appendleft), preserving arrival order.
        Returns the corrupted page ids."""
        if not self.paged or not self._page_fp:
            return []
        current = self._fingerprint_pages(sorted(self._page_fp), pools=_pools)
        bad = sorted(
            p for p, fp in self._page_fp.items() if current[p] != fp
        )
        if not bad:
            return []
        badset = set(bad)
        for p in bad:
            if p not in self._page_fp:
                continue   # already handled as part of a warm subtree
            if self._warm_tier and self.allocator.is_warm(p):
                # A corrupted WARM page has no sharers to heal — just
                # drop it from circulation.  Its warm descendants (a warm
                # page's children are never held) lose their ancestor
                # chain, so the whole subtree leaves the trie; clean
                # descendants reclaim to the free list while the bad
                # page — and any corrupted descendant — quarantines.
                sub = self.prefix.subtree_pages(p)
                self.prefix.evict(sub)
                for q in sub:
                    self._page_fp.pop(q, None)
                sub_bad = [q for q in sub if q in badset]
                clean = [q for q in sub if q not in badset]
                for q in sub_bad:
                    self.stats["corrupted_pages"] += 1
                    self.allocator.quarantine(q)
                if clean:
                    self.allocator.reclaim(clean)
                    self.stats["warm_reclaimed"] += len(clean)
                self.adaptive.note_reclaimed(sub)
                continue
            self._page_fp.pop(p)
            self.stats["corrupted_pages"] += 1
            self.allocator.quarantine(p)
        victims = [
            r for slot, r in self._live()
            if badset & set(self._slot_pages[slot])
        ]
        # Corruption healing is exempt from the once-only victim guard —
        # a slot reading poisoned KV must be restored no matter its
        # preemption history.
        for r in sorted(victims, key=lambda r: r.admit_seq, reverse=True):
            self.stats["healed_requests"] += 1
            self._preempt(r)
        if self._chaos or self._strict:
            self.check_invariants()
        return bad

    # -- snapshot / restore (DESIGN.md §5.6) -------------------------------

    def request(self, request_id: str) -> Request | None:
        """Live handle for a submitted request id (terminal ones kept)."""
        return self._by_id.get(request_id)

    def results(self) -> dict[str, list[int]]:
        """Emitted tokens per known request id — the stream-identity view
        the recovery gates compare."""
        return {rid: list(r.generated) for rid, r in self._by_id.items()}

    def snapshot(self, path: str) -> dict:
        """Serialize host-side truth to ``path`` (atomic, checksummed).

        Nothing device-resident is saved: the §5.5 restore-identity
        invariant makes every KV byte recomputable from (prompt, emitted
        tokens, seed, token index), so in-flight requests are recorded as
        re-queueable work and terminal requests keep their streams.  The
        journal offset recorded here is where replay resumes after an
        unplanned kill.  Callers invoke it between steps (chunk
        boundaries) — exactly where all host state is consistent."""
        self.stats["snapshots"] += 1
        residents = sorted(
            (r for _, r in self._live()), key=lambda r: r.admit_seq
        )
        queued = list(self.queue)
        terminal = [r for r in self._by_id.values() if r.done]
        records = (
            [snap.request_record(r) for r in terminal]
            # Residents re-enter as "preempted": re-queued work with
            # tokens already emitted.  Their crash-eviction does NOT
            # consume the anti-livelock budget (preempted_n untouched).
            + [snap.request_record(r, status="preempted") for r in residents]
            + [snap.request_record(r) for r in queued]
        )
        alloc = None
        if self.paged:
            alloc = {
                "refcounts": {
                    str(p): self.allocator.ref_count(p)
                    for p in sorted(self.allocator.held_pages)
                },
                "quarantined": sorted(self.allocator.quarantined_pages),
                "doomed": sorted(self.allocator.doomed_pages),
                "page_tables": {
                    str(slot): list(self._slot_pages[slot])
                    for slot in range(self.slots)
                    if self._slot_pages[slot]
                },
            }
        payload = {
            "cfg": snap.cfg_fingerprint(self.cfg),
            "geometry": {
                "slots": self.slots,
                "max_len": self.max_len,
                "paged": self.paged,
                "page_size": self.page_size if self.paged else None,
                "n_pages": self.n_pages if self.paged else None,
            },
            "counters": {
                "next_id": self._next_id, "admit_seq": self._admit_seq,
            },
            "stats": dict(self.stats),
            # Adaptive class knowledge survives restore (a counter-driven
            # policy must not diverge after crash-recovery); warm pages
            # themselves are volatile — restore starts with a cold warm
            # tier and relearns residency, which is placement-only.
            "adaptive": (
                self.adaptive.snapshot_state()
                if self.adaptive is not None else None
            ),
            "requests": records,
            "allocator": alloc,
            "journal": {
                "path": self.journal_path,
                "offset": (
                    self.journal.offset() if self.journal is not None else 0
                ),
            },
        }
        snap.write_snapshot(path, payload)
        return {
            "path": path,
            "requests": len(records),
            "in_flight": len(residents) + len(queued),
        }

    @staticmethod
    def _audit_snapshot(payload: dict) -> None:
        """Cross-check the snapshot's allocator section against its page
        tables — a snapshot whose refcounts don't equal the number of
        mapping tables was corrupt at WRITE time and must not restore."""
        alloc = payload.get("allocator")
        if not alloc:
            return
        mapped: collections.Counter[int] = collections.Counter()
        for pages in alloc["page_tables"].values():
            mapped.update(pages)
        refs = {int(p): n for p, n in alloc["refcounts"].items()}
        if refs != dict(mapped):
            raise SnapshotError("inconsistent", (
                "snapshot refcounts disagree with its page tables: "
                f"refcounts={refs} mapped={dict(mapped)}"
            ))

    def _request_from_record(self, rec: dict, now: float) -> Request:
        r = Request(
            prompt=np.asarray(rec["prompt"], np.int32),
            max_new_tokens=rec["max_new_tokens"],
            seed=rec["seed"],
            id=rec["id"],
            deadline_s=rec["deadline_s"],
            max_queue_wait_s=rec["max_queue_wait_s"],
        )
        r.generated = list(rec["generated"])
        r.status = rec["status"]
        r.preempted_n = rec["preempted_n"]
        r.cancel_requested = rec["cancel_requested"]
        r.ttft_s = rec["ttft_s"]
        r.queue_wait_s = rec["queue_wait_s"]
        r.done = rec["status"] in ("finished", "cancelled", "expired")
        if not r.done:
            # SLO clocks restart at recovery: wall time spent dead isn't
            # chargeable to the request's deadline.
            r.submit_t = now
        return r

    def _hard_reset(self) -> None:
        """Discard ALL engine state — device buffers, slots, queue,
        allocator, trie, stamps, counters — returning to the just-
        constructed blank.  The jitted dispatches survive (same shapes),
        so a restore re-uses every compilation."""
        b = self.slots
        self.cache = self.model.init_cache(
            self.params, batch=b, max_len=self.max_len, **self._cache_kwargs
        )
        self.cur_tok = jnp.zeros((b,), jnp.int32)
        self.remaining = jnp.zeros((b,), jnp.int32)
        self.tok_idx = jnp.zeros((b,), jnp.int32)
        self.seeds = jnp.zeros((b,), jnp.int32)
        self.hist = jnp.zeros((b, self.max_len + 1), jnp.int32)
        self.hist_len = jnp.zeros((b,), jnp.int32)
        self.slot_req = [None] * b
        self.queue = collections.deque()
        self._by_id = {}
        self._next_id = 0
        self._admit_seq = 0
        self._dirty_slots = set()
        self._page_fp = {}
        if self.paged:
            self.allocator = self._make_allocator()
            self.page_table = np.full((b, self.pages_per_slot), -1, np.int32)
            self._slot_pages = [[] for _ in range(b)]
            if self.prefix is not None:
                self.prefix = PrefixIndex(self.page_size)
        if self.adaptive is not None:
            self.adaptive = AdaptivePolicy(
                warm_pages=self.adaptive.warm_pages,
                replan_every=self.adaptive.replan_every,
                page_size=self.adaptive.page_size,
                spec_k=self.adaptive.spec_k,
                pinned=self.adaptive.pinned,
            )
        for k in self.stats:
            self.stats[k] = 0

    def restore(self, path: str | None = None) -> dict:
        """Rebuild the engine from a snapshot and/or the request journal.

        Validates BEFORE discarding anything: a corrupt/mismatched
        snapshot raises a typed ``SnapshotError`` and leaves the live
        engine untouched.  Then hard-resets, re-installs the quarantine
        set, re-enqueues every in-flight request (snapshot residents
        first, in admission order, then the queue — global arrival
        order), and replays the journal suffix past the snapshot's
        offset: unknown submits re-enter the queue, journaled terminal
        events re-retire their requests with the exact tokens they had
        emitted.  ``path=None`` replays the whole journal (snapshotless
        recovery).  Device KV is rebuilt entirely by the ordinary
        recompute-prefill admission path, so the restored streams are
        bit-identical to the uninterrupted run (§5.5/§5.6)."""
        if path is None and self.journal_path is None:
            raise SnapshotError(
                "no_source", "restore() needs a snapshot path or a journal"
            )
        payload = None
        if path is not None:
            payload = snap.load_snapshot(path)
            mine = snap.cfg_fingerprint(self.cfg)
            if payload.get("cfg") != mine:
                drift = sorted(
                    k for k in set(mine) | set(payload.get("cfg") or {})
                    if mine.get(k) != (payload.get("cfg") or {}).get(k)
                )
                raise SnapshotError("config_mismatch", (
                    f"snapshot was taken under a different config: {drift}"
                ))
            geo = {
                "slots": self.slots,
                "max_len": self.max_len,
                "paged": self.paged,
                "page_size": self.page_size if self.paged else None,
                "n_pages": self.n_pages if self.paged else None,
            }
            if payload.get("geometry") != geo:
                raise SnapshotError("geometry_mismatch", (
                    f"snapshot geometry {payload.get('geometry')} != "
                    f"engine geometry {geo}"
                ))
            self._audit_snapshot(payload)
        self._hard_reset()
        now = time.perf_counter()
        restored = replayed = 0
        journal_offset = 0
        self._replaying = True
        try:
            if payload is not None:
                self._next_id = payload["counters"]["next_id"]
                self._admit_seq = payload["counters"]["admit_seq"]
                for k, v in payload["stats"].items():
                    if k in self.stats:
                        self.stats[k] = v
                if (self.adaptive is not None
                        and payload.get("adaptive")):
                    self.adaptive.restore_state(payload["adaptive"])
                alloc = payload.get("allocator")
                if self.paged and alloc:
                    # Doomed pages' holders died with the crash: they are
                    # quarantined outright (refcount 0 now).
                    for p in alloc["quarantined"] + alloc["doomed"]:
                        self.allocator.quarantine(p)
                for rec in payload["requests"]:
                    r = self._request_from_record(rec, now)
                    self._by_id[r.id] = r
                    if not r.done:
                        self.queue.append(r)
                        restored += 1
                journal_offset = (payload.get("journal") or {}).get(
                    "offset", 0
                )
            if (self.journal_path is not None
                    and os.path.exists(self.journal_path)):
                for ev in snap.RequestJournal.replay(
                        self.journal_path, journal_offset):
                    replayed += 1
                    if ev.get("ev") == "submit":
                        if ev["id"] in self._by_id:
                            continue
                        r = Request(
                            prompt=np.asarray(ev["prompt"], np.int32),
                            max_new_tokens=ev["max_new_tokens"],
                            seed=ev["seed"],
                            id=ev["id"],
                            deadline_s=ev["deadline_s"],
                            max_queue_wait_s=ev["max_queue_wait_s"],
                        )
                        r.status = "queued"
                        r.submit_t = now
                        self._by_id[r.id] = r
                        self.queue.append(r)
                        restored += 1
                    elif ev.get("ev") == "terminal":
                        r = self._by_id.get(ev["id"])
                        if r is None:
                            continue
                        if not r.done and any(
                                q is r for q in self.queue):
                            self.queue = collections.deque(
                                q for q in self.queue if q is not r
                            )
                            restored -= 1
                        r.generated = list(ev["generated"])
                        r.status = ev["status"]
                        r.done = True
        finally:
            self._replaying = False
        self.stats["restores"] += 1
        if self._chaos or self._strict:
            self.check_invariants()
        return {
            "restored": restored,
            "replayed_events": replayed,
            "terminal": sum(1 for r in self._by_id.values() if r.done),
        }

    # -- scheduler loop ----------------------------------------------------

    def step(self) -> bool:
        """One scheduler tick: lifecycle sweep (cancel/expire), admission
        (with preemption), one decode chunk if anything is resident, then
        the integrity sweep and a journal flush — so every step ends on a
        durable, verified chunk boundary.  Returns True while work
        remains — callers interleave ``cancel()`` / ``submit()`` with
        ``step()`` for mid-stream control."""
        self._sweep_lifecycle()
        self._admit_wave()
        if self.slot_req.count(None) < self.slots:
            (self._run_spec_chunk if self.spec else self._run_chunk)()
        if self.integrity:
            self._integrity_sweep()
        if self.journal is not None:
            self.journal.flush()
        if (self.cfg.chaos_crash_after_wave > 0
                and self.stats["admission_waves"]
                >= self.cfg.chaos_crash_after_wave):
            # Injected kill (DESIGN.md §5.6): the journal is flushed and
            # every host structure sits at a chunk boundary — exactly
            # the state an external SIGKILL between steps would leave on
            # disk.  The engine object is dead; recovery restores a
            # fresh one from snapshot + journal.
            raise ChaosCrash(self.stats["admission_waves"])
        return bool(self.queue) or self.slot_req.count(None) < self.slots

    def _progress_marker(self) -> tuple:
        """Observable progress: tokens emitted or lifecycle transitions.
        Anything that changes one of these is forward motion; a step that
        changes none was pure spin."""
        s = self.stats
        return (s["decode_tokens"], s["prefill_tokens"], s["preempted"],
                s["cancelled"], s["expired"])

    def drain(self) -> None:
        """Run the scheduler until no work remains (all requests reach a
        terminal state: finished, cancelled or expired).

        Watchdog (DESIGN.md §5.6): ``no_progress_limit`` consecutive
        zero-progress steps with work still pending raise a typed
        ``NoProgressError`` instead of spinning forever — the failure
        mode of a queue gated behind a quarantine-shrunk pool, or of
        pathological injected alloc/share-failure rates."""
        idle = 0
        while True:
            before = self._progress_marker()
            if not self.step():
                return
            if self._progress_marker() != before:
                idle = 0
                continue
            idle += 1
            if idle >= self.no_progress_limit:
                gating = {
                    "queued": len(self.queue),
                    "resident": sum(
                        1 for r in self.slot_req if r is not None
                    ),
                    "free_pages": (
                        self.allocator.free_count() if self.paged else None
                    ),
                    "usable_pages": (
                        self.allocator.usable_pages() if self.paged else None
                    ),
                    "quarantined": (
                        len(self.allocator.quarantined_pages)
                        + len(self.allocator.doomed_pages)
                        if self.paged else 0
                    ),
                    "chaos_alloc_fail_p": self.cfg.chaos_alloc_fail_p,
                    "chaos_share_fail_p": self.cfg.chaos_share_fail_p,
                }
                raise NoProgressError(
                    f"drain() made no progress for {idle} consecutive "
                    f"steps: {gating}"
                )

    def run(self, requests: list[Request]) -> list[Request]:
        self.submit(requests)
        self.drain()
        return requests
