"""Batched serving engine: continuous prefill + decode over a KV cache.

The memory-policy engine drives two serving decisions (DESIGN.md §5):

* KV residency per layer (`engine.kv_policy`): decode KV is a zero-reuse
  stream (the paper's throughput-sensitive class) — STREAM via the
  split-KV decode kernel; fixed-source caches (whisper enc K/V, vision
  patch K/V) are RESIDENT (reused every step, fetched once).
* Split-count planning for flash-decoding (`kernels.decode_attention.ops`).

``ServeEngine`` keeps request slots (static batch), admits new requests by
prefilling into free slots, and steps all live slots together — simple
continuous batching.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits[:, -1], axis=-1)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_len: int, extras: dict[str, Any] | None = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.extras = extras or {}
        self.cache = self.model.init_cache(
            params, batch=batch_slots, max_len=max_len, **self.extras
        )
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(self.model.prefill)
        self.live: dict[int, Request] = {}

    # NOTE on the single-cursor cache: the uniform-cursor layout keeps the
    # dry-run/step functions static-shaped; slots admitted together share a
    # prompt window (padded).  Continuous batching with ragged lengths uses
    # the `lengths`-aware decode kernel at the attention level.
    def admit(self, requests: list[Request]) -> None:
        assert len(requests) <= self.slots
        pad_to = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.slots, pad_to), np.int32)
        for i, r in enumerate(requests):
            r.slot = i
            toks[i, pad_to - len(r.prompt):] = r.prompt  # left-pad
            self.live[i] = r
        logits, self.cache = self._prefill(
            self.params, self.cache, jnp.asarray(toks)
        )
        nxt = np.asarray(greedy_sample(logits))
        for r in requests:
            r.generated.append(int(nxt[r.slot]))

    def step(self) -> None:
        toks = np.zeros((self.slots, 1), np.int32)
        for slot, r in self.live.items():
            toks[slot, 0] = r.generated[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks)
        )
        nxt = np.asarray(greedy_sample(logits))
        finished = []
        for slot, r in self.live.items():
            r.generated.append(int(nxt[slot]))
            if len(r.generated) >= r.max_new_tokens:
                r.done = True
                finished.append(slot)
        for slot in finished:
            del self.live[slot]

    def run(self, requests: list[Request]) -> list[Request]:
        self.admit(requests)
        while self.live:
            self.step()
        return requests
