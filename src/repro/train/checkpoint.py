"""Sharded, async, atomic checkpointing with resharding restore.

Layout (one directory per step):

    <dir>/step_000100/
        manifest.msgpack    {step, tree structure, per-leaf shape/dtype}
        leaf_00000.npy ...  one file per pytree leaf (host-gathered)
        _DONE               atomic publish marker (written last)

* **Atomic**: written into ``step_<k>.tmp`` then os.rename'd; readers only
  trust directories containing ``_DONE``.  A crash mid-write never corrupts
  the latest checkpoint.
* **Async**: ``save_async`` snapshots to host (blocking only on device->host
  copy) and writes files on a background thread — training continues.
* **Resharding restore**: leaves are stored unsharded; ``restore`` places
  them onto whatever mesh/shardings the *new* topology wants — this is the
  elastic-rescale path (restart on a different mesh shape).
* **Retention**: ``keep`` most-recent checkpoints are preserved.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any

import jax
import msgpack
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [
        ("/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path), v)
        for path, v in flat
    ]


def save(state: Any, directory: str, step: int, keep: int = 3) -> str:
    """Synchronous checkpoint write. Returns the published path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _leaf_paths(state)
    # One batched transfer for the whole tree (R001): per-leaf
    # device_get pays one blocking device round-trip per parameter.
    host_leaves = jax.device_get([leaf for _, leaf in leaves])
    manifest = {"step": step, "leaves": []}
    for i, ((path, _), arr) in enumerate(zip(leaves, host_leaves)):
        arr = np.asarray(arr)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": path, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    with open(os.path.join(tmp, "_DONE"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


class AsyncCheckpointer:
    """Snapshot on the caller thread, write on a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, state: Any, step: int) -> None:
        self.wait()
        host_state = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state
        )

        def _write():
            try:
                save(host_state, self.directory, step, keep=self.keep)
            except Exception as e:  # pragma: no cover
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            raise self.last_error


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "_DONE")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int | None = None, shardings: Any = None,
            template: Any = None) -> tuple[Any, int]:
    """Load a checkpoint; optionally placing leaves onto ``shardings``
    (a pytree of NamedShardings matching the tree) for elastic restore."""
    step = latest_step(directory) if step is None else step
    assert step is not None, f"no checkpoint under {directory}"
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())

    by_path = {}
    for rec in manifest["leaves"]:
        arr = np.load(os.path.join(path, rec["file"]))
        by_path[rec["path"]] = arr

    assert template is not None, "restore needs a template pytree"
    shard_leaves = (
        _leaf_paths(shardings) if shardings is not None else None
    )
    shard_map = dict(shard_leaves) if shard_leaves else {}

    flat = jax.tree_util.tree_flatten_with_path(template)
    out_leaves = []
    for pathkeys, tmpl in flat[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in pathkeys
        )
        arr = by_path[key]
        assert tuple(arr.shape) == tuple(tmpl.shape), (key, arr.shape, tmpl.shape)
        if key in shard_map:
            out_leaves.append(jax.device_put(arr, shard_map[key]))
        else:
            out_leaves.append(
                jax.numpy.asarray(arr, dtype=tmpl.dtype)
            )
    tree = jax.tree_util.tree_unflatten(flat[1], out_leaves)
    return tree, step


def _gc(directory: str, keep: int) -> None:
    done = sorted(
        n for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp")
        and os.path.exists(os.path.join(directory, n, "_DONE"))
    )
    for n in done[:-keep]:
        shutil.rmtree(os.path.join(directory, n))
