"""Fault-tolerant training loop.

Production behaviours, all testable on one host:

* **Auto-resume**: restores the newest valid checkpoint (atomic-publish
  markers) and replays the deterministic data stream from that step.
* **Preemption**: SIGTERM/SIGINT set a flag; the loop finishes the in-flight
  step, writes a final checkpoint, and exits cleanly (exit early, never
  corrupt).
* **Straggler watchdog**: per-step wall time vs an EWMA baseline; slow steps
  are flagged through a callback — at fleet scale this is the hook that
  triggers hot-spare pod replacement; here it logs and counts.
* **Async checkpointing** every ``ckpt_every`` steps (write overlaps train).
* **NaN fuse**: a non-finite loss halts before it can poison the stream of
  checkpoints.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0     # step > factor x EWMA -> flagged
    ewma_alpha: float = 0.1
    handle_signals: bool = True


@dataclasses.dataclass
class LoopReport:
    final_step: int = 0
    losses: list = dataclasses.field(default_factory=list)
    straggler_steps: list = dataclasses.field(default_factory=list)
    preempted: bool = False
    resumed_from: int | None = None
    step_times: list = dataclasses.field(default_factory=list)


def run(
    train_step: Callable,
    state: Any,
    data_source: Callable[[int], dict],
    config: LoopConfig,
    shardings: Any = None,
    on_straggler: Callable[[int, float], None] | None = None,
    on_step: Callable[[int, dict], None] | None = None,
) -> tuple[Any, LoopReport]:
    report = LoopReport()
    preempt = {"flag": False}

    def _handler(signum, frame):
        preempt["flag"] = True

    old_handlers = {}
    if config.handle_signals:
        for sig in (signal.SIGTERM, signal.SIGINT):
            old_handlers[sig] = signal.signal(sig, _handler)

    start_step = 0
    latest = ckpt.latest_step(config.ckpt_dir)
    if latest is not None:
        state, start_step = ckpt.restore(
            config.ckpt_dir, shardings=shardings, template=state
        )
        report.resumed_from = start_step
    saver = ckpt.AsyncCheckpointer(config.ckpt_dir, keep=config.keep)

    ewma = None
    step = start_step
    steps_in_run = 0
    try:
        while step < config.total_steps:
            # perf_counter, not time.time (R004): an NTP step would make
            # dt negative/huge and poison the straggler-watchdog EWMA.
            t0 = time.perf_counter()
            batch = data_source(step)
            state, metrics = train_step(state, batch)
            # Per-step sync is the NaN fuse: the next line must observe
            # this step's loss before we commit to another step.
            loss = float(jax.device_get(metrics["loss"]))  # repro-lint: disable=R001 -- NaN fuse requires per-step observation
            dt = time.perf_counter() - t0
            report.step_times.append(dt)

            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}: {loss}")
            report.losses.append(loss)

            # Straggler watchdog (EWMA-baselined).  The first step of a run
            # is excluded — it carries compile/init cost and would inflate
            # the baseline.
            if steps_in_run == 0:
                pass
            elif ewma is None:
                ewma = dt
            else:
                if dt > config.straggler_factor * ewma:
                    report.straggler_steps.append(step)
                    if on_straggler is not None:
                        on_straggler(step, dt / ewma)
                ewma = (1 - config.ewma_alpha) * ewma + config.ewma_alpha * dt
            steps_in_run += 1

            step += 1
            if on_step is not None:
                on_step(step, metrics)
            if step % config.ckpt_every == 0 or step == config.total_steps:
                saver.save(state, step)
            if preempt["flag"]:
                report.preempted = True
                saver.save(state, step)
                break
    finally:
        saver.wait()
        if config.handle_signals:
            for sig, h in old_handlers.items():
                signal.signal(sig, h)

    report.final_step = step
    return state, report
