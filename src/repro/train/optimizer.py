"""AdamW with mixed precision, schedules (cosine / WSD), optional ZeRO-1.

Pure JAX (no optax): state is a pytree {mu, nu, count} with fp32 master
moments; params may be bf16 (master-quality updates are computed in fp32
and cast back).  ZeRO-1 sharding of optimizer state over the data axis is
expressed purely through shardings (the update math is elementwise, so
GSPMD partitions it for free) — see ``opt_shardings``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"        # "cosine" | "wsd" | "const"
    decay_frac: float = 0.1         # WSD: final fraction of steps that decay
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Warmup + (cosine | warmup-stable-decay | const)."""
    stepf = step.astype(jnp.float32)
    warm = jnp.minimum(stepf / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (stepf - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    if cfg.schedule == "cosine":
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * t)
        )
    elif cfg.schedule == "wsd":
        # MiniCPM warmup-stable-decay: constant, then linear decay in the
        # final decay_frac of training.
        decay_start = 1.0 - cfg.decay_frac
        frac = jnp.where(
            t < decay_start,
            1.0,
            1.0 - (1 - cfg.min_lr_frac) * (t - decay_start) / cfg.decay_frac,
        )
    else:
        frac = jnp.ones_like(t)
    return cfg.lr * warm * frac


def init_state(params: Any) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)
    ]
    return jnp.sqrt(sum(leaves))


def _is_matrix(path: str, p) -> bool:
    return p.ndim >= 2


def apply_updates(
    params: Any, grads: Any, state: dict[str, Any], cfg: AdamWConfig
) -> tuple[Any, dict[str, Any], dict[str, jnp.ndarray]]:
    count = state["count"] + 1
    lr = schedule_lr(cfg, count)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1 ** count.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


def opt_shardings(param_shardings: Any, params_shape: Any, mesh,
                  zero1: bool = False):
    """Shardings for optimizer state.

    ``zero1=True`` additionally shards each moment's first replicated,
    data-divisible dim over the data axis (ZeRO-1): memory/chip for mu/nu
    drops by |data|; the elementwise update is partitioned by GSPMD with no
    extra logic here.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    data_size = mesh.shape["data"]

    def moment(s, shape):
        if not zero1:
            return s
        spec = tuple(s.spec) + (None,) * (len(shape) - len(s.spec))
        used = {a for part in spec if part for a in
                ((part,) if isinstance(part, str) else part)}
        if "data" in used:
            return s
        for i, part in enumerate(spec):
            if part is None and shape[i] % data_size == 0 and shape[i] >= data_size:
                new = list(spec)
                new[i] = "data"
                return NamedSharding(s.mesh, P(*new))
        return s

    mu = jax.tree_util.tree_map(
        lambda s, x: moment(s, tuple(x.shape)), param_shardings, params_shape
    )
    return {
        "mu": mu,
        "nu": mu,
        "count": NamedSharding(mesh, P()),
    }
