"""Train-step builder: loss + grad + AdamW update under pjit.

The activation (remat) policy is assigned by the cache-policy engine — the
paper's technique applied at the trainer level.  Gradients are reduced in a
configurable dtype (bf16 reduction halves collective bytes — a §Perf knob)
and flushed through the rinse scheduler's bucket order when microbatched.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.remat import RematPolicy
from repro.models import build_model
from repro.models import common as model_common
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = dataclasses.field(default_factory=opt.AdamWConfig)
    remat: RematPolicy = RematPolicy.SAVE_DOTS
    microbatch: int = 1               # grad-accumulation splits per step
    grad_reduce_dtype: str = "float32"  # "bfloat16" halves collective bytes
    zero1: bool = False
    batch_axes: tuple = ("data",)     # mesh axes the batch dim shards over


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics)."""
    model = build_model(cfg)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat=tcfg.remat)
        return loss, metrics

    def single_grad(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def train_step(state, batch):
        params = state["params"]
        if tcfg.microbatch > 1:
            mb = tcfg.microbatch

            def split(x):
                b = x.shape[0]
                x = x.reshape(mb, b // mb, *x.shape[1:])
                if tcfg.batch_axes:
                    # Keep the per-microbatch batch dim sharded over data —
                    # without this GSPMD may shard the microbatch dim
                    # instead and replicate every activation.
                    from jax.sharding import PartitionSpec as P

                    x = jax.lax.with_sharding_constraint(
                        x, P(None, tcfg.batch_axes)
                    )
                return x

            batches = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mbatch):
                loss_acc, grads_acc = carry
                loss, _, grads = single_grad(params, mbatch)
                grads = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(a.dtype), grads_acc, grads
                )
                return (loss_acc + loss, grads), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.dtype(tcfg.grad_reduce_dtype)),
                params,
            )
            (loss, grads), _ = model_common.scan(
                acc_body, (jnp.zeros((), jnp.float32), zeros), batches
            )
            loss = loss / mb
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        else:
            loss, metrics, grads = single_grad(params, batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.dtype(tcfg.grad_reduce_dtype)), grads
            )

        new_params, new_opt, stats = opt.apply_updates(
            params, grads, state["opt"], tcfg.adamw
        )
        metrics = {"loss": loss, **metrics, **stats}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step, model


def init_train_state(model, key) -> dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt": opt.init_state(params)}


def state_shardings(cfg: ModelConfig, mesh, params_shape, zero1: bool = False):
    from repro.distributed import sharding as sh

    pshard = sh.params_shardings(params_shape["params"], cfg, mesh)
    oshard = opt.opt_shardings(pshard, params_shape["params"], mesh, zero1=zero1)
    return {"params": pshard, "opt": oshard}


@functools.cache
def eval_shape_state(arch: str, smoke: bool = False):
    """Shape-only train state (no allocation) for sharding/dry-run."""
    from repro.models import get_config

    cfg = get_config(arch, smoke=smoke)
    model = build_model(cfg)

    def make():
        return init_train_state(model, jax.random.PRNGKey(0))

    return cfg, model, jax.eval_shape(make)
