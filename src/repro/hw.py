"""Hardware model constants for the TARGET platform (TPU v5e) and roofline math.

This container executes on CPU; these constants define the machine the
framework is designed for and drive the analytical cost model, the VMEM
allocator and the roofline analysis of the dry-run artifacts.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Chip:
    """One TPU chip (v5e by default)."""

    name: str = "tpu-v5e"
    # Compute
    peak_flops_bf16: float = 197e12     # FLOP/s
    peak_flops_fp32: float = 197e12 / 4  # no fp32 MXU path; conservative
    mxu_dim: int = 128                  # systolic array is 128x128
    vpu_lanes: int = 8 * 128            # (8, 128) vector registers
    # Memory
    hbm_bytes: int = 16 * 1024**3       # 16 GB
    hbm_bw: float = 819e9               # B/s
    vmem_bytes: int = 128 * 1024 * 1024  # 128 MB software-managed scratchpad
    # Interconnect
    ici_bw_per_link: float = 50e9       # B/s per ICI link (per direction)
    ici_links: int = 4                  # 2D torus on v5e: 4 links/chip
    # DMA / burst granularity used by the rinse (write-contiguity) model.
    hbm_burst_bytes: int = 512
    # Fraction of VMEM the planner may claim (leave headroom for compiler
    # temporaries / semaphores / double-buffer bookkeeping).
    vmem_budget_frac: float = 0.75

    @property
    def vmem_budget(self) -> int:
        return int(self.vmem_bytes * self.vmem_budget_frac)

    @property
    def ridge_intensity_bf16(self) -> float:
        """FLOP/byte at which compute and HBM time balance."""
        return self.peak_flops_bf16 / self.hbm_bw


V5E = Chip()

# Calibrated model of the paper's simulated system (Table 1): 64-CU GCN3 APU,
# ~12.3 TFLOP/s fp32, HBM2 @ 512 GB/s, 4 MB GPU L2 (the "cache capacity" that
# plays VMEM's role in the reproduction benches), 2 KB DRAM rows.
PAPER_GPU = Chip(
    name="gem5-apu",
    peak_flops_bf16=12.3e12,   # single-rate fp32 machine; bf16 field = fp32 rate
    peak_flops_fp32=12.3e12,
    mxu_dim=64,                # wavefront/LDS tile granularity
    vpu_lanes=64,
    hbm_bytes=16 * 1024**3,
    hbm_bw=512e9,
    vmem_bytes=4 * 1024 * 1024,  # GPU L2 as the residency capacity
    ici_bw_per_link=0.0,
    ici_links=1,
    hbm_burst_bytes=2048,      # DRAM row-buffer granule
    vmem_budget_frac=0.9,
)

# Default pod geometry for this project (see launch/mesh.py).
PODS = 2
CHIPS_PER_POD = 256          # 16 x 16
POD_MESH = (16, 16)          # (data, model)
MULTIPOD_MESH = (2, 16, 16)  # (pod, data, model)

DTYPE_BYTES = {
    "float32": 4, "f32": 4,
    "bfloat16": 2, "bf16": 2,
    "float16": 2, "f16": 2,
    "float64": 8, "f64": 8,
    "int8": 1, "s8": 1, "u8": 1,
    "int32": 4, "s32": 4, "u32": 4,
    "int64": 8, "s64": 8, "u64": 8,
    "bool": 1, "pred": 1,
}


def dtype_bytes(dtype) -> int:
    """Bytes per element for a numpy/jax dtype or short HLO name."""
    s = str(dtype)
    if s in DTYPE_BYTES:
        return DTYPE_BYTES[s]
    import numpy as np

    return np.dtype(dtype).itemsize


def flops_time(flops: float, chip: Chip = V5E, dtype: str = "bf16") -> float:
    peak = chip.peak_flops_bf16 if dtype_bytes(dtype) <= 2 else chip.peak_flops_fp32
    return flops / peak


def hbm_time(num_bytes: float, chip: Chip = V5E) -> float:
    return num_bytes / chip.hbm_bw


def ici_time(num_bytes: float, chip: Chip = V5E, links: int | None = None) -> float:
    links = chip.ici_links if links is None else links
    return num_bytes / (chip.ici_bw_per_link * links)
