"""Rule R006: Pallas grid/BlockSpec consistency.

Every ``pl.pallas_call`` in ``kernels/*`` encodes the same contract:
the grid is ceil-div arithmetic over padded operand dims, each
BlockSpec's index map takes exactly one positional argument per grid
axis, and the index map returns one coordinate per block-shape axis.
Getting any of these wrong compiles fine and silently reads the wrong
tiles (or misses the operand tail entirely) — the worst kind of kernel
bug, because interpret-mode smoke tests on exact-multiple shapes pass.
"""
from __future__ import annotations

import ast

from repro.lint.core import Rule, register


@register
class PallasGridShape(Rule):
    id = "R006"
    title = "pallas-grid-shape"
    invariant = (
        "For each pl.pallas_call: grid arithmetic uses ceil-div (cdiv/"
        "round_up or a proven-exact floor-div), every BlockSpec index "
        "map takes one positional arg per grid axis, and the index map "
        "returns one coordinate per block-shape axis — otherwise tiles "
        "beyond the operand tail are silently skipped or misaddressed."
    )

    def check(self, module):
        findings = []
        for call in ast.walk(module.tree):
            if not isinstance(call, ast.Call):
                continue
            name = module.resolver.dotted(call.func)
            if not name or not name.endswith(".pallas_call"):
                continue
            findings.extend(self._check_call(module, call))
        return findings

    # ------------------------------------------------------------------

    def _check_call(self, module, call):
        findings = []
        func = module.enclosing_function(call)
        kwargs = {kw.arg: kw.value for kw in call.keywords if kw.arg}

        # grid/in_specs/out_specs may live inside a grid_spec object
        # (pltpu.PrefetchScalarGridSpec) instead of the pallas_call
        # kwargs; unwrap it so scalar-prefetch kernels get the same
        # checks.  Scalar-prefetch refs are passed to index maps as
        # trailing positional args, so the accepted arity grows by
        # num_scalar_prefetch.
        n_prefetch = self._unwrap_grid_spec(module, func, kwargs)

        grid_node = kwargs.get("grid")
        grid_len, grid_elts = self._resolve_grid(module, func, grid_node)

        # -- grid arithmetic: floor-div without exactness evidence ------
        for elt in grid_elts:
            findings.extend(
                self._check_grid_elt(module, func, elt, depth=0)
            )

        # -- BlockSpecs -------------------------------------------------
        for spec in self._iter_blockspecs(module, func, kwargs):
            findings.extend(
                self._check_blockspec(
                    module, func, spec, grid_len, n_prefetch
                )
            )
        return findings

    def _unwrap_grid_spec(self, module, func, kwargs):
        """Merge a PrefetchScalarGridSpec's grid/in_specs/out_specs into
        ``kwargs`` (in place); return its num_scalar_prefetch (else 0)."""
        node = kwargs.get("grid_spec")
        if node is None:
            return 0
        if isinstance(node, ast.Name) and func is not None:
            resolved = _nearest_assignment(func, node.id, node.lineno)
            if resolved is not None:
                node = resolved
        if not isinstance(node, ast.Call):
            return 0
        cname = module.resolver.dotted(node.func) or ""
        if not cname.endswith("GridSpec"):
            return 0
        gs_kwargs = {kw.arg: kw.value for kw in node.keywords if kw.arg}
        for key in ("grid", "in_specs", "out_specs"):
            if key in gs_kwargs and key not in kwargs:
                kwargs[key] = gs_kwargs[key]
        n_node = gs_kwargs.get("num_scalar_prefetch")
        if isinstance(n_node, ast.Constant) and isinstance(n_node.value, int):
            return n_node.value
        return 0

    def _resolve_grid(self, module, func, grid_node):
        """Resolve the grid expression to (length | None, element nodes)."""
        if grid_node is None:
            return None, []
        node = grid_node
        if isinstance(node, ast.Name) and func is not None:
            assign = _nearest_assignment(func, node.id, node.lineno)
            if assign is not None:
                node = assign
        if isinstance(node, ast.Tuple):
            return len(node.elts), list(node.elts)
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return 1, [node]
        return None, []

    def _check_grid_elt(self, module, func, elt, depth):
        """Flag ``a // b`` grid terms lacking exactness evidence.

        Exact-by-construction divisions are exempt: the numerator was
        produced by ``cdiv(x, b) * b`` / ``round_up(x, b)``, or the
        enclosing function asserts ``a % b == 0``.  Everything else
        silently drops the operand tail — use cdiv.
        """
        findings = []
        if depth > 4 or func is None:
            return findings
        # Chase names one level: grid elements are often precomputed.
        if isinstance(elt, ast.Name):
            assign = _nearest_assignment(func, elt.id, elt.lineno + 1)
            if assign is not None:
                return self._check_grid_elt(module, func, assign, depth + 1)
            return findings
        for node in ast.walk(elt):
            if not (isinstance(node, ast.BinOp)
                    and isinstance(node.op, ast.FloorDiv)):
                continue
            if self._division_is_exact(module, func, node):
                continue
            findings.append(self.finding(
                module, node,
                "floor-div in Pallas grid arithmetic without exactness "
                "evidence (no cdiv/round_up provenance, no `% == 0` "
                "assert): tiles past the operand tail are silently "
                "skipped; use cdiv",
            ))
        return findings

    def _division_is_exact(self, module, func, binop):
        num_s = _expr_str(module, binop.left)
        den_s = _expr_str(module, binop.right)
        # (a) an assert in the function proves num % den == 0
        for node in ast.walk(func):
            if not isinstance(node, ast.Assert):
                continue
            for cmp in ast.walk(node.test):
                if (isinstance(cmp, ast.BinOp)
                        and isinstance(cmp.op, ast.Mod)
                        and _expr_str(module, cmp.left) == num_s
                        and _expr_str(module, cmp.right) == den_s):
                    return True
        # (b) the numerator is cdiv(x, den) * den or round_up(x, den)
        if isinstance(binop.left, ast.Name):
            assign = _nearest_assignment(func, binop.left.id, binop.lineno)
            if assign is not None and self._is_rounded_multiple(
                module, assign, den_s
            ):
                return True
        return self._is_rounded_multiple(module, binop.left, den_s)

    def _is_rounded_multiple(self, module, node, den_s):
        """Is ``node`` of the form cdiv(x, d)*d or round_up(x, d) with
        d == the divisor (or a multiple expression containing it)?"""
        if (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult)):
            for side, other in ((node.left, node.right),
                                (node.right, node.left)):
                if (_expr_str(module, other) == den_s
                        and isinstance(side, ast.Call)
                        and _callee_name(module, side) in ("cdiv",)):
                    return True
        if isinstance(node, ast.Call) and _callee_name(module, node) in (
            "round_up",
        ):
            if len(node.args) == 2 and _expr_str(
                module, node.args[1]
            ) == den_s:
                return True
        return False

    # ------------------------------------------------------------------

    def _iter_blockspecs(self, module, func, kwargs):
        """Yield every BlockSpec Call reachable from in_specs/out_specs."""
        for key in ("in_specs", "out_specs"):
            node = kwargs.get(key)
            if node is None:
                continue
            if isinstance(node, ast.Name) and func is not None:
                resolved = _nearest_assignment(func, node.id, node.lineno)
                if resolved is not None:
                    node = resolved
            stack = [node]
            while stack:
                cur = stack.pop()
                if isinstance(cur, (ast.List, ast.Tuple)):
                    stack.extend(cur.elts)
                elif isinstance(cur, ast.IfExp):
                    stack.extend([cur.body, cur.orelse])
                elif isinstance(cur, ast.Call):
                    cname = module.resolver.dotted(cur.func) or ""
                    if cname.endswith("BlockSpec"):
                        yield cur

    def _check_blockspec(self, module, func, spec, grid_len, n_prefetch=0):
        findings = []
        kwargs = {kw.arg: kw.value for kw in spec.keywords if kw.arg}
        shape_node = spec.args[0] if spec.args else kwargs.get(
            "block_shape"
        )
        map_node = spec.args[1] if len(spec.args) > 1 else kwargs.get(
            "index_map"
        )
        shape_lens = set(self._tuple_lens(shape_node))
        for lam in self._iter_lambdas(module, func, map_node):
            n_pos = len(lam.args.args) - len(lam.args.defaults)
            allowed = {grid_len}
            if n_prefetch:
                allowed.add((grid_len or 0) + n_prefetch)
            if grid_len is not None and n_pos not in allowed:
                findings.append(self.finding(
                    module, lam,
                    f"BlockSpec index map takes {n_pos} positional "
                    f"grid argument(s) but the grid has {grid_len} "
                    "axis/axes: the map does not cover the grid",
                ))
            ret_lens = {
                len(lam.body.elts)
            } if isinstance(lam.body, ast.Tuple) else set()
            if shape_lens and ret_lens and not (shape_lens & ret_lens):
                findings.append(self.finding(
                    module, lam,
                    f"BlockSpec index map returns "
                    f"{sorted(ret_lens)[0]} coordinate(s) but the block "
                    f"shape has {sorted(shape_lens)[0]} axis/axes: "
                    "block addressing is misaligned",
                ))
        return findings

    def _tuple_lens(self, node):
        """Possible block-shape tuple lengths (IfExp yields both arms)."""
        if node is None:
            return []
        if isinstance(node, ast.Tuple):
            return [len(node.elts)]
        if isinstance(node, ast.IfExp):
            return self._tuple_lens(node.body) + self._tuple_lens(
                node.orelse
            )
        return []

    def _iter_lambdas(self, module, func, node):
        if node is None:
            return
        if isinstance(node, ast.Lambda):
            yield node
        elif isinstance(node, ast.IfExp):
            yield from self._iter_lambdas(module, func, node.body)
            yield from self._iter_lambdas(module, func, node.orelse)
        elif isinstance(node, ast.Name) and func is not None:
            # A named map may be bound in several branches; check each.
            for assign_val in _all_assignments(func, node.id):
                if isinstance(assign_val, (ast.Lambda, ast.IfExp)):
                    yield from self._iter_lambdas(module, func, assign_val)


# --------------------------------------------------------------------------
# Local constant-ish propagation helpers


def _nearest_assignment(func, name, before_line):
    """The value of the lexically nearest ``name = ...`` above a line."""
    best, best_line = None, -1
    for node in ast.walk(func):
        if not isinstance(node, ast.Assign):
            continue
        if node.lineno >= before_line or node.lineno <= best_line:
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name) and tgt.id == name:
                best, best_line = node.value, node.lineno
    return best


def _all_assignments(func, name):
    out = []
    for node in ast.walk(func):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == name:
                    out.append(node.value)
    return out


def _expr_str(module, node):
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - ancient nodes
        return ast.dump(node)


def _callee_name(module, call):
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None
