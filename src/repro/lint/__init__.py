"""repro.lint — a JAX/Pallas-aware static-analysis pass for this repo.

The serve tier's performance and determinism rest on invariants that are
easy to break silently: at most one host sync per decode chunk, no
Python-value-dependent shapes inside jitted dispatch, donated buffers
never read after the donating call, refcount-balanced page alloc/release
on every path, reproducible iteration order, and Pallas grids that
actually cover their operands.  ``repro.lint`` encodes each invariant as
a rule over the stdlib ``ast`` plus a lightweight device-taint dataflow
(no third-party dependencies), so CI catches violations before a bench
regresses or a chaos run flakes.

Usage::

    PYTHONPATH=src python -m repro.lint src benchmarks examples
    PYTHONPATH=src python -m repro.lint --list-rules

Suppression: append ``# repro-lint: disable=R001 -- reason`` to the
offending line (or the line just above).  Grandfathered findings live in
``lint_baseline.json`` at the repo root; see DESIGN.md §6 for policy.
"""
from repro.lint.core import (  # noqa: F401
    Finding,
    LintResult,
    all_rules,
    analyze_source,
    run_lint,
)
from repro.lint.baseline import load_baseline, write_baseline  # noqa: F401

# Importing the rule modules registers every rule with the registry.
from repro.lint import rules_sync  # noqa: F401,E402
from repro.lint import rules_determinism  # noqa: F401,E402
from repro.lint import rules_pallas  # noqa: F401,E402
