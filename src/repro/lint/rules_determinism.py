"""Rules R004–R005: reproducibility and refcount balance.

R004 guards the bit-identity contracts every CI gate relies on
(paged==contiguous, spec==plain, fault==fault-free): dispatch order
must not flow through unordered sets, and serve/core paths must not
read wall clocks or unseeded RNGs.  R005 guards the page-pool ledger
(PR 3/5/6): every alloc/share must reach a release/free/quarantine or
escape into owned state on every non-raising path — a leaked page is
capacity gone until restart.
"""
from __future__ import annotations

import ast

from repro.lint.core import Rule, register

# R004's clock/RNG prongs apply only to deterministic-by-contract tiers;
# benchmarks and examples may legitimately read wall clocks.
_DETERMINISTIC_PATHS = ("/serve/", "/core/", "/train/", "/launch/")


def _in_deterministic_tier(path):
    p = "/" + path.replace("\\", "/").lstrip("/")
    return any(seg in p for seg in _DETERMINISTIC_PATHS)


# Consuming a set through one of these makes iteration order moot.
_ORDER_INSENSITIVE = {"sorted", "min", "max", "sum", "len", "set",
                      "frozenset", "any", "all", "Counter"}


def _feeds_order_insensitive(module, iter_node):
    """True when the set iteration's result flows straight into an
    order-insensitive consumer (``sorted(x for x in some_set)``)."""
    cur = iter_node
    for _ in range(4):
        cur = module.parent(cur)
        if cur is None:
            return False
        if isinstance(cur, ast.Call):
            f = cur.func
            name = (f.id if isinstance(f, ast.Name)
                    else f.attr if isinstance(f, ast.Attribute) else None)
            return name in _ORDER_INSENSITIVE
        if isinstance(cur, ast.stmt):
            return False
    return False


@register
class Nondeterminism(Rule):
    id = "R004"
    title = "nondeterminism"
    invariant = (
        "Serve/core behavior must be a pure function of (requests, "
        "seeds): no iteration over sets feeding dispatch order (hash "
        "randomization reorders them across runs), no time.time() "
        "(non-monotonic under NTP steps; use time.perf_counter for "
        "intervals), no unseeded or global-state RNGs outside the "
        "explicitly-seeded chaos knobs."
    )

    def check(self, module):
        findings = []
        deterministic = _in_deterministic_tier(module.path)
        for ev in module.analysis.events:
            if ev.kind == "set_iter":
                if _feeds_order_insensitive(module, ev.node):
                    continue
                findings.append(self.finding(
                    module, ev.node,
                    "iterating a set: order varies under hash "
                    "randomization and can reorder dispatch; wrap in "
                    "sorted(...) or use an ordered structure",
                ))
            elif ev.kind == "time_time" and deterministic:
                findings.append(self.finding(
                    module, ev.node,
                    "time.time() is non-monotonic (NTP steps make "
                    "intervals negative or huge); use "
                    "time.perf_counter() for intervals or pass "
                    "timestamps in explicitly",
                ))
            elif ev.kind == "unseeded_rng" and deterministic:
                findings.append(self.finding(
                    module, ev.node,
                    f"{ev.detail}: unseeded or global-state RNG in a "
                    "deterministic tier; thread an explicitly-seeded "
                    "np.random.default_rng(seed) through instead",
                ))
        return findings


# --------------------------------------------------------------------------
# R005: path-sensitive alloc/release balance


_ACQUIRE_METHODS = {"alloc"}
_CHECK_METHODS = {"share"}
_RELEASE_METHODS = {"release", "free", "quarantine"}

_MAX_PATHS = 256

# Per-path variable states.
_PENDING = "pending"      # holds pages, not yet consumed
_NONE = "none"            # proven None (alloc refused)
_CONSUMED = "consumed"    # released/escaped/returned


@register
class RefcountBalance(Rule):
    id = "R005"
    title = "refcount-balance"
    invariant = (
        "Every .alloc(...) result must, on every non-raising path, be "
        "released/freed/quarantined, stored into owned state, returned, "
        "or passed on — and every .share(...) verdict must be checked. "
        "A dropped page list leaks pool capacity until restart "
        "(check_invariants() only catches it after the damage)."
    )

    def check(self, module):
        findings = []
        for func in ast.walk(module.tree):
            if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if _defines_allocator_api(func):
                continue  # the allocator's own methods
            findings.extend(self._check_function(module, func))
        return findings

    def _check_function(self, module, func):
        findings = []
        allocs = {}       # var name -> alloc Call node
        own_stmts = _own_statements(func)
        for stmt in own_stmts:
            # Bare-expression alloc/share: result dropped unchecked.
            if isinstance(stmt, ast.Expr) and isinstance(
                stmt.value, ast.Call
            ):
                m = _method_name(stmt.value)
                if m in _ACQUIRE_METHODS:
                    findings.append(self.finding(
                        module, stmt.value,
                        ".alloc(...) result dropped: the returned pages "
                        "are held by the allocator but unowned — "
                        "permanent pool leak",
                    ))
                elif m in _CHECK_METHODS:
                    findings.append(self.finding(
                        module, stmt.value,
                        ".share(...) verdict dropped: a refused share "
                        "(chaos injection, quarantined page) goes "
                        "unnoticed and the refcount ledger diverges",
                    ))
            if isinstance(stmt, ast.Assign) and isinstance(
                stmt.value, ast.Call
            ):
                m = _method_name(stmt.value)
                if m in _ACQUIRE_METHODS:
                    for tgt in stmt.targets:
                        if isinstance(tgt, ast.Name):
                            allocs[tgt.id] = stmt.value
        if not allocs:
            return findings
        # Enumerate acyclic paths; find a path where some alloc'd var
        # stays pending (non-None, never consumed) at exit.
        leaks = _find_leaks(func, allocs)
        for var, call in sorted(leaks.items()):
            findings.append(self.finding(
                module, call,
                f"pages alloc'd into `{var}` are not released, freed, "
                "quarantined, stored, or returned on every non-raising "
                "path: leaked pool capacity on the unbalanced path",
            ))
        return findings


def _defines_allocator_api(func):
    return func.name in (_ACQUIRE_METHODS | _CHECK_METHODS
                         | _RELEASE_METHODS)


def _method_name(call):
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    return None


def _own_statements(func):
    """Statements of ``func`` excluding nested function/class bodies."""
    out = []

    def visit(stmts):
        for s in stmts:
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
                continue
            out.append(s)
            for block in _child_blocks(s):
                visit(block)

    visit(func.body)
    return out


def _child_blocks(stmt):
    blocks = []
    for field in ("body", "orelse", "finalbody"):
        b = getattr(stmt, field, None)
        if isinstance(b, list):
            blocks.append(b)
    for handler in getattr(stmt, "handlers", []) or []:
        blocks.append(handler.body)
    return blocks


def _find_leaks(func, allocs):
    """Return {var: alloc_call} for vars pending at the end of any path."""
    leaks = {}
    init = {v: None for v in allocs}
    cont, exited, broke = _exec_block(func.body, [init], allocs)
    for final in cont + exited + broke:
        for var, state in final.items():
            if state == _PENDING and var not in leaks:
                leaks[var] = allocs[var]
    return leaks


def _exec_block(stmts, states, allocs):
    """Abstractly execute ``stmts`` over each incoming path state.

    Returns ``(fallthrough, exited, broke)``: states that fall off the
    end, states that left via ``return``, and states that left via
    ``break``/``continue`` (resolved by the nearest enclosing loop).
    Raising paths are dropped — R005's contract covers non-raising
    paths only.
    """
    exited, broke = [], []
    for stmt in stmts:
        nxt = []
        for st in states[:_MAX_PATHS]:
            c, e, b = _exec_stmt(stmt, st, allocs)
            nxt.extend(c)
            exited.extend(e)
            broke.extend(b)
        states = nxt
        if not states:
            break
    return states, exited, broke


def _exec_stmt(stmt, state, allocs):
    """Execute one statement on one path state."""
    if isinstance(stmt, ast.Raise):
        return [], [], []
    if isinstance(stmt, ast.Return):
        if stmt.value is not None:
            _consume_uses(stmt.value, state)
        return [], [dict(state)], []
    if isinstance(stmt, (ast.Break, ast.Continue)):
        return [], [], [dict(state)]

    if isinstance(stmt, ast.Assign) and isinstance(stmt.value, ast.Call) \
            and _method_name(stmt.value) in _ACQUIRE_METHODS:
        _consume_uses(stmt.value, state)
        st = dict(state)
        for tgt in stmt.targets:
            if isinstance(tgt, ast.Name) and tgt.id in allocs:
                st[tgt.id] = _PENDING
        return [st], [], []

    if isinstance(stmt, ast.If):
        refined = _refine_none(stmt.test, state)
        if refined is not None:
            true_state, false_state = refined
        else:
            _consume_uses(stmt.test, state)
            true_state, false_state = dict(state), dict(state)
        c1, e1, b1 = _exec_block(stmt.body, [true_state], allocs)
        c2, e2, b2 = _exec_block(stmt.orelse, [false_state], allocs)
        return c1 + c2, e1 + e2, b1 + b2

    if isinstance(stmt, (ast.For, ast.While, ast.AsyncFor)):
        if isinstance(stmt, ast.While):
            _consume_uses(stmt.test, state)
        else:
            _consume_uses(stmt.iter, state)
        # One-or-zero iterations is enough to observe per-iteration
        # release patterns; break/continue land after the loop.
        bc, be, bb = _exec_block(stmt.body, [dict(state)], allocs)
        after = [dict(state)] + bc + bb
        if stmt.orelse:
            oc, oe, ob = _exec_block(stmt.orelse, after, allocs)
            return oc, be + oe, ob
        return after, be, []

    if isinstance(stmt, ast.Try):
        bc, be, bb = _exec_block(stmt.body, [dict(state)], allocs)
        through = list(bc)
        for handler in stmt.handlers:
            hc, he, hb = _exec_block(handler.body, [dict(state)], allocs)
            through.extend(hc)
            be.extend(he)
            bb.extend(hb)
        if stmt.orelse:
            oc, oe, ob = _exec_block(stmt.orelse, through, allocs)
            through, be, bb = oc, be + oe, bb + ob
        if stmt.finalbody:
            fc, fe, fb = _exec_block(stmt.finalbody, through, allocs)
            return fc, be + fe, bb + fb
        return through, be, bb

    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        for item in stmt.items:
            _consume_uses(item.context_expr, state)
        return _exec_block(stmt.body, [dict(state)], allocs)

    # Any other statement: every mention of a tracked var consumes it.
    _consume_uses(stmt, state)
    return [dict(state)], [], []


def _refine_none(test, state):
    """``if X is None: ...`` / ``if X is not None: ...`` / ``if X:`` on
    a tracked var refines its None-ness instead of consuming it.
    Returns (true_state, false_state) or None."""
    var, positive = None, None
    if isinstance(test, ast.Compare) and len(test.ops) == 1 and isinstance(
        test.comparators[0], ast.Constant
    ) and test.comparators[0].value is None and isinstance(
        test.left, ast.Name
    ):
        var = test.left.id
        positive = isinstance(test.ops[0], ast.IsNot)  # True: non-None br.
    elif isinstance(test, ast.Name):
        var, positive = test.id, True
    elif (isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not)
          and isinstance(test.operand, ast.Name)):
        var, positive = test.operand.id, False
    if var is None or var not in state or state[var] is None:
        return None
    true_state, false_state = dict(state), dict(state)
    if state[var] == _PENDING:
        if positive:
            false_state[var] = _NONE
        else:
            true_state[var] = _NONE
    return (true_state, false_state)


def _consume_uses(node, state):
    """Any Load of a tracked pending var consumes it (released, passed
    on, stored, compared, logged — we only require *some* use on the
    path; the specific release discipline is the allocator's contract)."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and isinstance(
            getattr(sub, "ctx", None), ast.Load
        ):
            if state.get(sub.id) == _PENDING:
                state[sub.id] = _CONSUMED
