"""Command-line entry point: ``python -m repro.lint [paths ...]``.

Exit codes: 0 = clean (modulo suppressions/baseline), 1 = active
findings, 2 = usage or internal error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.lint import baseline as baseline_mod
from repro.lint.core import all_rules, run_lint

DEFAULT_PATHS = ("src", "benchmarks", "examples")
DEFAULT_BASELINE = "lint_baseline.json"


def build_parser():
    ap = argparse.ArgumentParser(
        prog="repro.lint",
        description=("JAX/Pallas-aware static analysis for the serve "
                     "tier's performance & determinism invariants."),
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="emit a JSON report to PATH ('-' = stdout)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE, metavar="PATH",
                    help="baseline file of grandfathered findings "
                         f"(default: {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline file "
                         "and exit 0")
    ap.add_argument("--rules", default=None, metavar="R001,R004",
                    help="comma-separated subset of rule ids to run")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("--root", default=None, metavar="DIR",
                    help="repo root for relative paths (default: cwd)")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.id}  {rule.title}")
            print(f"      {rule.invariant}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        known = {r.id for r in all_rules()}
        unknown = [r for r in rules if r not in known]
        if unknown:
            print(f"unknown rule id(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2

    paths = args.paths or list(DEFAULT_PATHS)
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        print(f"no such path(s): {', '.join(missing)}", file=sys.stderr)
        return 2

    baseline = None
    if not args.no_baseline and not args.write_baseline:
        if os.path.isfile(args.baseline):
            try:
                baseline = baseline_mod.load_baseline(args.baseline)
            except (ValueError, OSError, json.JSONDecodeError) as e:
                print(f"bad baseline: {e}", file=sys.stderr)
                return 2

    result = run_lint(paths, rules=rules, baseline=baseline,
                      root=args.root)

    if args.write_baseline:
        n = baseline_mod.write_baseline(args.baseline, result.findings)
        print(f"wrote {n} baseline entr{'y' if n == 1 else 'ies'} "
              f"({len(result.findings)} finding(s)) -> {args.baseline}")
        return 0

    json_payload = result.to_json()
    if args.json == "-":
        print(json.dumps(json_payload, indent=1, sort_keys=True))
    else:
        _print_human(result)
        if args.json:
            with open(args.json, "w", encoding="utf-8") as f:
                json.dump(json_payload, f, indent=1, sort_keys=True)
                f.write("\n")
            print(f"# wrote {args.json}")

    if result.errors:
        return 2
    return 1 if result.findings else 0


def _print_human(result):
    for f in result.findings:
        print(f.render())
    for path, message in result.errors:
        print(f"{path}: ERROR {message}", file=sys.stderr)
    counts = {}
    for f in result.findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    by_rule = " ".join(f"{k}:{v}" for k, v in sorted(counts.items()))
    print(
        f"repro.lint: {len(result.findings)} finding(s)"
        + (f" [{by_rule}]" if by_rule else "")
        + f", {result.inline_suppressed} inline-suppressed"
        + f", {result.baseline_suppressed} baselined"
        + f" | {len(result.rules_run)} rules over "
        + f"{result.files_checked} files in {result.wall_s:.2f}s"
    )


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
