"""Lightweight device-taint dataflow over the stdlib AST.

This is deliberately *not* a full abstract interpreter.  It is a single
forward pass per function (loop bodies walked twice so loop-carried
taint stabilises) classifying every expression into one of four taint
classes:

* ``DEVICE``  — a jax array / tracer (rooted at ``jnp.*``, ``jax.lax.*``,
  ``jax.random.*``, calls of known-jitted callables, traced parameters).
  Coercing one of these to a Python or numpy value is a host sync.
* ``HOST``    — host memory (numpy results, ``jax.device_get`` output).
  Operating on these is free; they never sync again.
* ``STATIC``  — Python values that are constant under tracing
  (literals, ``.shape``/``.ndim``/``.dtype``, ``static_argnames``
  parameters).  Branching on these inside jit is legitimate.
* ``UNKNOWN`` — everything else (plain parameters, results of calls we
  cannot see).  Rules never flag UNKNOWN values: false-positive control
  beats recall for a CI-gating linter.

The pass emits *events* (host syncs, device-dependent branches, traced
shape construction, set iteration, ...) annotated with their loop and
jit-region context; the rule modules turn events into findings.
"""
from __future__ import annotations

import ast
import dataclasses

DEVICE = "device"
HOST = "host"
STATIC = "static"
UNKNOWN = "unknown"

# Call prefixes whose results live on device (or are tracers under jit).
_DEVICE_PREFIXES = (
    "jax.numpy.", "jax.lax.", "jax.nn.", "jax.random.", "jax.scipy.",
    "jax.image.", "jax.ops.",
)
_DEVICE_CALLS = {"jax.device_put", "jax.vmap", "jax.grad", "jax.value_and_grad",
                 "jax.pmap", "jax.checkpoint", "jax.remat"}
# Structural jax helpers: result taint follows the arguments.
_TREE_CALLS = ("jax.tree_util.", "jax.tree.")
# Attributes that are trace-time constants on any array-like.
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "itemsize"}
# Builtins whose result is a plain Python value derived structurally.
_STATIC_BUILTINS = {"len", "range", "isinstance", "hasattr", "id", "repr",
                    "str", "format", "type"}
# Builtins that pass element taint through.
_PASSTHROUGH_BUILTINS = {"sorted", "list", "tuple", "reversed", "sum", "min",
                         "max", "abs", "zip", "enumerate", "map", "filter",
                         "next", "iter"}
# jnp constructors whose shape argument must be trace-static (R002).
_SHAPE_CTORS = {
    "jax.numpy.zeros": 0, "jax.numpy.ones": 0, "jax.numpy.full": 0,
    "jax.numpy.empty": 0, "jax.numpy.eye": 0, "jax.numpy.arange": None,
    "jax.numpy.broadcast_to": 1, "jax.numpy.reshape": 1,
    "jax.numpy.tile": 1,
}


@dataclasses.dataclass
class Value:
    taint: str = UNKNOWN
    is_set: bool = False          # tracked separately for R004

    @staticmethod
    def join(*values):
        taints = [v.taint for v in values] or [STATIC]
        if DEVICE in taints:
            t = DEVICE
        elif HOST in taints:
            t = HOST
        elif all(t == STATIC for t in taints):
            t = STATIC
        else:
            t = UNKNOWN
        return Value(t, any(v.is_set for v in values))


V_DEVICE = Value(DEVICE)
V_HOST = Value(HOST)
V_STATIC = Value(STATIC)
V_UNKNOWN = Value(UNKNOWN)


# --------------------------------------------------------------------------
# Name resolution through import aliases


class Resolver:
    """Resolve dotted expressions to canonical module paths.

    ``import jax.numpy as jnp`` makes ``jnp.zeros`` resolve to
    ``jax.numpy.zeros``; ``from jax.experimental import pallas as pl``
    makes ``pl.pallas_call`` resolve to
    ``jax.experimental.pallas.pallas_call``.
    """

    def __init__(self, tree: ast.Module):
        self.aliases: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.aliases[a.asname or a.name] = (
                        f"{node.module}.{a.name}"
                    )

    def dotted(self, node):
        """Return the canonical dotted name of an expression, or None."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.aliases.get(node.id, node.id)
        parts.append(head)
        return ".".join(reversed(parts))

    def raw_dotted(self, node):
        """Dotted name WITHOUT alias resolution ('self._prefill')."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        return ".".join(reversed(parts))


# --------------------------------------------------------------------------
# Jit-region discovery


@dataclasses.dataclass
class TracedInfo:
    """Why a function body is traced, and which params are static."""

    kind: str                       # "jit" | "scan_body" | "pallas" | "nested"
    static_names: frozenset = frozenset()
    donate_argnums: tuple = ()


@dataclasses.dataclass
class JitBinding:
    """``target = jax.jit(fn, ...)`` — call sites of ``target`` dispatch
    a jitted computation (device result; donation applies)."""

    target: str                     # raw dotted string, e.g. "self._prefill"
    donate_argnums: tuple = ()
    func_def: object = None


_SCAN_HOFS = {"jax.lax.scan", "jax.lax.while_loop", "jax.lax.cond",
              "jax.lax.fori_loop", "jax.lax.switch", "jax.lax.map"}


def _static_names_from_call(call: ast.Call, func_def):
    """Extract static_argnames/static_argnums from a jax.jit(...) call."""
    names = set()
    nums = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for elt in ast.walk(kw.value):
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    names.add(elt.value)
        elif kw.arg == "static_argnums":
            for elt in ast.walk(kw.value):
                if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                    nums.add(elt.value)
    if nums and func_def is not None:
        params = [a.arg for a in func_def.args.args]
        if params and params[0] == "self":
            params = params[1:]
        for i in sorted(nums):
            if 0 <= i < len(params):
                names.add(params[i])
    return frozenset(names)


def _donate_from_call(call: ast.Call):
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            return tuple(
                elt.value for elt in ast.walk(kw.value)
                if isinstance(elt, ast.Constant) and isinstance(elt.value, int)
            )
    return ()


class JitIndex:
    """Which FunctionDefs are traced, and which names are jitted callables."""

    def __init__(self, tree: ast.Module, resolver: Resolver):
        self.resolver = resolver
        self.traced: dict[ast.AST, TracedInfo] = {}
        self.bindings: dict[str, JitBinding] = {}
        # name -> FunctionDef for module-level and class-level defs
        defs: dict[str, ast.AST] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, node)

        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_decorators(node)
            elif isinstance(node, ast.Call):
                self._scan_call(node, defs)
        # Functions defined inside a traced body are themselves traced
        # (lax.scan steps, pl.when branches, ...).
        self._propagate_nested(tree)

    def _scan_decorators(self, node):
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            name = self.resolver.dotted(target)
            if name in ("jax.jit", "jit"):
                info = TracedInfo("jit")
                if isinstance(dec, ast.Call):
                    info = TracedInfo(
                        "jit",
                        _static_names_from_call(dec, node),
                        _donate_from_call(dec),
                    )
                self.traced[node] = info
                self.bindings[node.name] = JitBinding(
                    node.name, info.donate_argnums, node
                )
            elif name in ("functools.partial", "partial") and isinstance(
                dec, ast.Call
            ):
                if dec.args and self.resolver.dotted(dec.args[0]) in (
                    "jax.jit", "jit"
                ):
                    info = TracedInfo(
                        "jit",
                        _static_names_from_call(dec, node),
                        _donate_from_call(dec),
                    )
                    self.traced[node] = info
                    self.bindings[node.name] = JitBinding(
                        node.name, info.donate_argnums, node
                    )

    def _scan_call(self, call: ast.Call, defs):
        name = self.resolver.dotted(call.func)
        if name in ("jax.jit", "jit") and call.args:
            fn_arg = call.args[0]
            # Resolve the wrapped function to a def in this module, by
            # trailing attribute name (handles both `f` and `self._f`).
            fn_name = None
            if isinstance(fn_arg, ast.Name):
                fn_name = fn_arg.id
            elif isinstance(fn_arg, ast.Attribute):
                fn_name = fn_arg.attr
            elif isinstance(fn_arg, ast.IfExp):
                # jax.jit(self._a if flag else self._b, ...)
                for branch in (fn_arg.body, fn_arg.orelse):
                    bname = (branch.attr if isinstance(branch, ast.Attribute)
                             else branch.id if isinstance(branch, ast.Name)
                             else None)
                    if bname in defs:
                        fd = defs[bname]
                        self.traced[fd] = TracedInfo(
                            "jit", _static_names_from_call(call, fd),
                            _donate_from_call(call))
            func_def = defs.get(fn_name)
            if func_def is not None:
                self.traced[func_def] = TracedInfo(
                    "jit",
                    _static_names_from_call(call, func_def),
                    _donate_from_call(call),
                )
        elif name in _SCAN_HOFS:
            # Function-valued arguments become traced bodies.
            for arg in call.args:
                fd = None
                if isinstance(arg, ast.Name):
                    fd = defs.get(arg.id)
                elif isinstance(arg, ast.Lambda):
                    fd = arg
                if fd is not None and fd not in self.traced:
                    self.traced[fd] = TracedInfo("scan_body")
        elif name and name.endswith("pallas_call") and call.args:
            fn_arg = call.args[0]
            static = set()
            # pl.pallas_call(kernel, ...) or functools.partial(kernel, ...);
            # partial keywords bind Python config, not Refs.
            if isinstance(fn_arg, ast.Call):
                static.update(kw.arg for kw in fn_arg.keywords if kw.arg)
                inner = fn_arg.args[0] if fn_arg.args else None
                fn_arg = inner if inner is not None else fn_arg
            kname = (fn_arg.id if isinstance(fn_arg, ast.Name)
                     else fn_arg.attr if isinstance(fn_arg, ast.Attribute)
                     else None)
            fd = defs.get(kname)
            if fd is not None and fd not in self.traced:
                # Keyword-only params are config by convention: Pallas
                # passes Refs positionally.
                static.update(a.arg for a in fd.args.kwonlyargs)
                self.traced[fd] = TracedInfo("pallas", frozenset(static))

    def _record_binding(self, target_raw, call):
        pass

    def _propagate_nested(self, tree):
        changed = True
        while changed:
            changed = False
            for node in ast.walk(tree):
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if node not in self.traced:
                    continue
                for inner in ast.walk(node):
                    if inner is node:
                        continue
                    if isinstance(inner, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                        if inner not in self.traced:
                            self.traced[inner] = TracedInfo("nested")
                            changed = True

    def record_assignment(self, target_node, call, resolver, defs_hint=None):
        pass


def collect_jit_bindings(tree: ast.Module, resolver: Resolver,
                         jit_index: JitIndex):
    """Find ``target = jax.jit(fn, ...)`` assignments; index by the raw
    dotted target string so call sites like ``self._prefill(...)`` match."""
    bindings = dict(jit_index.bindings)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or not isinstance(
            node.value, ast.Call
        ):
            continue
        call = node.value
        name = resolver.dotted(call.func)
        if name not in ("jax.jit", "jit"):
            continue
        donate = _donate_from_call(call)
        for tgt in node.targets:
            raw = resolver.raw_dotted(tgt)
            if raw:
                bindings[raw] = JitBinding(raw, donate, None)
    return bindings


# --------------------------------------------------------------------------
# Events


@dataclasses.dataclass
class Event:
    kind: str           # sync | branch_device | shape_traced | jit_in_loop
    #                   | set_iter | alloc_drop | time_time | unseeded_rng
    node: ast.AST
    func: ast.AST | None        # enclosing FunctionDef (None at module level)
    loop_depth: int             # 0 = not inside any for/while/comprehension
    traced: TracedInfo | None   # jit-region context, if any
    detail: str = ""            # e.g. sync sub-kind


class ModuleAnalysis:
    """Run the taint pass over every function; collect events."""

    def __init__(self, module):
        self.module = module
        self.resolver = module.resolver
        self.jit_index = module.jit_index
        self.bindings = collect_jit_bindings(
            module.tree, self.resolver, self.jit_index
        )
        self.events: list[Event] = []
        self.self_taint = _class_attr_taint(module, self)
        self._analyzed: set = set()
        # Module level: treat the module body as a pseudo-function.
        FunctionPass(self, None, module.tree.body, env={},
                     traced=None).run()
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.analyze_function(node)

    def analyze_function(self, node):
        if node in self._analyzed:
            return
        self._analyzed.add(node)
        traced = self.jit_index.traced.get(node)
        env = {}
        args = node.args
        params = list(args.posonlyargs) + list(args.args) + list(
            args.kwonlyargs
        )
        for i, a in enumerate(params):
            if a.arg == "self" and i == 0:
                env[a.arg] = V_UNKNOWN
            elif traced is not None and traced.kind in ("jit", "scan_body",
                                                        "pallas"):
                if a.arg in traced.static_names:
                    env[a.arg] = V_STATIC
                else:
                    env[a.arg] = V_DEVICE
            else:
                env[a.arg] = V_UNKNOWN
        FunctionPass(self, node, node.body, env=env, traced=traced).run()

    def emit(self, kind, node, func, loop_depth, traced, detail=""):
        self.events.append(
            Event(kind, node, func, loop_depth, traced, detail)
        )


def _class_attr_taint(module, analysis):
    """Infer taint of ``self.X`` per class from every ``self.X = ...``.

    Two fixed-point iterations: the second pass sees first-pass attr
    taints, which resolves chains like ``self.cache`` assigned from the
    result of a jitted call that itself reads ``self.cache``.
    """
    result: dict[str, Value] = {}
    for _ in range(2):
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for stmt in ast.walk(node):
                targets = []
                if isinstance(stmt, ast.Assign):
                    targets, value = stmt.targets, stmt.value
                elif isinstance(stmt, ast.AugAssign):
                    targets, value = [stmt.target], stmt.value
                else:
                    continue
                ev = Evaluator(analysis, env={}, traced=None,
                               self_taint=result, silent=True)
                val = ev.eval(value)
                flat = []
                for t in targets:
                    flat.extend(t.elts if isinstance(
                        t, (ast.Tuple, ast.List)) else [t])
                for t in flat:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        prev = result.get(t.attr)
                        result[t.attr] = (Value.join(prev, val)
                                          if prev else val)
    return result


# --------------------------------------------------------------------------
# Expression evaluation


class Evaluator:
    """Evaluate an expression to a Value, emitting events as side effects."""

    def __init__(self, analysis, env, traced, self_taint, silent=False,
                 func=None, loop_depth=0):
        self.analysis = analysis
        self.env = env
        self.traced = traced
        self.self_taint = self_taint
        self.silent = silent
        self.func = func
        self.loop_depth = loop_depth

    def emit(self, kind, node, detail=""):
        if not self.silent:
            self.analysis.emit(kind, node, self.func, self.loop_depth,
                               self.traced, detail)

    # -- dispatch -----------------------------------------------------------

    def eval(self, node):
        if node is None:
            return V_STATIC
        method = getattr(self, f"_eval_{type(node).__name__}", None)
        if method is not None:
            return method(node)
        # Default: join taints of child expressions.
        vals = [self.eval(c) for c in ast.iter_child_nodes(node)
                if isinstance(c, ast.expr)]
        return Value.join(*vals) if vals else V_UNKNOWN

    def _eval_Constant(self, node):
        return V_STATIC

    def _eval_Name(self, node):
        return self.env.get(node.id, V_UNKNOWN)

    def _eval_Attribute(self, node):
        if node.attr in _STATIC_ATTRS:
            return V_STATIC
        if isinstance(node.value, ast.Name) and node.value.id == "self":
            return self.self_taint.get(node.attr, V_UNKNOWN)
        base = self.eval(node.value)
        if base.taint == DEVICE:
            return V_DEVICE        # keeps `.at[...]`-style chains on device
        return V_UNKNOWN

    def _eval_Subscript(self, node):
        base = self.eval(node.value)
        self.eval(node.slice)
        if base.taint in (DEVICE, HOST):
            return Value(base.taint)
        if base.taint == STATIC:
            return V_STATIC        # shape[0] etc.
        return V_UNKNOWN

    def _eval_BinOp(self, node):
        return Value.join(self.eval(node.left), self.eval(node.right))

    def _eval_UnaryOp(self, node):
        return self.eval(node.operand)

    def _eval_BoolOp(self, node):
        return Value.join(*[self.eval(v) for v in node.values])

    def _eval_Compare(self, node):
        vals = [self.eval(node.left)] + [self.eval(c) for c in
                                         node.comparators]
        # `x is None`, `x in container` produce Python bools even on
        # containers of device arrays — not device-valued.
        if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
               for op in node.ops):
            return V_STATIC
        return Value.join(*vals)

    def _eval_IfExp(self, node):
        self.eval(node.test)
        return Value.join(self.eval(node.body), self.eval(node.orelse))

    def _eval_Tuple(self, node):
        return Value.join(*[self.eval(e) for e in node.elts]) \
            if node.elts else V_STATIC

    _eval_List = _eval_Tuple

    def _eval_Set(self, node):
        v = self._eval_Tuple(node)
        return Value(v.taint, is_set=True)

    def _eval_Dict(self, node):
        vals = [self.eval(v) for v in node.values if v is not None]
        return Value.join(*vals) if vals else V_STATIC

    def _eval_JoinedStr(self, node):
        for v in node.values:
            if isinstance(v, ast.FormattedValue):
                self.eval(v.value)
        return V_STATIC

    def _eval_Lambda(self, node):
        return V_UNKNOWN

    def _eval_ListComp(self, node):
        return self._eval_comp(node, node.elt)

    def _eval_GeneratorExp(self, node):
        return self._eval_comp(node, node.elt)

    def _eval_SetComp(self, node):
        v = self._eval_comp(node, node.elt)
        return Value(v.taint, is_set=True)

    def _eval_DictComp(self, node):
        return self._eval_comp(node, node.value)

    def _eval_comp(self, node, elt):
        inner = Evaluator(self.analysis, dict(self.env), self.traced,
                          self.self_taint, self.silent, self.func,
                          self.loop_depth + 1)
        for gen in node.generators:
            src = inner.eval(gen.iter)
            if src.is_set:
                inner.emit("set_iter", gen.iter)
            tgt_val = Value(src.taint) if src.taint in (DEVICE, HOST) \
                else V_UNKNOWN
            _bind_target(inner.env, gen.target, tgt_val)
            for cond in gen.ifs:
                inner.eval(cond)
        return inner.eval(elt)

    def _eval_Starred(self, node):
        return self.eval(node.value)

    def _eval_Await(self, node):
        return self.eval(node.value)

    # -- calls --------------------------------------------------------------

    def _eval_Call(self, node):
        name = self.analysis.resolver.dotted(node.func)
        raw = self.analysis.resolver.raw_dotted(node.func)
        arg_vals = [self.eval(a) for a in node.args]
        for kw in node.keywords:
            arg_vals.append(self.eval(kw.value))
        any_device = any(v.taint == DEVICE for v in arg_vals)

        # ---- host-sync sources (R001) --------------------------------
        if name == "jax.device_get":
            self.emit("sync", node, "jax.device_get")
            return V_HOST
        if name in ("jax.block_until_ready",):
            self.emit("sync", node, name)
            return arg_vals[0] if arg_vals else V_UNKNOWN
        if name in ("numpy.asarray", "numpy.array",
                    "numpy.ascontiguousarray"):
            if any_device:
                self.emit("sync", node, name)
            return V_HOST
        if name and name.startswith("numpy."):
            if any_device:
                self.emit("sync", node, name)
            if name == "numpy.random.default_rng":
                if not node.args and not node.keywords:
                    self.emit("unseeded_rng", node, name)
                return V_HOST
            if name.startswith("numpy.random.") and name not in (
                "numpy.random.default_rng", "numpy.random.Generator",
                "numpy.random.SeedSequence",
            ):
                # Module-level numpy RNG: global mutable seed state.
                self.emit("unseeded_rng", node, name)
            return V_HOST
        if name in ("int", "float", "bool", "complex") and any_device:
            self.emit("sync", node, f"{name}()")
            return V_STATIC
        recv = (self.eval(node.func.value)
                if isinstance(node.func, ast.Attribute) else None)
        if (recv is not None
                and node.func.attr in ("item", "tolist", "tobytes")
                and recv.taint == DEVICE):
            self.emit("sync", node, f".{node.func.attr}()")
            return V_HOST

        # ---- nondeterminism sources (R004) ---------------------------
        if name == "time.time":
            self.emit("time_time", node, name)
            return V_STATIC
        if name and (name.startswith("random.") or name == "uuid.uuid4"):
            self.emit("unseeded_rng", node, name)
            return V_UNKNOWN
        if name in ("set", "frozenset"):
            return Value(Value.join(*arg_vals).taint if arg_vals
                         else STATIC, is_set=True)

        # ---- recompile hazards (R002) --------------------------------
        if name in ("jax.jit", "jit") and self.loop_depth > 0:
            self.emit("jit_in_loop", node, name or "jax.jit")
        if self.traced is not None and name in _SHAPE_CTORS:
            pos = _SHAPE_CTORS[name]
            pos_vals = arg_vals[: len(node.args)]
            hazard = (
                any(v.taint == DEVICE for v in pos_vals) if pos is None
                else (len(pos_vals) > pos and pos_vals[pos].taint == DEVICE)
            )
            if hazard:
                self.emit("shape_traced", node, name)

        # ---- result taint --------------------------------------------
        if name:
            if name in _DEVICE_CALLS or any(
                name.startswith(p) for p in _DEVICE_PREFIXES
            ):
                return V_DEVICE
            if any(name.startswith(p) for p in _TREE_CALLS):
                return Value.join(*arg_vals) if arg_vals else V_UNKNOWN
            if name in _STATIC_BUILTINS:
                return V_STATIC
            if name in _PASSTHROUGH_BUILTINS:
                j = Value.join(*arg_vals) if arg_vals else V_STATIC
                return Value(j.taint)  # sorted(set) is a list again
            if name in ("dict",):
                return Value(Value.join(*arg_vals).taint if arg_vals
                             else STATIC)
        if raw and raw in self.analysis.bindings:
            return V_DEVICE         # call of a jitted binding
        # Constructor calls (capitalized by convention) wrap their
        # arguments in host objects; don't inherit device taint from a
        # `params` argument (ServeEngine(cfg, params) is not an array).
        last = (name or raw or "").rsplit(".", 1)[-1]
        if last[:1].isupper():
            return V_UNKNOWN
        # Method calls on device values stay on device (.astype, .sum, ...)
        if recv is not None:
            if recv.taint == DEVICE:
                return V_DEVICE
            if recv.taint == HOST:
                return V_HOST
        if any_device:
            return V_DEVICE         # local helpers over device args
        return V_UNKNOWN


def _bind_target(env, target, value):
    if isinstance(target, ast.Name):
        env[target.id] = value
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            _bind_target(env, elt, Value(value.taint))
    elif isinstance(target, ast.Starred):
        _bind_target(env, target.value, value)
    # Attribute/Subscript stores don't rebind local taint.


# --------------------------------------------------------------------------
# Statement walk


class FunctionPass:
    """Forward statement walk over one function body."""

    def __init__(self, analysis, func, body, env, traced):
        self.analysis = analysis
        self.func = func
        self.body = body
        self.env = env
        self.traced = traced
        self.self_taint = analysis.self_taint

    def run(self):
        self.visit_block(self.body, loop_depth=0)

    def _evaluator(self, loop_depth):
        return Evaluator(self.analysis, self.env, self.traced,
                         self.self_taint, func=self.func,
                         loop_depth=loop_depth)

    def visit_block(self, stmts, loop_depth):
        for stmt in stmts:
            self.visit_stmt(stmt, loop_depth)

    def visit_stmt(self, stmt, loop_depth):
        ev = self._evaluator(loop_depth)
        if isinstance(stmt, ast.Assign):
            val = ev.eval(stmt.value)
            for tgt in stmt.targets:
                self._store(tgt, val, stmt.value)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._store(stmt.target, ev.eval(stmt.value), stmt.value)
        elif isinstance(stmt, ast.AugAssign):
            val = Value.join(ev.eval(stmt.target), ev.eval(stmt.value))
            self._store(stmt.target, val, stmt.value)
        elif isinstance(stmt, ast.Expr):
            ev.eval(stmt.value)
        elif isinstance(stmt, (ast.Return, ast.Raise)):
            if getattr(stmt, "value", None) is not None:
                ev.eval(stmt.value)
            if isinstance(stmt, ast.Raise) and stmt.exc is not None:
                ev.eval(stmt.exc)
        elif isinstance(stmt, ast.If):
            self._branch_test(stmt.test, loop_depth)
            before = dict(self.env)
            self.visit_block(stmt.body, loop_depth)
            after_body = dict(self.env)
            self.env.clear()
            self.env.update(before)
            self.visit_block(stmt.orelse, loop_depth)
            for k in sorted(set(after_body) | set(self.env)):
                a, b = after_body.get(k), self.env.get(k)
                self.env[k] = Value.join(a, b) if a and b else (a or b)
        elif isinstance(stmt, ast.While):
            self._branch_test(stmt.test, loop_depth)
            for _ in range(2):
                self.visit_block(stmt.body, loop_depth + 1)
            self.visit_block(stmt.orelse, loop_depth)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            src = ev.eval(stmt.iter)
            if src.is_set:
                ev.emit("set_iter", stmt.iter)
            tgt_val = (Value(src.taint) if src.taint in (DEVICE, HOST)
                       else V_UNKNOWN)
            _bind_target(self.env, stmt.target, tgt_val)
            for _ in range(2):
                self.visit_block(stmt.body, loop_depth + 1)
            self.visit_block(stmt.orelse, loop_depth)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                val = ev.eval(item.context_expr)
                if item.optional_vars is not None:
                    _bind_target(self.env, item.optional_vars, val)
            self.visit_block(stmt.body, loop_depth)
        elif isinstance(stmt, ast.Try):
            self.visit_block(stmt.body, loop_depth)
            for handler in stmt.handlers:
                self.visit_block(handler.body, loop_depth)
            self.visit_block(stmt.orelse, loop_depth)
            self.visit_block(stmt.finalbody, loop_depth)
        elif isinstance(stmt, ast.Assert):
            self._branch_test(stmt.test, loop_depth, kind="assert")
            if stmt.msg is not None:
                ev.eval(stmt.msg)
        elif isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    self.env.pop(t.id, None)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # Nested def: analyzed separately with closure taint seeded
            # from the current environment.
            traced = self.analysis.jit_index.traced.get(stmt)
            env = dict(self.env)
            params = (list(stmt.args.posonlyargs) + list(stmt.args.args)
                      + list(stmt.args.kwonlyargs))
            for a in params:
                if traced is not None:
                    env[a.arg] = (V_STATIC if a.arg in traced.static_names
                                  else V_DEVICE)
                else:
                    env[a.arg] = V_UNKNOWN
            self.analysis._analyzed.add(stmt)
            FunctionPass(self.analysis, stmt, stmt.body, env, traced).run()
        # ClassDef bodies at function level, Global, Import, Pass: skip.

    def _store(self, target, value, rhs):
        # Elementwise unpack when the RHS is a literal tuple/list.
        if (isinstance(target, (ast.Tuple, ast.List))
                and isinstance(rhs, (ast.Tuple, ast.List))
                and len(target.elts) == len(rhs.elts)):
            ev = self._evaluator(0)
            for t, r in zip(target.elts, rhs.elts):
                self._store(t, ev.eval(r), r)
            return
        _bind_target(self.env, target, value)
        # `self.X = ...` refines the module-wide attr taint locally.
        if (isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)
                and target.value.id == "self"):
            prev = self.self_taint.get(target.attr)
            if prev is None or prev.taint == UNKNOWN:
                self.self_taint[target.attr] = value

    def _branch_test(self, test, loop_depth, kind="branch"):
        ev = self._evaluator(loop_depth)
        val = ev.eval(test)
        if val.taint == DEVICE:
            ev.emit("branch_device", test, kind)
