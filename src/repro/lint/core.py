"""Rule registry, suppression comments, and the per-file analysis driver.

A rule is a small object with an ``id`` (``R001``..), a one-line
``title``, an ``invariant`` docstring, and a ``check(module)`` method
returning :class:`Finding` objects.  Rules register themselves via
:func:`register`; the driver runs every registered rule over every file
and filters the findings through line-level suppression comments.

Suppression syntax (line-level only — no file-level blanket disables)::

    x = np.asarray(dev)  # repro-lint: disable=R001 -- seed reference path
    # repro-lint: disable=R004 -- wall-clock timestamp is the point here
    t = time.time()

A suppression applies to findings on its own line or, for a standalone
comment line, on the next line.  The ``-- reason`` suffix is required by
convention (DESIGN.md §6) but not enforced syntactically.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import time
import tokenize


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a specific source location."""

    rule: str
    path: str
    line: int
    col: int
    symbol: str           # enclosing function/class qualname ("<module>" at top level)
    message: str

    def sort_key(self):
        return (self.path, self.line, self.col, self.rule)

    def to_dict(self):
        return dataclasses.asdict(self)

    def render(self):
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.symbol}] {self.message}")


class Rule:
    """Base class for lint rules.  Subclasses set id/title/invariant."""

    id = "R000"
    title = "unnamed rule"
    invariant = ""

    def check(self, module: "ModuleInfo"):  # pragma: no cover - interface
        raise NotImplementedError

    def finding(self, module, node, message):
        return Finding(
            rule=self.id,
            path=module.path,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0) + 1,
            symbol=module.qualname(node),
            message=message,
        )


_REGISTRY: dict[str, Rule] = {}


def register(cls):
    """Class decorator adding an instance of ``cls`` to the registry."""
    rule = cls()
    if rule.id in _REGISTRY:
        raise ValueError(f"duplicate rule id {rule.id}")
    _REGISTRY[rule.id] = rule
    return cls


def all_rules():
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


# --------------------------------------------------------------------------
# Suppression comments


_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Z0-9,\s]+?)(?:\s*--\s*(.*))?$"
)


def parse_suppressions(source: str):
    """Map line number -> set of suppressed rule ids.

    A comment suppresses its own line; a comment that is the only thing
    on its line also suppresses the next line (so multi-line statements
    can carry a suppression above them).
    """
    suppressed: dict[int, set[str]] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            line = tok.start[0]
            suppressed.setdefault(line, set()).update(rules)
            # Standalone comment: nothing but whitespace before it.
            if tok.line[: tok.start[1]].strip() == "":
                suppressed.setdefault(line + 1, set()).update(rules)
    except tokenize.TokenError:
        pass
    return suppressed


# --------------------------------------------------------------------------
# Per-module context shared by all rules


class ModuleInfo:
    """Parsed source plus the lazily-built shared analyses rules need."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path
        self.source = source
        self.tree = tree
        self.suppressions = parse_suppressions(source)
        self._parents: dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent
        self._resolver = None
        self._jit_index = None
        self._analysis = None

    # -- lazy shared analyses ------------------------------------------------

    @property
    def resolver(self):
        if self._resolver is None:
            from repro.lint.dataflow import Resolver

            self._resolver = Resolver(self.tree)
        return self._resolver

    @property
    def jit_index(self):
        if self._jit_index is None:
            from repro.lint.dataflow import JitIndex

            self._jit_index = JitIndex(self.tree, self.resolver)
        return self._jit_index

    @property
    def analysis(self):
        if self._analysis is None:
            from repro.lint.dataflow import ModuleAnalysis

            self._analysis = ModuleAnalysis(self)
        return self._analysis

    # -- tree helpers --------------------------------------------------------

    def parent(self, node):
        return self._parents.get(node)

    def ancestors(self, node):
        cur = self._parents.get(node)
        while cur is not None:
            yield cur
            cur = self._parents.get(cur)

    def enclosing_function(self, node):
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    def qualname(self, node):
        parts = []
        for anc in [node, *self.ancestors(node)]:
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                parts.append(anc.name)
        return ".".join(reversed(parts)) or "<module>"

    def is_suppressed(self, finding: Finding):
        for line in (finding.line, ):
            rules = self.suppressions.get(line)
            if rules and finding.rule in rules:
                return True
        return False


# --------------------------------------------------------------------------
# Driver


@dataclasses.dataclass
class LintResult:
    findings: list          # active (post-suppression, post-baseline)
    baseline_suppressed: int
    inline_suppressed: int
    rules_run: list
    files_checked: int
    wall_s: float
    errors: list            # (path, message) for unparseable files

    def to_json(self):
        return {
            "rules_run": self.rules_run,
            "findings": [f.to_dict() for f in self.findings],
            "baseline_suppressed": self.baseline_suppressed,
            "inline_suppressed": self.inline_suppressed,
            "files_checked": self.files_checked,
            "wall_s": round(self.wall_s, 4),
            "errors": [{"path": p, "message": m} for p, m in self.errors],
        }


def analyze_source(source: str, path: str = "<string>", rules=None):
    """Lint a source string; returns (findings, inline_suppressed_count).

    Findings are sorted; suppression comments are applied.  ``rules``
    restricts to a subset of rule ids.
    """
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        raise ValueError(f"{path}: syntax error: {e}") from e
    module = ModuleInfo(path, source, tree)
    active = all_rules()
    if rules is not None:
        wanted = set(rules)
        active = [r for r in active if r.id in wanted]
    findings = []
    for rule in active:
        findings.extend(rule.check(module))
    findings = list(dict.fromkeys(findings))  # dedup repeated events
    kept, suppressed = [], 0
    for f in sorted(findings, key=Finding.sort_key):
        if module.is_suppressed(f):
            suppressed += 1
        else:
            kept.append(f)
    return kept, suppressed


def iter_python_files(paths):
    """Expand files/directories into sorted .py file paths."""
    out = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(
                    d for d in dirs
                    if d not in ("__pycache__", ".git", ".pytest_cache")
                )
                out.extend(
                    os.path.join(root, f) for f in sorted(files)
                    if f.endswith(".py")
                )
    # De-dup while keeping deterministic order.
    seen, uniq = set(), []
    for p in out:
        key = os.path.normpath(p)
        if key not in seen:
            seen.add(key)
            uniq.append(key)
    return uniq


def run_lint(paths, rules=None, baseline=None, root=None):
    """Lint every .py file under ``paths``; returns a :class:`LintResult`.

    ``baseline`` is a parsed baseline mapping (see repro.lint.baseline);
    matched findings are counted, not reported.  Paths in findings are
    made relative to ``root`` (default: cwd) so baselines are portable.
    """
    t0 = time.perf_counter()
    root = root or os.getcwd()
    files = iter_python_files(paths)
    findings, inline_suppressed, errors = [], 0, []
    for fpath in files:
        try:
            with open(fpath, encoding="utf-8") as f:
                source = f.read()
        except OSError as e:
            errors.append((fpath, str(e)))
            continue
        rel = os.path.relpath(fpath, root).replace(os.sep, "/")
        try:
            kept, supp = analyze_source(source, path=rel, rules=rules)
        except ValueError as e:
            errors.append((rel, str(e)))
            continue
        findings.extend(kept)
        inline_suppressed += supp
    baseline_suppressed = 0
    if baseline:
        from repro.lint.baseline import filter_findings

        findings, baseline_suppressed = filter_findings(findings, baseline)
    active = all_rules()
    if rules is not None:
        wanted = set(rules)
        active = [r for r in active if r.id in wanted]
    return LintResult(
        findings=sorted(findings, key=Finding.sort_key),
        baseline_suppressed=baseline_suppressed,
        inline_suppressed=inline_suppressed,
        rules_run=[r.id for r in active],
        files_checked=len(files),
        wall_s=time.perf_counter() - t0,
        errors=errors,
    )
