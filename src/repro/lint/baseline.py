"""Baseline file: grandfathered findings that don't fail the build.

Entries are keyed by ``(rule, path, symbol)`` with a count — robust to
line drift (a refactor that moves a function doesn't invalidate the
baseline) but strict about growth (one *new* finding in a baselined
function still fails).  Every entry carries a human ``reason``; the
policy (DESIGN.md §6) is that baselining is for pre-existing findings
awaiting a real fix, never for new code — new code uses an inline
``# repro-lint: disable=...`` with a justification, or gets fixed.
"""
from __future__ import annotations

import json

BASELINE_VERSION = 1


def load_baseline(path):
    """Load a baseline file; returns {(rule, path, symbol): count}."""
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"{path}: unsupported baseline version {data.get('version')!r}"
        )
    out = {}
    for entry in data.get("entries", []):
        key = (entry["rule"], entry["path"], entry["symbol"])
        out[key] = out.get(key, 0) + int(entry.get("count", 1))
    return out


def write_baseline(path, findings, reason="grandfathered"):
    """Serialize current findings as a fresh baseline (sorted, stable)."""
    counts = {}
    for f in findings:
        key = (f.rule, f.path, f.symbol)
        counts[key] = counts.get(key, 0) + 1
    entries = [
        {"rule": rule, "path": fpath, "symbol": symbol, "count": n,
         "reason": reason}
        for (rule, fpath, symbol), n in sorted(counts.items())
    ]
    data = {"version": BASELINE_VERSION, "entries": entries}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    return len(entries)


def filter_findings(findings, baseline):
    """Drop findings covered by the baseline.

    Returns ``(kept, n_suppressed)``.  Within one ``(rule, path,
    symbol)`` group the first ``count`` findings (in line order) are
    suppressed; any beyond that are new and stay active.
    """
    budget = dict(baseline)
    kept, suppressed = [], 0
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col)):
        key = (f.rule, f.path, f.symbol)
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            suppressed += 1
        else:
            kept.append(f)
    return kept, suppressed
