"""Rules R001–R003: host-sync budgets, recompile hazards, donation.

These three rules guard the serve tier's core perf contract (DESIGN.md
§5.1): the chunked decode loop pays exactly one device→host sync per
chunk, the jitted dispatch never retraces on Python values, and buffers
donated to ``jax.jit`` are dead after the call.
"""
from __future__ import annotations

import ast

from repro.lint.core import Rule, register
from repro.lint.dataflow import collect_jit_bindings


@register
class HostSyncInHotLoop(Rule):
    id = "R001"
    title = "host-sync-in-hot-loop"
    invariant = (
        "Device->host syncs (.item(), int()/float()/bool() on traced "
        "values, np.asarray/jax.device_get on device arrays, implicit "
        "bool of a device array) must not appear inside jit-traced code "
        "at all, and must not run per-iteration inside Python loops — "
        "batch them through one jax.device_get per chunk/wave (the "
        "PR 2 serve loop's '1 host sync per chunk' contract)."
    )

    def check(self, module):
        findings = []
        for ev in module.analysis.events:
            if ev.kind == "sync":
                if ev.traced is not None:
                    findings.append(self.finding(
                        module, ev.node,
                        f"host sync ({ev.detail}) inside jit-traced code: "
                        "forces a trace-time transfer or fails under jit; "
                        "hoist it out of the traced region",
                    ))
                elif ev.loop_depth > 0:
                    findings.append(self.finding(
                        module, ev.node,
                        f"per-iteration host sync ({ev.detail}) inside a "
                        "loop: each iteration blocks on the device; batch "
                        "via a single jax.device_get outside the loop",
                    ))
            elif ev.kind == "branch_device" and ev.traced is None:
                findings.append(self.finding(
                    module, ev.node,
                    "implicit bool() of a device array in a "
                    f"{ev.detail} test is a hidden host sync; compute "
                    "the predicate on host or batch the transfer",
                ))
        return findings


@register
class RecompileHazard(Rule):
    id = "R002"
    title = "recompile-hazard"
    invariant = (
        "Inside jit-traced code, shapes and control flow must depend "
        "only on static values (literals, .shape, static_argnames); "
        "branching or shape construction from traced values retraces "
        "per distinct value, and jax.jit called inside a loop defeats "
        "the compile cache (the PlanCache fingerprinting discipline of "
        "core/planner.py applied to the serve tier)."
    )

    def check(self, module):
        findings = []
        for ev in module.analysis.events:
            if ev.kind == "branch_device" and ev.traced is not None:
                findings.append(self.finding(
                    module, ev.node,
                    "Python branch on a traced value inside jit-traced "
                    "code: triggers ConcretizationError or a retrace per "
                    "value; use lax.cond/jnp.where or mark the argument "
                    "static",
                ))
            elif ev.kind == "shape_traced":
                findings.append(self.finding(
                    module, ev.node,
                    f"{ev.detail} shape depends on a traced value inside "
                    "jit-traced code: every distinct value recompiles; "
                    "derive shapes from .shape/static args",
                ))
            elif ev.kind == "jit_in_loop":
                findings.append(self.finding(
                    module, ev.node,
                    "jax.jit(...) constructed inside a loop: each "
                    "iteration builds a fresh callable and misses the "
                    "compile cache; hoist the jit wrapping out of the "
                    "loop",
                ))
        return findings


@register
class DonationViolation(Rule):
    id = "R003"
    title = "donation-violation"
    invariant = (
        "A buffer passed at a donate_argnums position of a jitted call "
        "is invalidated by that call; reading it afterwards (before "
        "rebinding) returns garbage or errors on non-CPU backends. The "
        "serve engine relies on this for its in-place KV/cursor update "
        "(engine.__init__ donates cache/cursor state back to itself)."
    )

    def check(self, module):
        findings = []
        bindings = collect_jit_bindings(
            module.tree, module.resolver, module.jit_index
        )
        donating = {t: b for t, b in bindings.items() if b.donate_argnums}
        if not donating:
            return findings
        for call in ast.walk(module.tree):
            if not isinstance(call, ast.Call):
                continue
            raw = module.resolver.raw_dotted(call.func)
            if raw not in donating:
                continue
            binding = donating[raw]
            donated = []
            for idx in binding.donate_argnums:
                if idx < len(call.args):
                    expr = module.resolver.raw_dotted(call.args[idx])
                    if expr:
                        donated.append(expr)
            if not donated:
                continue
            findings.extend(
                self._check_liveness(module, call, donated)
            )
        return findings

    def _check_liveness(self, module, call, donated):
        """Flag loads of donated expressions after the donating call."""
        func = module.enclosing_function(call)
        if func is None:
            return []
        # The statement containing the call; its Assign targets rebind.
        stmt = call
        for anc in module.ancestors(call):
            if isinstance(anc, ast.stmt):
                stmt = anc
                break
        killed = set()
        if isinstance(stmt, ast.Assign):
            for tgt in stmt.targets:
                killed.update(_target_names(module, tgt))
        end = getattr(stmt, "end_lineno", stmt.lineno)
        findings = []
        live = [d for d in donated if d not in killed]
        if not live:
            return findings
        # Linear scan of subsequent statements in source order: a load
        # before a rebind of the same expression is a violation.
        events = []
        for node in ast.walk(func):
            if not isinstance(node, ast.stmt) or node.lineno <= end:
                continue
            for tgt, val in _stores_of(node):
                for name in _target_names(module, tgt):
                    events.append((node.lineno, 0, "store", name, node))
            for sub in ast.walk(node):
                if isinstance(sub, (ast.Name, ast.Attribute)) and isinstance(
                    getattr(sub, "ctx", None), ast.Load
                ):
                    expr = module.resolver.raw_dotted(sub)
                    if expr in live:
                        # Skip loads nested inside a larger matching
                        # attribute chain (counted at the chain root).
                        events.append(
                            (sub.lineno, sub.col_offset, "load", expr, sub)
                        )
        events.sort(key=lambda e: (e[0], e[1]))
        dead = set(live)
        for _, _, kind, name, node in events:
            if kind == "store":
                dead.discard(name)
            elif kind == "load" and name in dead:
                findings.append(self.finding(
                    module, node,
                    f"`{name}` was donated to `{module.resolver.raw_dotted(call.func)}` "
                    f"(line {call.lineno}) and read afterwards without "
                    "rebinding: donated buffers are invalidated by the "
                    "call",
                ))
                dead.discard(name)  # one finding per donated expr
        return findings


def _target_names(module, target):
    names = []
    if isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            names.extend(_target_names(module, elt))
    elif isinstance(target, ast.Starred):
        names.extend(_target_names(module, target.value))
    else:
        expr = module.resolver.raw_dotted(target)
        if expr:
            names.append(expr)
    return names


def _stores_of(stmt):
    if isinstance(stmt, ast.Assign):
        return [(t, stmt.value) for t in stmt.targets]
    if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        return [(stmt.target, stmt.value)]
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [(stmt.target, stmt.iter)]
    return []
