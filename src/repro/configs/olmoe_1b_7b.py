"""olmoe-1b-7b: 64 experts top-8 [arXiv:2409.02060; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1024, vocab=50304, head_dim=128, n_experts=64, top_k=8,
)

SMOKE = ModelConfig(
    arch="olmoe-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=64, vocab=256, head_dim=16, n_experts=8, top_k=2,
    vocab_pad_multiple=64, dtype="float32",
)
