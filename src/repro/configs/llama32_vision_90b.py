"""llama-3.2-vision-90b: cross-attention image layers every 5th layer;
vision tower is a stub providing patch embeddings
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="llama-3.2-vision-90b", family="vlm", n_layers=100, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128256, head_dim=128,
    cross_attn_every=5, n_vis_tokens=1600, rope_theta=5e5,
)

SMOKE = ModelConfig(
    arch="llama-vision-smoke", family="vlm", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, head_dim=16, cross_attn_every=2,
    n_vis_tokens=8, vocab_pad_multiple=64, dtype="float32",
)
