"""minicpm-2b: llama-like dense MHA, tied embeddings, WSD schedule
[arXiv:2404.06395; hf].  36 heads do not divide the model axis (16):
attention TP shards head_dim (64/16=4) instead — see distributed/sharding."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="minicpm-2b", family="dense", n_layers=40, d_model=2304, n_heads=36,
    n_kv_heads=36, d_ff=5760, vocab=122753, head_dim=64, tie_embeddings=True,
)

SMOKE = ModelConfig(
    arch="minicpm-2b-smoke", family="dense", n_layers=2, d_model=72, n_heads=6,
    n_kv_heads=6, d_ff=180, vocab=256, head_dim=12, tie_embeddings=True,
    vocab_pad_multiple=64, dtype="float32",
)
