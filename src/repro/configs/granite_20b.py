"""granite-20b: llama-arch dense, MQA (kv=1), code model [arXiv:2405.04324; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="granite-20b", family="dense", n_layers=52, d_model=6144, n_heads=48,
    n_kv_heads=1, d_ff=24576, vocab=49152, head_dim=128, act="gelu",
)

SMOKE = ModelConfig(
    arch="granite-20b-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=1, d_ff=256, vocab=256, head_dim=16, act="gelu",
    vocab_pad_multiple=64, dtype="float32",
)
