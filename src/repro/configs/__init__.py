"""Per-architecture configuration files (exact public-literature configs)."""
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401
