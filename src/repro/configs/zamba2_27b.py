"""zamba2-2.7b: Mamba-2 backbone + shared attention block [arXiv:2411.15242].

Shared block applied every 6 SSM layers (9 invocations over 54 layers),
weights shared, KV caches per invocation."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560, n_heads=32,
    n_kv_heads=32, d_ff=10240, vocab=32000, head_dim=80, ssm_state=64,
    ssm_headdim=64, ssm_groups=1, shared_attn_every=6, subquadratic=True,
)

SMOKE = ModelConfig(
    arch="zamba2-smoke", family="hybrid", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, head_dim=16, ssm_state=16,
    ssm_headdim=16, ssm_groups=2, shared_attn_every=2, vocab_pad_multiple=64,
    dtype="float32", subquadratic=True,
)
