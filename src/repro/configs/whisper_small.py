"""whisper-small: enc-dec audio backbone; conv frontend is a stub
[arXiv:2212.04356; unverified].  12L refers to each stack."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-small", family="encdec", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_ff=3072, vocab=51865, head_dim=64, enc_layers=12,
    enc_seq=1500, norm_kind="layer", act="gelu",
)

SMOKE = ModelConfig(
    arch="whisper-small-smoke", family="encdec", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, head_dim=16, enc_layers=2,
    enc_seq=16, norm_kind="layer", act="gelu", vocab_pad_multiple=64,
    dtype="float32",
)
