"""qwen2.5-32b: dense GQA with QKV bias [hf:Qwen/Qwen2.5; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2.5-32b", family="dense", n_layers=64, d_model=5120, n_heads=40,
    n_kv_heads=8, d_ff=27648, vocab=152064, head_dim=128, qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    arch="qwen2.5-32b-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=192, vocab=256, head_dim=16, qkv_bias=True,
    vocab_pad_multiple=64, dtype="float32",
)
