"""mamba2-1.3b: SSD state-space model, attention-free [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048, n_heads=0,
    n_kv_heads=0, d_ff=0, vocab=50280, ssm_state=128, ssm_headdim=64,
    ssm_groups=1, tie_embeddings=True, subquadratic=True,
)

SMOKE = ModelConfig(
    arch="mamba2-smoke", family="ssm", n_layers=2, d_model=64, n_heads=0,
    n_kv_heads=0, d_ff=0, vocab=256, ssm_state=16, ssm_headdim=16,
    ssm_groups=2, tie_embeddings=True, vocab_pad_multiple=64,
    dtype="float32", subquadratic=True,
)
