"""yi-9b: llama-arch dense GQA [arXiv:2403.04652; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="yi-9b", family="dense", n_layers=48, d_model=4096, n_heads=32,
    n_kv_heads=4, d_ff=11008, vocab=64000, head_dim=128, rope_theta=5e6,
)

SMOKE = ModelConfig(
    arch="yi-9b-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=176, vocab=256, head_dim=16, vocab_pad_multiple=64,
    dtype="float32",
)
