"""Model/run configuration dataclasses shared by all architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # Transformer details
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_kind: str = "rms"           # rms | layer
    act: str = "swiglu"              # swiglu | gelu
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_dispatch: str = "dense"      # "dense" | "sorted" (capacity-based)
    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_expand: int = 2
    # Hybrid (zamba2): shared attention block every k SSM layers
    shared_attn_every: int = 0
    # Encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500              # stub frame-embedding length
    # VLM: cross-attention to vision tokens every k layers
    cross_attn_every: int = 0
    n_vis_tokens: int = 1600
    # KV cache layout (serving): "contiguous" reserves a per-slot
    # (max_len, hkv, dh) ring; "paged" pools capacity into fixed-size pages
    # shared across slots via a per-slot page table (DESIGN.md §5.2).
    cache_layout: str = "contiguous"   # "contiguous" | "paged"
    kv_page_size: int = 16             # tokens per page ("paged" only)
    # Decode-attention kernel for the single-token decode step (DESIGN.md
    # §5.2).  "xla": gather a dense per-slot view and run the masked XLA
    # softmax (the default, and the prefill path always).  "pallas_paged":
    # the paged split-KV Pallas kernel dereferences the page table inside
    # the kernel and reads the pool in place — no gather copy.
    # "pallas_gather": the same kernel math over the gathered dense view;
    # this is the bit-identity reference for the paged path and the
    # gather-cost ablation arm in the benches.
    decode_kernel: str = "xla"    # "xla" | "pallas_gather" | "pallas_paged"
    # Split-K parallelism for the Pallas decode kernels.  0 = planned: the
    # serve engine bakes its CachePolicyEngine decode plan in here before
    # building the model (jitted traces need a static split count); direct
    # model users get kernels.decode_attention.ops.plan_splits' default.
    decode_splits: int = 0
    # Prefix sharing (serving, DESIGN.md §5.4): admission attaches a new
    # request to already-resident full prefix pages via the host-side radix
    # trie (serve.prefix) and refcounted page pool, prefilling only the
    # unshared suffix.  Requires the paged layout and a pure-KV decoder
    # family (dense/moe): recurrent state is not page-shareable and
    # encdec/vlm prefix KV depends on per-slot source context, so those
    # engines fall back to unshared bookkeeping.
    prefix_sharing: bool = False
    # Speculative decode (serving, DESIGN.md §5.3): an on-device n-gram
    # proposer drafts spec_k tokens per slot; one multi-token verify
    # dispatch accepts a ragged per-slot prefix and rolls the rest back.
    spec_k: int = 0                    # draft tokens per verify (0 = off)
    spec_ngram: int = 3                # suffix length for the proposer
    # Serving-time sampling (serve.sampling.Sampler); non-greedy modes
    # thread per-request PRNG keys folded from (seed, token index) so
    # outputs are independent of slot assignment order.
    sampling: str = "greedy"           # greedy | temperature | top_k | top_p
    temperature: float = 1.0
    top_k: int = 0                     # "top_k" mode: sample from k largest
    top_p: float = 1.0                 # "top_p" mode: smallest mass >= top_p
    # Request lifecycle (serving, DESIGN.md §5.5): when admission is gated
    # on an empty free list, evict the youngest resident and re-enqueue it
    # for recompute-prefill over prompt + emitted tokens (bit-identical
    # restore by construction of the (seed, token-index) sampler keys).
    preemption: bool = True
    # Chaos / fault injection (serve.chaos, DESIGN.md §5.5): seeded alloc
    # failures (paged only) and forced preemptions at wave boundaries.
    # Probabilities must stay < 1.0 or the serve loop cannot make progress.
    chaos_alloc_fail_p: float = 0.0    # P(injected alloc refusal) per alloc
    chaos_preempt_p: float = 0.0       # P(forced preemption) per wave
    chaos_seed: int = 0                # seeds every chaos RNG
    # Crash safety + KV integrity (serve.snapshot, DESIGN.md §5.6).
    # strict_invariants arms the per-wave check_invariants() sweep even
    # with no chaos knob set (CI tier-1 also arms it via the
    # REPRO_STRICT_INVARIANTS env var).  kv_integrity stamps per-page
    # fingerprints at chunk boundaries and verifies them every step,
    # quarantining + recompute-healing any corrupted page.  The remaining
    # chaos knobs inject the failures those paths exist for: seeded
    # device-side bit flips on stamped pages and a typed ChaosCrash after
    # the Nth admission wave (0 = off).  Snapshot config fingerprints
    # exclude all chaos_* knobs and strict_invariants, so a restore may
    # run with them off.
    strict_invariants: bool = False
    kv_integrity: bool = False
    chaos_share_fail_p: float = 0.0    # P(injected share refusal) per share
    chaos_corrupt_p: float = 0.0       # P(bit-flip on a stamped page) per step
    chaos_crash_after_wave: int = 0    # raise ChaosCrash after wave N (0=off)
    # Adaptive serve-tier cache policy (serve.adaptive, DESIGN.md §5.7):
    # runtime counters (prefix hit rate, page reuse distance, spec
    # acceptance, recompute cost) drive warm-prefix retention beyond
    # refcount zero (bounded by warm_pages), cost-aware preemption victim
    # selection, and per-workload-class policy selection through the
    # core.sweep exact lattice argmin, re-planned every
    # adaptive_replan_every admission waves.  Placement-only: every
    # decision moves pages/slots, never tokens — outputs stay
    # bit-identical to the static engine, so snapshot config fingerprints
    # exclude all three knobs (like the chaos knobs).
    adaptive: bool = False
    warm_pages: int = 0                # warm-cache page budget (0 = no tier)
    adaptive_replan_every: int = 4     # admission waves between re-plans
    # Numerics / sharding
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 2048   # pad vocab so `model` axis (16) divides it
    # Sub-quadratic attention available (gates the long_500k shape cell)
    subquadratic: bool = False

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    def param_count(self) -> int:
        """Analytical parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hq, hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * (hq + 2 * hkv) * dh + hq * dh * d
        mlp = (3 if self.act == "swiglu" else 2) * d * f
        if self.family == "moe":
            mlp = self.n_experts * mlp + d * self.n_experts
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, g, ds, h = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            ssm = (
                d * (2 * di + 2 * g * ds + h)      # in_proj
                + self.ssm_conv * (di + 2 * g * ds)  # conv
                + di * d + 2 * h + di              # out_proj, A/D, norm
            )
        per_layer = 2 * d  # norms
        if self.family == "ssm":
            layer = ssm + per_layer
            total = self.n_layers * layer
        elif self.family == "hybrid":
            n_shared = (
                self.n_layers // self.shared_attn_every
                if self.shared_attn_every else 0
            )
            total = self.n_layers * (ssm + per_layer) + (attn + mlp + 2 * d)
            del n_shared  # single shared block: params counted once
        elif self.family == "encdec":
            enc = self.enc_layers * (attn + mlp + per_layer)
            dec = self.n_layers * (2 * attn + mlp + 3 * d)
            total = enc + dec
        elif self.family == "vlm":
            n_cross = (
                self.n_layers // self.cross_attn_every
                if self.cross_attn_every else 0
            )
            n_self = self.n_layers - n_cross
            total = n_self * (attn + mlp + per_layer) + n_cross * (
                attn + mlp + per_layer
            )
        else:
            total = self.n_layers * (attn + mlp + per_layer)
        return int(total + emb)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top_k + router only)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp_all = self.n_experts * (3 * d * f)
        mlp_act = self.top_k * (3 * d * f)
        return self.param_count() - self.n_layers * (mlp_all - mlp_act)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
