"""The paper's 17 MI workloads (Table 2) as analytical OpSpecs + runnable
jnp kernels.

Each workload carries the paper's input configuration (batch size, GPU
footprint) and its expected §VI.A class.  Calibration annotations (all
documented inline) mirror measured gem5/MIOpen behaviour:

* SGEMM/DGEMM: ``achieved_eff=0.3`` — the paper finds these COMPUTE-bound on
  a 12.3 TFLOP/s GPU despite modest arithmetic intensity, implying ~30% of
  peak for MIOpenGEMM's short-K kernels in gem5.
* FwLRN: the cross-channel window reuse is modeled as UNREALIZABLE
  (reuse_distance ~ footprint) because MIOpen's LRN kernel interleaves
  images across the batch — the paper groups LRN with the no-reuse
  throughput-sensitive class.
* RNN cells: per-step cell kernels reuse gate inputs ~4x within small
  windows; FwBw adds write-coalescible wgrad accumulation (paper: write
  caching wins up to 32% on Bw* workloads).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.characterize import (
    conv2d_op,
    elementwise_op,
    matmul_op,
    rowwise_op,
    window_op,
)
from repro.core.policy import OpSpec, WorkloadClass

MB = 1024 * 1024


def _with_eff(op: OpSpec, eff: float) -> OpSpec:
    return dataclasses.replace(op, meta={**op.meta, "achieved_eff": eff})


@dataclasses.dataclass
class Workload:
    name: str
    ops: list[OpSpec]
    launches: int                     # total kernels (Table 2)
    footprint_bytes: float
    expected: WorkloadClass
    runnable: Callable | None = None  # scaled-down jnp version (CPU-exec)


def _runnable_elementwise(elems):
    def fn(key):
        x = jax.random.normal(key, (elems,), jnp.float32)
        return jax.nn.relu(x)
    return fn


def _runnable_softmax(rows, row_len):
    def fn(key):
        x = jax.random.normal(key, (rows, row_len), jnp.float32)
        return jax.nn.softmax(x, axis=-1)
    return fn


def _runnable_matmul(m, k, n, dtype=jnp.float32):
    def fn(key):
        a = jax.random.normal(key, (m, k), dtype)
        b = jax.random.normal(key, (k, n), dtype)
        return a @ b
    return fn


def _runnable_pool(n, c, h, w):
    def fn(key):
        x = jax.random.normal(key, (n, c, h, w), jnp.float32)
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 1, 3, 3), (1, 1, 2, 2), "SAME"
        )
    return fn


def _rnn_sequence_op(hidden: int, gates: int, steps: int,
                     name: str) -> OpSpec:
    """Weight streaming across the timestep loop.

    The 0.38MB-footprint RNNs are reuse-sensitive in the paper because the
    cell weights (fitting easily in L2/VMEM) are re-touched every timestep;
    with caching they are fetched once.  batch=1 GEMVs run at low MXU
    efficiency (achieved_eff 0.15)."""
    eb = 4
    w_bytes = 2 * hidden * hidden * gates * eb   # input + recurrent weights
    io_bytes = steps * hidden * (gates + 2) * eb
    from repro.core.policy import OperandProfile

    w = OperandProfile(
        name="w", role="input", shape=(2 * hidden, hidden * gates),
        dtype="f32", unique_bytes=w_bytes,
        touched_bytes_stream=w_bytes * steps,
        reuse_window_bytes=w_bytes,
    )
    h = OperandProfile(
        name="h", role="input", shape=(steps, hidden), dtype="f32",
        unique_bytes=io_bytes,
        touched_bytes_stream=io_bytes * gates,      # gates re-read h/c
        reuse_window_bytes=hidden * (gates + 2) * eb,
    )
    out = OperandProfile(
        name="out", role="output", shape=(steps, hidden), dtype="f32",
        unique_bytes=io_bytes, touched_bytes_stream=io_bytes, revisits=1,
    )
    flops = steps * 2 * (2 * hidden) * hidden * gates
    op = OpSpec(kind="rnn_cell", name=name, operands=(w, h, out),
                flops=flops, dtype="f32",
                meta={"achieved_eff": 0.15, "elems": steps * hidden})
    return op


def _rnn_ops(hidden: int, gates: int, steps: int, bwd: bool, name: str):
    ops = [_rnn_sequence_op(hidden, gates, steps, name)]
    if bwd:
        # wgrad accumulates partial sums over timesteps: the writes are
        # coalescible (split-K style revisits) — the Bw* write-caching win.
        wg = matmul_op(hidden * 2, steps, hidden * gates, dtype="f32",
                       bm=64, bn=64, bk=16, split_k=steps, name=name + "_wg")
        ops.append(_with_eff(wg, 0.15))
        ops.append(_rnn_sequence_op(hidden, gates, steps, name + "_dgrad"))
    return ops


def build_suite() -> dict[str, Workload]:
    C = WorkloadClass
    suite: dict[str, Workload] = {}

    def add(name, ops, launches, footprint_mb, expected, runnable=None):
        suite[name] = Workload(
            name, ops, launches, footprint_mb * MB, expected, runnable
        )

    # --- elementwise activations (throughput-sensitive) -------------------
    add("FwAct", [elementwise_op(200_000_000, dtype="f32", name="FwAct")],
        1, 1600, C.THROUGHPUT_SENSITIVE, _runnable_elementwise(1 << 20))
    add("BwAct",
        [elementwise_op(200_000_000, n_inputs=2, dtype="f32", name="BwAct")],
        1, 2400, C.THROUGHPUT_SENSITIVE, _runnable_elementwise(1 << 20))

    # --- normalization -----------------------------------------------------
    add("FwBN", [rowwise_op(256, 20480, passes=2, dtype="f32", name="FwBN")],
        1, 42, C.REUSE_SENSITIVE, _runnable_softmax(256, 1024))
    bwbn = rowwise_op(512, 1440, passes=3, dtype="f32", name="BwBN")
    # BwBN's dgamma/dbeta partial sums revisit the output: coalescible.
    ops = list(bwbn.operands)
    out = dataclasses.replace(ops[-1], revisits=4)
    bwbn = dataclasses.replace(bwbn, operands=(*ops[:-1], out))
    add("BwBN", [bwbn], 1, 5.88, C.REUSE_SENSITIVE)
    add("FwLRN",
        [window_op(600_000_000, 5, 1, reuse_distance_elems=120_000_000,
                   loads_per_out=2.0, dtype="f32", name="FwLRN")],
        1, 2400, C.THROUGHPUT_SENSITIVE)

    # --- pooling (3x3 stride-2: 2.25x overlapped reads) --------------------
    add("FwPool",
        [window_op(96_000_000, 9, 4, reuse_distance_elems=20_000,
                   loads_per_out=9.0, dtype="f32", name="FwPool")],
        1, 480, C.REUSE_SENSITIVE, _runnable_pool(4, 16, 128, 128))
    bwpool = window_op(50_000_000, 9, 4, reuse_distance_elems=20_000,
                       loads_per_out=9.0, dtype="f32", name="BwPool")
    ops = list(bwpool.operands)
    out = dataclasses.replace(
        ops[-1], revisits=2,
        unique_bytes=ops[0].unique_bytes,          # dx is input-sized
        touched_bytes_stream=ops[0].unique_bytes,
    )
    bwpool = dataclasses.replace(bwpool, operands=(*ops[:-1], out))
    add("BwPool", [bwpool], 1, 252, C.REUSE_SENSITIVE)

    # --- softmax ------------------------------------------------------------
    add("FwSoft", [rowwise_op(512, 5, passes=3, dtype="f32", name="FwSoft")],
        1, 0.01, C.REUSE_SENSITIVE, _runnable_softmax(512, 5))
    add("BwSoft", [rowwise_op(512, 10, passes=2, dtype="f32", name="BwSoft")],
        1, 0.02, C.REUSE_SENSITIVE)

    # --- fully connected / GEMM --------------------------------------------
    # Large well-shaped GEMM: ~75% of peak (vs 30% for the short-K SGEMM
    # benchmarks) — at that rate the uncached 2.4GB DRAM stream is the
    # bottleneck and caching wins (paper: FwFc is reuse-sensitive with a
    # 93% traffic cut).
    add("FwFc",
        [_with_eff(matmul_op(512, 9216, 4096, dtype="f32",
                             bm=64, bn=64, bk=64, name="FwFc"), 0.75)],
        1, 148.2, C.REUSE_SENSITIVE, _runnable_matmul(128, 512, 512))
    add("SGEMM",
        [_with_eff(matmul_op(4096, 128, 4096, dtype="f32",
                             bm=64, bn=64, bk=64, name="SGEMM"), 0.3)],
        1, 68, C.MEMORY_INSENSITIVE, _runnable_matmul(512, 128, 512))
    add("DGEMM",
        [_with_eff(matmul_op(4096, 128, 4096, dtype="f64",
                             bm=64, bn=64, bk=64, name="DGEMM"), 0.3)],
        1, 132, C.MEMORY_INSENSITIVE,
        _runnable_matmul(512, 128, 512, jnp.float64)
        if jax.config.jax_enable_x64 else _runnable_matmul(512, 128, 512))

    # --- RNNs (batch 1, seq 16, hidden 128) ---------------------------------
    add("FwLSTM", _rnn_ops(128, 4, 16, False, "FwLSTM"), 150,
        0.38, C.REUSE_SENSITIVE)
    add("FwGRU", _rnn_ops(128, 3, 16, False, "FwGRU"), 150,
        0.38, C.REUSE_SENSITIVE)
    add("FwBwLSTM", _rnn_ops(128, 4, 16, True, "FwBwLSTM"), 363,
        0.48, C.REUSE_SENSITIVE)
    add("FwBwGRU", _rnn_ops(128, 3, 16, True, "FwBwGRU"), 363,
        0.48, C.REUSE_SENSITIVE)

    # --- Composed Model (conv -> pool -> bn -> fc, batch 64) ----------------
    cm_ops = [
        _with_eff(conv2d_op(64, 64, 28, 28, 128, 3, 3, dtype="f32",
                            name="CM_conv"), 0.5),
        window_op(64 * 128 * 28 * 28, 9, 4, reuse_distance_elems=20_000,
                  dtype="f32", name="CM_pool"),
        rowwise_op(64, 128 * 14 * 14, passes=2, dtype="f32", name="CM_bn"),
        _with_eff(matmul_op(64, 128 * 14 * 14, 1000, dtype="f32",
                            bm=64, bn=64, bk=64, name="CM_fc"), 0.3),
    ]
    add("CM", cm_ops, 130, 12.1, C.MEMORY_INSENSITIVE)

    return suite


SUITE = build_suite()
