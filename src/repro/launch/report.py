"""Tabulate dry-run artifacts into EXPERIMENTS.md §Dry-run / §Roofline.

Usage:  PYTHONPATH=src python -m repro.launch.report [--dir artifacts/dryrun]
Prints markdown; the EXPERIMENTS.md assembly script embeds it.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(directory: str) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        r["_file"] = os.path.basename(path)
        r["_tag"] = (
            r["_file"].split("__")[3].removesuffix(".json")
            if r["_file"].count("__") >= 3 else ""
        )
        rows.append(r)
    return rows


def _fmt_bytes(b) -> str:
    if not b:
        return "-"
    return f"{b/2**30:.1f}"


def dryrun_table(rows: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | fits | HBM GiB/chip | compile s | knobs | "
        "collectives (count) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["_tag"]:
            continue
        mesh = "x".join(str(v) for v in r["mesh"].values())
        rl = r.get("roofline", {})
        kn = r.get("knobs", {})
        knob_s = (
            f"{kn.get('remat','-')[:9]}/mb{kn.get('microbatch',1)}"
            + ("/z1" if kn.get("zero1") else "")
        )
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {'Y' if rl.get('fits_hbm') else 'n/a' if rl.get('fits_hbm') is None else 'N'} "
            f"| {_fmt_bytes(rl.get('hbm_need_bytes'))} "
            f"| {r.get('compile_seconds','-')} | {knob_s} "
            f"| {int(r.get('counted',{}).get('coll_count',0))} |"
        )
    return "\n".join(out)


def roofline_table(rows: list[dict], mesh_filter: str = "single") -> str:
    out = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant | "
        "MODEL_FLOPS | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["_tag"]:
            continue
        mesh = r["mesh"]
        is_single = "pod" not in mesh
        if (mesh_filter == "single") != is_single:
            continue
        rl = r.get("roofline", {})
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rl.get('t_compute_s', 0):.4f} "
            f"| {rl.get('t_memory_s', 0):.4f} "
            f"| {rl.get('t_collective_s', 0):.4f} "
            f"| **{rl.get('dominant','-')}** "
            f"| {rl.get('model_flops_total', 0):.2e} "
            f"| {rl.get('useful_compute_ratio', 0):.3f} "
            f"| {rl.get('roofline_fraction', 0):.3f} |"
        )
    return "\n".join(out)


def worst_cells(rows: list[dict], n: int = 5):
    cells = [
        r for r in rows
        if "pod" not in r["mesh"] and not r["_tag"] and "roofline" in r
    ]
    by_frac = sorted(cells, key=lambda r: r["roofline"]["roofline_fraction"])
    by_coll = sorted(
        cells,
        key=lambda r: -(
            r["roofline"]["t_collective_s"]
            / max(sum((r["roofline"]["t_compute_s"],
                       r["roofline"]["t_memory_s"],
                       r["roofline"]["t_collective_s"])), 1e-30)
        ),
    )
    return by_frac[:n], by_coll[:n]


def perf_table(rows: list[dict], arch: str, shape: str) -> str:
    """Hillclimb variants (tagged artifacts) vs the baseline for one cell."""
    cell = [
        r for r in rows
        if r["arch"] == arch and r["shape"] == shape and "pod" not in r["mesh"]
    ]
    base = next((r for r in cell if not r["_tag"]), None)
    out = [
        "| variant | t_comp | t_mem | t_coll | dominant | roofline frac | "
        "Δ dominant vs baseline |",
        "|---|---|---|---|---|---|---|",
    ]
    if base is None:
        return "(no baseline artifact)"
    bdom = base["roofline"]["dominant"]
    bval = base["roofline"][f"t_{'memory' if bdom == 'memory' else bdom if bdom != 'collective' else 'collective'}_s"]
    key = {"memory": "t_memory_s", "compute": "t_compute_s",
           "collective": "t_collective_s"}[bdom]
    for r in sorted(cell, key=lambda r: r["_tag"]):
        rl = r["roofline"]
        delta = (rl[key] - bval) / bval if bval else 0.0
        out.append(
            f"| {r['_tag'] or 'baseline'} "
            f"| {rl['t_compute_s']:.3f} | {rl['t_memory_s']:.3f} "
            f"| {rl['t_collective_s']:.3f} | {rl['dominant']} "
            f"| {rl['roofline_fraction']:.4f} "
            f"| {delta*100:+.1f}% |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--perf", nargs=2, metavar=("ARCH", "SHAPE"))
    args = ap.parse_args()
    if args.perf:
        print(perf_table(load(args.dir), *args.perf))
        return
    rows = load(args.dir)
    print(f"### Dry-run ({len([r for r in rows if not r['_tag']])} cells)\n")
    print(dryrun_table(rows))
    print("\n### Roofline (single-pod 16x16)\n")
    print(roofline_table(rows, "single"))
    print("\n### Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(rows, "multi"))
    frac, coll = worst_cells(rows)
    print("\nworst roofline fractions:",
          [(r["arch"], r["shape"]) for r in frac])
    print("most collective-bound:",
          [(r["arch"], r["shape"]) for r in coll])


if __name__ == "__main__":
    main()
