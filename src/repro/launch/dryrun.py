import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# 512 placeholder host devices stand in for 2 pods x 256 chips.  This is set
# ONLY here — tests and benches see the real single CPU device.

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

For each cell we jit the real step function (train_step / prefill /
decode_step) with production shardings over the 16x16 single-pod or 2x16x16
multi-pod mesh, ``.lower().compile()`` it against ShapeDtypeStruct inputs
(no allocation), and record:

* ``compiled.memory_analysis()``  — proves the cell fits per-device HBM,
* ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline,
* collective bytes parsed from the optimized HLO (all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute).

Usage:
    python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
    python -m repro.launch.dryrun --sweep            # all cells, subprocesses
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import hw
from repro.configs.base import SHAPES
from repro.core.remat import RematPolicy
from repro.distributed import sharding as sh
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models import build_model, get_config, runnable_cells
from repro.train import optimizer as opt
from repro.train.step import TrainConfig, make_train_step

ARTIFACT_DIR = "artifacts/dryrun"


def _cost_dict(compiled) -> dict:
    """Portable ``compiled.cost_analysis()``: newer jax returns a list of
    per-computation dicts, older a flat dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def plan_model_policies(cfg, shape, plan_cache=None) -> dict:
    """Plan VMEM policies for the model's per-layer op graph through the
    memoized CachePolicyEngine (DESIGN.md §3).

    Characterizes each transformer layer's ops (norms, QKV/O projections,
    attention, MLP matmuls) as OpSpecs and plans all ``n_layers`` of them:
    every layer after the first hits the PlanCache, so the reported
    ``hit_rate`` is ~(L-1)/L per distinct op — the artifact's proof that
    repeated layers plan once.
    """
    from repro.core import make_engine
    from repro.core.characterize import attention_op, matmul_op, rowwise_op
    from repro.core.planner import PlanCache

    eng = make_engine(plan_cache=plan_cache or PlanCache())
    b = max(1, shape.global_batch // hw.CHIPS_PER_POD)   # per-chip slice
    s = 1 if shape.kind == "decode" else shape.seq_len
    d, f = cfg.d_model, cfg.d_ff
    hq, hkv, dh = cfg.n_heads, max(1, cfg.n_kv_heads), cfg.head_dim_
    tokens = b * s
    layer_ops = [rowwise_op(tokens, d, passes=2, name="ln_in")]
    if hq and dh:
        layer_ops += [
            matmul_op(tokens, d, (hq + 2 * hkv) * dh, name="qkv_proj"),
            attention_op(b, hq, hkv, s, shape.seq_len, dh, name="attn"),
            matmul_op(tokens, hq * dh, d, name="o_proj"),
        ]
    if f:
        layer_ops += [
            rowwise_op(tokens, d, passes=2, name="ln_mlp"),
            matmul_op(tokens, d, f, name="mlp_up"),
            matmul_op(tokens, f, d, name="mlp_down"),
        ]
    policies = {}
    vmem_peak = 0
    for _ in range(max(1, cfg.n_layers)):
        for op in layer_ops:
            plan = eng.plan_op(op)
            eng.cost(op, plan)
            vmem_peak = max(vmem_peak, plan.vmem_bytes)
            policies[op.name] = {
                o.name: plan.assignment[o.name].value for o in op.operands
            }
    stats = eng.plan_stats()
    return {
        "layers": cfg.n_layers,
        "ops_per_layer": len(layer_ops),
        "ops_planned": max(1, cfg.n_layers) * len(layer_ops),
        "plan_cache_hit_rate": stats["hit_rate"],
        "plan_cache": stats,
        "vmem_peak_bytes": vmem_peak,
        "policies": policies,
    }


def _tree_shardings(tree, mesh, spec_fn):
    return jax.tree_util.tree_map_with_path(
        lambda path, x: NamedSharding(mesh, spec_fn(path, x)), tree
    )


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    remat: str = "save_dots",
    grad_reduce_dtype: str = "float32",
    microbatch: int = 1,
    zero1: bool = False,
    fsdp: str = "auto",
    moe_dispatch: str = "dense",
    cfg=None,
):
    cfg = cfg or get_config(arch)
    if moe_dispatch != "dense":
        import dataclasses as _dc

        cfg = _dc.replace(cfg, moe_dispatch=moe_dispatch)
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    specs = input_specs(arch, shape_name, model=model, cfg=cfg)
    use_fsdp = (
        sh.fsdp_needed(cfg, mesh, train=shape.kind == "train")
        if fsdp == "auto" else fsdp in (True, "on", "true")
    )

    if specs["kind"] == "train":
        tcfg = TrainConfig(
            remat=RematPolicy(remat),
            grad_reduce_dtype=grad_reduce_dtype,
            microbatch=microbatch,
            zero1=zero1,
            batch_axes=tuple(sh.batch_axes(mesh)),
        )
        train_step, _ = make_train_step(cfg, tcfg)
        pshard = sh.params_shardings(specs["state"]["params"], cfg, mesh, fsdp=use_fsdp)
        oshard = opt.opt_shardings(
            pshard, specs["state"]["params"], mesh, zero1=zero1
        )
        state_shardings = {"params": pshard, "opt": oshard}
        bspec = sh.batch_spec(cfg, mesh, shape.global_batch)
        batch_shardings = {
            k: NamedSharding(mesh, bspec[k]) for k in specs["batch"]
        }
        with mesh:
            lowered = jax.jit(
                train_step,
                in_shardings=(state_shardings, batch_shardings),
                out_shardings=(state_shardings, None),
                donate_argnums=(0,),
            ).lower(specs["state"], specs["batch"])
    else:
        long_ctx = shape_name == "long_500k"
        pshard = sh.params_shardings(specs["params"], cfg, mesh, fsdp=use_fsdp)
        cspec_fn = sh.cache_spec(
            cfg, mesh, shape.global_batch, long_context=long_ctx
        )
        cshard = _tree_shardings(specs["cache"], mesh, cspec_fn)
        b = sh._batch_rule(mesh, shape.global_batch)
        tok_shard = NamedSharding(mesh, P(b, None))

        step = model.prefill if specs["kind"] == "prefill" else model.decode_step
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(pshard, cshard, tok_shard),
                out_shardings=(None, cshard),
                donate_argnums=(1,),
            ).lower(specs["params"], specs["cache"], specs["tokens"])
    return cfg, shape, mesh, lowered


def _layer_unit(cfg) -> int:
    return cfg.cross_attn_every or cfg.shared_attn_every or 1


def counted_metrics(arch: str, shape_name: str, multi_pod: bool, **knobs):
    """Trip-count-correct HLO FLOPs/bytes/collectives.

    XLA's cost_analysis counts a while (scan) body ONCE regardless of trip
    count, so the scanned full model under-reports.  We lower the SAME cell
    at 1 and 2 layer-units with every scan fully unrolled, then linearly
    extrapolate: metric(L) = base + L * per_unit.  Exact for costs linear in
    depth (all of ours are: per-layer compute/traffic/collectives + a
    depth-independent embed/unembed/optimizer base).
    """
    import dataclasses as dc

    from repro.models import common as model_common

    # Counting runs at microbatch=1: unrolling the grad-accumulation scan
    # multiplies HLO size by mb for ~0.1% traffic difference (params are
    # re-read per microbatch but are ~1e-3 of activation traffic here).
    knobs = dict(knobs, microbatch=1)
    cfg = get_config(arch)
    if knobs.get("moe_dispatch", "dense") != "dense":
        cfg = dc.replace(cfg, moe_dispatch=knobs["moe_dispatch"])
    unit = _layer_unit(cfg)
    units_real = cfg.n_layers // unit
    cfgs = []
    for k in (1, 2):
        c = dc.replace(cfg, n_layers=unit * k)
        if cfg.family == "encdec":
            c = dc.replace(c, enc_layers=k)
        cfgs.append(c)

    model_common.set_scan_unroll(True)
    try:
        measured = []
        for c in cfgs:
            _, shape, mesh, lowered = lower_cell(
                arch, shape_name, multi_pod, cfg=c, **knobs
            )
            compiled = lowered.compile()
            cost = _cost_dict(compiled)
            colls = roofline.parse_collectives(compiled.as_text())
            measured.append({
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll_moved": colls["total_moved_bytes"],
                "coll_count": colls["total_count"],
                "coll_per_kind": {
                    k: v["moved_bytes"] for k, v in colls["per_kind"].items()
                },
            })
    finally:
        model_common.set_scan_unroll(False)

    m1, m2 = measured

    def extrap(a, b):
        per = b - a
        return (a - per) + units_real * per

    out = {k: extrap(m1[k], m2[k]) for k in ("flops", "bytes", "coll_moved",
                                             "coll_count")}
    out["coll_per_kind"] = {
        k: extrap(m1["coll_per_kind"][k], m2["coll_per_kind"][k])
        for k in m1["coll_per_kind"]
    }
    out["units"] = units_real
    out["measured_1unit"] = m1
    out["measured_2unit"] = m2
    return out


def analyze(cfg, shape, mesh, lowered, compile_s, compiled):
    n_chips = int(np.prod(list(mesh.shape.values())))
    cost = {}
    try:
        cost = _cost_dict(compiled)
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    hlo = compiled.as_text()
    colls = roofline.parse_collectives(hlo)
    return {
        "arch": cfg.arch,
        "shape": shape.name,
        "mesh": dict(mesh.shape),
        "n_chips": n_chips,
        "compile_seconds": round(compile_s, 2),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float)) and "{" not in k},
        "memory_analysis": mem,
        "collectives_scanned_module_raw": colls,
    }, cost, colls, mem


HBM_BYTES = 16 * 1024**3


def _fits(mem: dict) -> bool:
    need = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
    return bool(need and need <= HBM_BYTES)


def _prior_knobs(arch: str, shape_name: str, out_dir: str) -> dict | None:
    """Fitted knobs from the single-pod artifact (reused by multi-pod)."""
    path = os.path.join(out_dir, f"{arch}__{shape_name}__single.json")
    if os.path.exists(path):
        try:
            with open(path) as f:
                return json.load(f).get("knobs")
        except Exception:
            return None
    return None


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             tag: str = "", auto_fit: bool = True, counting: bool = True,
             **knobs) -> dict:
    """Compile one cell.  ``auto_fit`` escalates (microbatch, remat) like the
    allocation-bypass planner does for VMEM: never 'OOM-stall', demote the
    activation-residency policy / split the batch until the cell fits HBM.
    Multi-pod cells reuse the single-pod run's fitted knobs and skip the
    counting lowers (the roofline table is single-pod only)."""
    shape_kind = SHAPES[shape_name].kind
    if multi_pod and not tag:
        prior = _prior_knobs(arch, shape_name, out_dir)
        if prior:
            knobs = dict(knobs, **prior)
    if "fsdp" not in knobs or knobs["fsdp"] == "auto":
        # Resolve FSDP once per cell so the counting lowers (reduced-depth
        # configs) use the SAME sharding strategy as the artifact.
        class _M:
            shape = {"data": 16, "model": 16}

        knobs = dict(knobs, fsdp=sh.fsdp_needed(
            get_config(arch), _M, train=shape_kind == "train"
        ))
    if shape_kind == "train" and knobs.get("remat") == "save_dots" and (
        knobs.get("microbatch", 1) == 1
    ):
        # Baseline train config: recompute/mb4 (the save_dots/mb1 rung never
        # fits the 4k-seq 16GB-HBM cells; skipping it saves a compile).
        knobs = dict(knobs, remat="recompute", microbatch=4)
    ladder = [dict(knobs)]
    if auto_fit and shape_kind == "train":
        step_knobs = dict(knobs, remat="recompute",
                          microbatch=max(16, knobs.get("microbatch", 1)))
        if step_knobs not in ladder:
            ladder.append(step_knobs)

    result = cost = colls = mem = None
    # perf_counter, not time.time (R004): these are interval timings and
    # the wall clock is not monotonic under NTP steps.
    t0 = t1 = t2 = time.perf_counter()
    for i, kn in enumerate(ladder):
        t0 = time.perf_counter()
        cfg, shape, mesh, lowered = lower_cell(arch, shape_name, multi_pod, **kn)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        result, cost, colls, mem = analyze(cfg, shape, mesh, lowered, t2 - t1,
                                           compiled)
        del lowered, compiled
        knobs = kn
        if not auto_fit or shape_kind != "train" or _fits(mem):
            break
        if i < len(ladder) - 1:
            print(f"[dryrun] {arch} x {shape_name}: "
                  f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.1f}GiB "
                  f"does not fit; escalating to {ladder[i+1]}", flush=True)
    # free before the counting lowers
    if counting:
        # Trip-count-correct costs from the reduced-depth unrolled lowers.
        counted = counted_metrics(arch, shape_name, multi_pod, **knobs)
        result["counted"] = {k: counted[k] for k in
                             ("flops", "bytes", "coll_moved", "coll_count",
                              "coll_per_kind", "units")}
        corrected_cost = {"flops": counted["flops"],
                          "bytes accessed": counted["bytes"]}
        corrected_colls = {"total_moved_bytes": counted["coll_moved"]}
        result["roofline"] = roofline.roofline_terms(
            cfg, shape, mesh, corrected_cost, corrected_colls, mem
        )
    else:
        # Multi-pod: compile-proof + memory only (roofline is single-pod).
        result["counted"] = {"coll_count": colls["total_count"]}
        result["roofline"] = {
            "fits_hbm": _fits(mem) if mem else None,
            "hbm_need_bytes": mem.get("argument_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0),
            "note": "multi-pod compile proof; roofline from single-pod",
        }
    try:
        result["policy_plan"] = plan_model_policies(cfg, shape)
    except Exception as e:  # report must never sink the compile proof
        result["policy_plan"] = {"error": str(e)}
    result["lower_seconds"] = round(t1 - t0, 2)
    result["knobs"] = knobs
    mesh_tag = "multi" if multi_pod else "single"
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_tag}{('__' + tag) if tag else ''}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    print(f"[dryrun] {arch} x {shape_name} x {mesh_tag}: "
          f"compile={t2 - t1:.1f}s "
          f"dominant={result['roofline'].get('dominant')} "
          f"plan_hit_rate={result['policy_plan'].get('plan_cache_hit_rate', 'n/a')} "
          f"-> {fname}")
    # Required prints per the brief:
    print(json.dumps(result["memory_analysis"]))
    print(json.dumps(result["cost_analysis"]))
    return result


def sweep(out_dir: str, meshes=("single", "multi"), cells=None,
          timeout_s: int = 5400, jobs: int = 1):
    """Run every runnable cell in an isolated subprocess; JSON per cell."""
    from concurrent.futures import ThreadPoolExecutor

    cells = cells or runnable_cells()
    # Riskiest/heaviest archs first so failures surface early.
    risk = ["llama-3.2-vision-90b", "zamba2-2.7b", "mamba2-1.3b",
            "phi3.5-moe-42b-a6.6b", "whisper-small", "qwen2.5-32b"]
    cells = sorted(
        cells, key=lambda c: (risk.index(c[0]) if c[0] in risk else 99)
    )
    work = []
    for mesh_tag in meshes:
        for arch, shape_name in cells:
            fname = os.path.join(
                out_dir, f"{arch}__{shape_name}__{mesh_tag}.json"
            )
            if os.path.exists(fname):
                print(f"[sweep] skip existing {fname}")
                continue
            work.append((arch, shape_name, mesh_tag))

    failures = []

    def run_one(item):
        arch, shape_name, mesh_tag = item
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape_name, "--mesh", mesh_tag,
            "--out", out_dir,
        ] + (["--no-counting"] if mesh_tag == "multi" else [])
        print("[sweep]", " ".join(cmd), flush=True)
        try:
            r = subprocess.run(
                cmd, capture_output=True, text=True, timeout=timeout_s
            )
            if r.returncode != 0:
                failures.append((arch, shape_name, mesh_tag, r.stderr[-2500:]))
                print(f"[sweep] FAIL {arch} {shape_name} {mesh_tag}:\n"
                      f"{r.stderr[-2500:]}", flush=True)
            else:
                print(f"[sweep] OK {arch} {shape_name} {mesh_tag}", flush=True)
        except subprocess.TimeoutExpired:
            failures.append((arch, shape_name, mesh_tag, "timeout"))
            print(f"[sweep] TIMEOUT {arch} {shape_name} {mesh_tag}", flush=True)

    with ThreadPoolExecutor(max_workers=jobs) as pool:
        list(pool.map(run_one, work))
    print(f"[sweep] done, {len(failures)} failures")
    for f in failures:
        print("  FAIL:", f[0], f[1], f[2])
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--remat", default="save_dots",
                    choices=[p.value for p in RematPolicy])
    ap.add_argument("--grad-reduce-dtype", default="float32")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--zero1", action="store_true", default=True)
    ap.add_argument("--tag", default="")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--no-counting", action="store_true")
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--moe-dispatch", default="dense", choices=["dense", "sorted"])
    args = ap.parse_args()

    if args.sweep:
        failures = sweep(args.out, jobs=args.jobs)
        sys.exit(1 if failures else 0)

    knobs = dict(
        remat=args.remat,
        grad_reduce_dtype=args.grad_reduce_dtype,
        microbatch=args.microbatch,
        zero1=args.zero1,
    )
    if args.fsdp != "auto":
        knobs["fsdp"] = args.fsdp == "on"
    if args.moe_dispatch != "dense":
        knobs["moe_dispatch"] = args.moe_dispatch
    try:
        run_cell(
            args.arch, args.shape, args.mesh == "multi", args.out,
            tag=args.tag, counting=not args.no_counting, **knobs,
        )
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
