"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), in seconds (TPU v5e constants):

    t_compute    = HLO_FLOPs/chip   / 197e12 (bf16)
    t_memory     = HLO_bytes/chip   / 819e9
    t_collective = sum(bytes_moved) / (links x 50e9)

``cost_analysis`` supplies FLOPs and bytes; collective bytes come from
parsing the optimized (post-SPMD) HLO: for every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op we sum the result
operand sizes (the per-device module has local shapes) and apply ring
algorithm factors per kind.
"""
from __future__ import annotations

import re
from typing import Any

import numpy as np

from repro import hw
from repro.configs.base import ModelConfig, ShapeConfig

_DTYPE_RE = r"(?:pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)"
_SHAPE_RE = re.compile(rf"({_DTYPE_RE})\[([0-9,]*)\]")
_COLL_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# `%name = TYPE kind(` — TYPE may be a tuple of shapes.
_COLL_LINE = re.compile(
    rf"=\s+(\([^)]*\)|{_DTYPE_RE}\[[0-9,]*\][^ ]*)\s+"
    rf"({'|'.join(_COLL_KINDS)})(-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {
    "pred": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "c64": 8, "c128": 16,
}

# Ring-algorithm bytes-moved-per-participant factors, as multiples of the
# RESULT size parsed from the local module.
#   all-gather: result is the gathered (global) tensor; moved ~ (n-1)/n x result
#   all-reduce: result local; ring moves 2 x (n-1)/n x size
#   reduce-scatter: result is the scattered shard; moved ~ (n-1) x result
#   all-to-all / collective-permute: ~ 1 x result
def _moved_bytes(kind: str, result_bytes: float, group: int) -> float:
    g = max(group, 2)
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    return result_bytes


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict[str, Any]:
    """Sum result bytes + estimated moved bytes per collective kind."""
    per_kind: dict[str, dict[str, float]] = {
        k: {"count": 0, "result_bytes": 0.0, "moved_bytes": 0.0}
        for k in _COLL_KINDS
    }
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_LINE.search(line)
        if not m:
            continue
        type_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        gm = _GROUPS_RE.search(line)
        if gm:
            group = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            group = int(gi.group(2)) if gi else 16
        rb = _shape_bytes(type_str)
        per_kind[kind]["count"] += 1
        per_kind[kind]["result_bytes"] += rb
        per_kind[kind]["moved_bytes"] += _moved_bytes(kind, rb, group)
    total_moved = sum(v["moved_bytes"] for v in per_kind.values())
    total_count = sum(v["count"] for v in per_kind.values())
    del seen_done
    return {"per_kind": per_kind, "total_moved_bytes": total_moved,
            "total_count": total_count}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS: 6*N*D train (fwd+bwd), 2*N*D forward-only."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n * tokens


def roofline_terms(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    cost: dict,
    colls: dict,
    mem: dict,
    chip: hw.Chip = hw.V5E,
) -> dict[str, Any]:
    n_chips = int(np.prod(list(mesh.shape.values())))
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    # cost_analysis on the SPMD module reports PER-DEVICE numbers.
    t_compute = flops / chip.peak_flops_bf16
    t_memory = bytes_accessed / chip.hbm_bw
    t_coll = colls["total_moved_bytes"] / (chip.ici_bw_per_link * chip.ici_links)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get) if any(terms.values()) else "n/a"
    mf = model_flops(cfg, shape)
    mf_chip = mf / n_chips
    useful = mf_chip / flops if flops else 0.0
    bound = max(terms.values()) if any(terms.values()) else 0.0
    # Roofline fraction: useful model FLOP throughput vs peak, given the
    # bound set by the dominant term.
    frac = (mf_chip / chip.peak_flops_bf16) / bound if bound else 0.0
    hbm_need = (mem or {}).get("argument_size_in_bytes", 0) + (mem or {}).get(
        "temp_size_in_bytes", 0
    )
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_total": mf,
        "model_flops_per_chip": mf_chip,
        "useful_compute_ratio": useful,
        "roofline_fraction": frac,
        "fits_hbm": bool(hbm_need <= chip.hbm_bytes) if hbm_need else None,
        "hbm_need_bytes": hbm_need,
    }
