"""ShapeDtypeStruct stand-ins for every model input — the dry-run currency.

Weak-type-correct, shardable, no device allocation.  ``input_specs``
returns the kwargs for the step function selected by the shape's kind:

* train  -> train_step(state, batch)
* prefill -> prefill(params, cache_empty, tokens)
* decode  -> decode_step(params, cache_full, tokens_1)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["vis"] = _sds((b, cfg.n_vis_tokens, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        batch["frames"] = _sds((b, min(cfg.enc_seq, s), cfg.d_model), cfg.dtype)
    return batch


def cache_shape(model, cfg: ModelConfig, batch: int, max_len: int):
    """Shape-only serving cache via eval_shape (no allocation)."""
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["vis"] = _sds((batch, cfg.n_vis_tokens, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        kwargs["frames"] = _sds(
            (batch, min(cfg.enc_seq, max_len), cfg.d_model), cfg.dtype
        )

    params_shape = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    cache = jax.eval_shape(
        lambda p, kw: model.init_cache(p, batch=batch, max_len=max_len, **kw),
        params_shape, kwargs,
    )
    return cache, kwargs


def input_specs(arch: str, shape_name: str, model=None, cfg=None,
                smoke: bool = False) -> dict[str, Any]:
    """All ShapeDtypeStructs needed to lower the cell's step function."""
    from repro.models import build_model, get_config
    from repro.train.step import init_train_state

    cfg = cfg or get_config(arch, smoke=smoke)
    model = model or build_model(cfg)
    shape = SHAPES[shape_name]

    params_shape = jax.eval_shape(
        lambda k: init_train_state(model, k), jax.random.PRNGKey(0)
    )
    if shape.kind == "train":
        return {
            "kind": "train",
            "state": params_shape,
            "batch": batch_specs(cfg, shape),
        }
    b = shape.global_batch
    if shape.kind == "prefill":
        cache, extra = cache_shape(model, cfg, b, shape.seq_len)
        return {
            "kind": "prefill",
            "params": params_shape["params"],
            "cache": cache,
            "tokens": _sds((b, shape.seq_len), jnp.int32),
            "extras": extra,
        }
    # decode: one new token against a full cache of seq_len.
    cache, extra = cache_shape(model, cfg, b, shape.seq_len)
    return {
        "kind": "decode",
        "params": params_shape["params"],
        "cache": cache,
        "tokens": _sds((b, 1), jnp.int32),
        "extras": extra,
    }
