"""Roofline cost model with caching-overhead terms (paper §VI.C).

Models the execution time of one op under a policy assignment as

    t_total = max(t_compute, t_hbm) + t_overhead

where the overhead term carries the paper's two caching costs, adapted to a
software-managed hierarchy (DESIGN.md §2):

* **stalls** — on the GPU these are blocked cache allocations; here they are
  the contention charged when an operand is held RESIDENT but its reuse
  window exceeds the residency budget (thrash regime).  Allocation-Bypass
  (``allocation_bypass=True``) eliminates the stall term, exactly as the
  paper's AB converts blocking allocations into bypasses.
* **write-locality disruption** — DRAM row-hit loss becomes an HBM
  write-burst *contiguity* derate.  Coalesced (RESIDENT_ACCUM) writebacks
  scatter unless the rinse scheduler orders them; rinsing restores
  contiguity, exactly as the paper's CR restores row hits.

Calibration constants live in :data:`CALIB`; magnitudes are matched to the
paper's reported ranges (caching hurts throughput-sensitive workloads by up
to ~24%, write coalescing wins up to ~32%).
"""
from __future__ import annotations

import dataclasses

from repro import hw
from repro.core.policy import (
    Assignment,
    OperandProfile,
    OpSpec,
    Policy,
    StaticMode,
    reuse_density,
    static_assignment,
)


@dataclasses.dataclass(frozen=True)
class CostCalib:
    # Fraction of peak FLOP/s a well-tiled kernel achieves (MXU/SIMD realism).
    achieved_compute_frac: float = 0.6
    # Max fraction of HBM time added by allocation-blocking stalls (paper: the
    # throughput-sensitive degradations top out ~24%).
    max_stall_frac: float = 0.12
    # Write contiguity of delayed/coalesced writebacks without rinsing.
    coalesce_contiguity: float = 0.7
    # ... and with row-locality-aware rinsing (paper Fig 13: CR beats best static).
    rinse_contiguity: float = 0.98
    # Effective-bandwidth floor for fully scattered writes (burst efficiency).
    burst_floor: float = 0.35
    # Fixed kernel-launch cost (dispatch + DMA warmup).
    launch_overhead_s: float = 2e-6
    # Default streaming tile (double-buffered) for VMEM claims.
    stream_tile_bytes: int = 2 * 1024 * 1024
    # Residency accumulator claim cap (fp32 output tile).
    accum_tile_bytes: int = 512 * 1024
    # AB demotes (reports) resident operands realizing less than this fraction.
    demote_threshold: float = 0.5


CALIB = CostCalib()


@dataclasses.dataclass
class ResidencyPlan:
    """How much of each RESIDENT operand's reuse window actually fits."""

    realized: dict[str, float]
    vmem_claimed: int
    demotions: tuple[str, ...]


@dataclasses.dataclass
class CostBreakdown:
    t_compute: float = 0.0
    t_hbm: float = 0.0
    t_overhead: float = 0.0
    t_total: float = 0.0
    read_bytes: float = 0.0
    write_bytes: float = 0.0
    write_contiguity: float = 1.0
    stall_frac: float = 0.0
    launches: int = 0
    demotions: int = 0
    vmem_claimed: int = 0

    @property
    def hbm_bytes(self) -> float:
        return self.read_bytes + self.write_bytes

    def add(self, other: "CostBreakdown") -> "CostBreakdown":
        w = self.write_bytes + other.write_bytes
        self.write_contiguity = (
            (self.write_contiguity * self.write_bytes
             + other.write_contiguity * other.write_bytes) / w
            if w else 1.0
        )
        self.t_compute += other.t_compute
        self.t_hbm += other.t_hbm
        self.t_overhead += other.t_overhead
        self.t_total += other.t_total
        self.read_bytes += other.read_bytes
        self.write_bytes += other.write_bytes
        self.stall_frac = max(self.stall_frac, other.stall_frac)
        self.launches += other.launches
        self.demotions += other.demotions
        self.vmem_claimed = max(self.vmem_claimed, other.vmem_claimed)
        return self


def _peak_flops(chip: hw.Chip, dtype: str) -> float:
    nbytes = hw.dtype_bytes(dtype)
    if nbytes <= 2:
        return chip.peak_flops_bf16
    if nbytes == 4:
        return chip.peak_flops_fp32
    return chip.peak_flops_fp32 / 2  # fp64


def _stream_tile(chip: hw.Chip, calib: CostCalib) -> int:
    """Streaming double-buffer tile, scaled to the chip's residency budget."""
    return min(calib.stream_tile_bytes, chip.vmem_budget // 8)


def plan_residency(
    op: OpSpec,
    assignment: Assignment,
    chip: hw.Chip,
    calib: CostCalib = CALIB,
) -> ResidencyPlan:
    """Greedy residency-budget allocation (reuse-densest operands first)."""
    budget = chip.vmem_budget
    tile = _stream_tile(chip, calib)
    # Reserve double-buffers for every streamed input and accumulators for
    # coalesced outputs first — these are mandatory.
    for o in op.operands:
        pol = assignment[o.name]
        if o.is_output:
            if pol is Policy.RESIDENT_ACCUM:
                budget -= min(o.unique_bytes * 2, calib.accum_tile_bytes)
            else:
                budget -= min(o.unique_bytes, tile)
        elif pol is Policy.STREAM:
            budget -= 2 * min(o.unique_bytes, tile)
    budget = max(budget, 0)

    resident = [o for o in op.inputs if assignment[o.name] is Policy.RESIDENT]
    realized: dict[str, float] = {}
    claimed = chip.vmem_budget - budget
    for o in sorted(resident, key=reuse_density, reverse=True):
        take = min(o.window_bytes, budget)
        realized[o.name] = take / max(o.window_bytes, 1)
        budget -= take
        claimed += take
    demotions = tuple(
        name for name, frac in realized.items() if frac < calib.demote_threshold
    )
    return ResidencyPlan(realized=realized, vmem_claimed=claimed, demotions=demotions)


def op_cost(
    op: OpSpec,
    assignment: Assignment | None = None,
    mode: StaticMode | None = None,
    chip: hw.Chip = hw.V5E,
    allocation_bypass: bool = True,
    rinse: bool = True,
    launches: int = 1,
    calib: CostCalib = CALIB,
) -> CostBreakdown:
    """Model one op's execution time under a policy assignment."""
    if assignment is None:
        assignment = static_assignment(op, mode or StaticMode.UNCACHED)
    res = plan_residency(op, assignment, chip, calib)

    read_bytes = 0.0
    stall = 0.0
    for o in op.inputs:
        pol = assignment[o.name]
        if pol is Policy.RESIDENT:
            frac = res.realized.get(o.name, 0.0)
            # Partial residency: reuse captured proportionally to the window
            # fraction that fits (cache-thrash regime when frac << 1).
            traffic = o.touched_bytes_stream - (
                (o.touched_bytes_stream - o.unique_bytes) * frac
            )
            if frac < 1.0 and not allocation_bypass:
                stall = max(stall, calib.max_stall_frac * (1.0 - frac))
        else:
            traffic = float(o.touched_bytes_stream)
        read_bytes += traffic

    write_bytes = 0.0
    contig_acc = 0.0
    for o in op.outputs:
        pol = assignment[o.name]
        traffic = float(o.hbm_bytes(pol))
        if pol is Policy.RESIDENT_ACCUM:
            c = max(calib.rinse_contiguity, o.contiguity) if rinse else (
                o.contiguity * calib.coalesce_contiguity
            )
            if traffic > o.unique_bytes:
                # Partial write-through still re-reads partials.
                read_bytes += traffic - o.unique_bytes
                traffic = float(o.unique_bytes)
        else:
            c = o.contiguity * (1.0 - stall)
        write_bytes += traffic
        contig_acc += c * traffic
    contiguity = contig_acc / write_bytes if write_bytes else 1.0

    eff = float(op.meta.get("achieved_eff", calib.achieved_compute_frac))
    t_compute = op.flops / (_peak_flops(chip, op.dtype) * max(eff, 1e-3))
    bw_eff = calib.burst_floor + (1.0 - calib.burst_floor) * contiguity
    t_hbm = read_bytes / chip.hbm_bw + write_bytes / (chip.hbm_bw * bw_eff)
    t_overhead = stall * t_hbm + launches * calib.launch_overhead_s
    return CostBreakdown(
        t_compute=t_compute,
        t_hbm=t_hbm,
        t_overhead=t_overhead,
        t_total=max(t_compute, t_hbm) + t_overhead,
        read_bytes=read_bytes,
        write_bytes=write_bytes,
        write_contiguity=contiguity,
        stall_frac=stall,
        launches=launches,
        demotions=len(res.demotions),
        vmem_claimed=res.vmem_claimed,
    )


def adaptive_assignment(
    op: OpSpec, chip: hw.Chip = hw.V5E, calib: CostCalib = CALIB
) -> Assignment:
    """Cost-model-seeded per-operand policy (the PCby criterion, §VII.C):
    cache exactly the accesses whose reuse is realizable and beneficial."""
    a: Assignment = {}
    tile = _stream_tile(chip, calib)
    budget = chip.vmem_budget
    for o in op.operands:
        if o.is_output:
            a[o.name] = Policy.RESIDENT_ACCUM if o.revisits > 1 else Policy.STREAM
            budget -= (
                min(o.unique_bytes * 2, calib.accum_tile_bytes)
                if o.revisits > 1 else min(o.unique_bytes, tile)
            )
        else:
            a[o.name] = Policy.STREAM
            budget -= 2 * min(o.unique_bytes, tile)
    # Residency candidates, densest first, greedily while they fit.  A
    # promoted operand trades its streaming double-buffer for its window.
    cands = [o for o in op.inputs if o.reuse_factor > 1.1]
    cands.sort(key=reuse_density, reverse=True)
    for o in cands:
        extra = o.window_bytes - 2 * min(o.unique_bytes, tile)
        if extra <= budget:
            a[o.name] = Policy.RESIDENT
            budget -= extra
    return a


def workload_cost(
    ops: list[OpSpec],
    mode: StaticMode = StaticMode.UNCACHED,
    chip: hw.Chip = hw.V5E,
    allocation_bypass: bool | None = None,
    rinse: bool | None = None,
    launches_per_op: int = 1,
    calib: CostCalib = CALIB,
    search: str = "exact",
    memoize: bool = True,
    plan_cache=None,
) -> CostBreakdown:
    """Sum of op costs under a static mode or the adaptive engine.

    Static modes default to the paper's *baseline* machine behaviour:
    blocking allocation, no rinse.  ADAPTIVE defaults to AB+CR+PCby on.

    ``search`` picks the adaptive-mode assignment: ``"exact"`` (lattice
    argmin via ``core.sweep``, never worse than greedy) or ``"greedy"``
    (the original ``adaptive_assignment`` walk).  ``memoize`` routes
    plan/cost evaluation through the :class:`~repro.core.planner.PlanCache`
    (``plan_cache``, or the shared default) — cached results are
    bit-identical to cold ones, so this only changes wall time.
    """
    adaptive = mode is StaticMode.ADAPTIVE
    ab = adaptive if allocation_bypass is None else allocation_bypass
    rn = adaptive if rinse is None else rinse
    planner = None
    if memoize or (adaptive and search == "exact"):
        from repro.core.planner import Planner  # local: avoid import cycle

        planner = Planner(chip=chip, calib=calib, cache=plan_cache)
    total = CostBreakdown()
    for op in ops:
        if adaptive:
            if search == "exact":
                assignment = planner.optimal_assignment(
                    op, allocation_bypass=ab, rinse=rn
                )
            else:
                assignment = adaptive_assignment(op, chip, calib)
        else:
            assignment = static_assignment(op, mode)
        if memoize:
            bd = planner.cost(
                op, assignment=assignment, allocation_bypass=ab, rinse=rn,
                launches=launches_per_op,
            )
        else:
            bd = op_cost(
                op,
                assignment=assignment,
                chip=chip,
                allocation_bypass=ab,
                rinse=rn,
                launches=launches_per_op,
                calib=calib,
            )
        total.add(bd)
    return total
