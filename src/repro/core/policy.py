"""Memory-policy vocabulary: the TPU adaptation of the paper's GPU cache policies.

Paper policy -> TPU software-managed analogue (see DESIGN.md §2):

* ``Uncached``  -> every operand ``STREAM``ed (tiles fetched per use, never kept).
* ``CacheR``    -> reused *read* operands ``RESIDENT`` in VMEM across grid steps.
* ``CacheRW``   -> additionally, outputs ``RESIDENT_ACCUM``: accumulated in VMEM
  across the contraction grid dimension and written back once (write coalescing).
"""
from __future__ import annotations

import dataclasses
import enum
import math
import types
from typing import Any


class Policy(enum.Enum):
    """Per-operand memory policy."""

    STREAM = "stream"                  # bypass: fetch/write tiles exactly when used
    RESIDENT = "resident"              # pin whole operand in VMEM (read caching)
    RESIDENT_ACCUM = "resident_accum"  # accumulate output tiles in VMEM (write coalescing)


class StaticMode(enum.Enum):
    """The paper's static configurations plus the adaptive mode of §VII."""

    UNCACHED = "uncached"
    CACHER = "cacher"
    CACHERW = "cacherw"
    ADAPTIVE = "adaptive"


class WorkloadClass(enum.Enum):
    """Paper §VI.A classification."""

    MEMORY_INSENSITIVE = "memory_insensitive"
    REUSE_SENSITIVE = "reuse_sensitive"
    THROUGHPUT_SENSITIVE = "throughput_sensitive"


@dataclasses.dataclass(frozen=True)
class OperandProfile:
    """Analytical access characterization for one operand of one op.

    ``reuse_factor`` is the mean number of touches per element over the op's
    schedule (1.0 == no temporal reuse).  ``touched_bytes_stream`` is HBM
    traffic if the operand is STREAMed (refetched per revisit);
    ``unique_bytes`` is the traffic if RESIDENT (single fetch / single
    writeback).  ``contiguity`` in [0,1]: fraction of naturally sequential
    accesses under the default row-major schedule.
    """

    name: str
    role: str                 # "input" | "output"
    shape: tuple[int, ...]
    dtype: str
    unique_bytes: int
    touched_bytes_stream: int
    contiguity: float = 1.0
    # For outputs: number of partial-update visits per element (K-dim revisits).
    revisits: int = 1
    # Working set that must stay resident to actually capture the reuse
    # (the reuse *distance* in bytes).  None -> the whole operand.  Reuse whose
    # window exceeds VMEM capacity is NOT realizable by caching — this is what
    # makes FwLRN "throughput sensitive" in the paper despite its 5-wide
    # window reuse: the reuse distance exceeds the 4MB L2.
    reuse_window_bytes: int | None = None

    @property
    def is_output(self) -> bool:
        return self.role == "output"

    @property
    def window_bytes(self) -> int:
        return self.unique_bytes if self.reuse_window_bytes is None else self.reuse_window_bytes

    @property
    def reuse_factor(self) -> float:
        if self.unique_bytes == 0:
            return 1.0
        return self.touched_bytes_stream / self.unique_bytes

    def hbm_bytes(self, policy: Policy) -> int:
        """HBM traffic attributed to this operand under ``policy``."""
        if self.is_output:
            if policy is Policy.RESIDENT_ACCUM:
                return self.unique_bytes  # written back once
            # write-through partials: each revisit writes (and all but the
            # final revisit later re-reads) the element.
            return self.unique_bytes * max(1, 2 * self.revisits - 1)
        if policy is Policy.RESIDENT:
            return self.unique_bytes
        return self.touched_bytes_stream


def reuse_density(o: OperandProfile) -> float:
    """Traffic saved per resident byte — the single residency-priority
    metric shared by the greedy planners and the vectorized sweep (their
    orderings must agree exactly for the sweep==scalar invariants)."""
    return (o.touched_bytes_stream - o.unique_bytes) / max(o.window_bytes, 1)


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Shape-level description of one operator instance (a kernel launch)."""

    kind: str                                  # "matmul", "attention", "elementwise", ...
    operands: tuple[OperandProfile, ...]
    flops: float
    dtype: str = "bf16"
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)
    name: str = ""

    def __post_init__(self):
        # Freeze meta: the plan cache fingerprints ops by structural content
        # (including meta), so in-place mutation would silently alias stale
        # cache entries.  A read-only view makes it fail loudly instead;
        # derive variants with dataclasses.replace(op, meta={...}).
        if not isinstance(self.meta, types.MappingProxyType):
            object.__setattr__(
                self, "meta", types.MappingProxyType(dict(self.meta))
            )

    def operand(self, name: str) -> OperandProfile:
        for o in self.operands:
            if o.name == name:
                return o
        raise KeyError(name)

    @property
    def inputs(self) -> tuple[OperandProfile, ...]:
        return tuple(o for o in self.operands if not o.is_output)

    @property
    def outputs(self) -> tuple[OperandProfile, ...]:
        return tuple(o for o in self.operands if o.is_output)

    def unique_bytes(self) -> int:
        return sum(o.unique_bytes for o in self.operands)

    def arithmetic_intensity(self) -> float:
        """FLOP per *unique* byte — the best-case (fully cached) intensity."""
        b = self.unique_bytes()
        return self.flops / b if b else math.inf


# An assignment maps operand name -> Policy.
Assignment = dict[str, Policy]


def static_assignment(op: OpSpec, mode: StaticMode) -> Assignment:
    """The paper's static policies applied uniformly to an op."""
    if mode is StaticMode.ADAPTIVE:
        raise ValueError("adaptive mode has no static assignment; use the engine")
    a: Assignment = {}
    for o in op.operands:
        if o.is_output:
            a[o.name] = (
                Policy.RESIDENT_ACCUM if mode is StaticMode.CACHERW else Policy.STREAM
            )
        else:
            a[o.name] = (
                Policy.RESIDENT
                if mode in (StaticMode.CACHER, StaticMode.CACHERW)
                else Policy.STREAM
            )
    return a


@dataclasses.dataclass
class KernelPlan:
    """Concrete, VMEM-feasible execution plan for one op.

    Produced by the engine (characterize -> predict -> allocate -> rinse) and
    consumed by the Pallas kernels in ``repro.kernels`` and by the cost model.
    """

    op: OpSpec
    assignment: Assignment
    block: dict[str, int]            # logical dim name -> tile size (MXU-aligned)
    grid_order: tuple[str, ...]      # loop nest, innermost last
    vmem_bytes: int                  # total VMEM claimed (incl. double buffers)
    demotions: tuple[str, ...] = ()  # operands demoted RESIDENT->STREAM (alloc bypass)
    shrink_events: int = 0           # times tiles were shrunk under pressure (stall proxy)
    rinse: bool = True               # contiguous flush scheduling enabled
    notes: str = ""

    def policy(self, operand: str) -> Policy:
        return self.assignment[operand]
