"""CachePolicyEngine — the paper's adaptive mechanism as one composable object.

Pipeline per op: characterize (OpSpec) -> predict (PCby site table) ->
allocate (AB non-blocking VMEM planner) -> rinse (grid/flush order).
Output: a :class:`KernelPlan` consumed by the Pallas kernels, plus modeled
cost for reporting/feedback.

Planning is memoized (DESIGN.md §3): every plan/cost query routes through a
:class:`~repro.core.planner.Planner`, so an op that launches repeatedly
(RNN cells, each transformer layer) plans once and hits the
:class:`~repro.core.planner.PlanCache` thereafter.  ``plan_stats()``
exposes the hit/miss counters.

The engine also owns the trainer-level activation policy (remat) and is the
single switch between the paper's static baselines and the adaptive mode.
"""
from __future__ import annotations

import dataclasses

from repro import hw
from repro.core import allocator, remat
from repro.core.planner import PlanCache, Planner
from repro.core.policy import (
    Assignment,
    KernelPlan,
    OpSpec,
    Policy,
    StaticMode,
    static_assignment,
)
from repro.core.predictor import PolicyPredictor


@dataclasses.dataclass
class EngineConfig:
    mode: StaticMode = StaticMode.ADAPTIVE
    allocation_bypass: bool = True
    rinse: bool = True
    chip_name: str = "tpu-v5e"

    @property
    def chip(self) -> hw.Chip:
        return hw.PAPER_GPU if self.chip_name == "gem5-apu" else hw.V5E


class CachePolicyEngine:
    def __init__(
        self,
        config: EngineConfig | None = None,
        predictor: PolicyPredictor | None = None,
        plan_cache: PlanCache | None = None,
    ):
        self.config = config or EngineConfig()
        self.chip = self.config.chip
        self.planner = Planner(chip=self.chip, cache=plan_cache)
        self.predictor = predictor or PolicyPredictor(
            chip=self.chip, planner=self.planner
        )

    # -- per-op planning ----------------------------------------------------

    def assign(self, op: OpSpec) -> Assignment:
        if self.config.mode is StaticMode.ADAPTIVE:
            return self.predictor.predict(
                op,
                allocation_bypass=self.config.allocation_bypass,
                rinse=self.config.rinse,
            )
        return static_assignment(op, self.config.mode)

    def plan_op(self, op: OpSpec) -> KernelPlan:
        return self.planner.plan(
            op,
            self.assign(op),
            allocation_bypass=self.config.allocation_bypass,
            rinse=self.config.rinse,
        )

    def cost(self, op: OpSpec, plan: KernelPlan | None = None):
        plan = plan or self.plan_op(op)
        breakdown = self.planner.cost(
            op,
            assignment=plan.assignment,
            allocation_bypass=self.config.allocation_bypass,
            rinse=self.config.rinse,
        )
        # Fold MXU starvation from shrunken tiles into compute time.
        eff = allocator.mxu_efficiency(plan, self.chip)
        breakdown.t_compute /= eff
        breakdown.t_total = (
            max(breakdown.t_compute, breakdown.t_hbm) + breakdown.t_overhead
        )
        return breakdown

    def feedback(self, op: OpSpec, plan: KernelPlan, measured_time: float) -> None:
        """Close the loop: compare against the bypass baseline and update
        the predictor's confidence counters."""
        baseline = self.planner.cost(
            op, mode=StaticMode.UNCACHED
        ).t_total
        benefit = (baseline - measured_time) / max(baseline, 1e-30)
        self.predictor.update(op, plan.assignment, benefit)

    # -- cache visibility ----------------------------------------------------

    @property
    def plan_cache(self) -> PlanCache:
        return self.planner.cache

    def plan_stats(self) -> dict:
        return self.planner.stats()

    # -- trainer-level activation policy ------------------------------------

    def remat_policy(
        self,
        activation_bytes_per_layer: float,
        n_layers: int,
        hbm_free_bytes: float | None = None,
    ) -> remat.RematPolicy:
        free = self.chip.hbm_bytes * 0.6 if hbm_free_bytes is None else hbm_free_bytes
        return remat.choose_policy(activation_bytes_per_layer, n_layers, free)

    # -- reporting -----------------------------------------------------------

    def kv_policy(self, kv_bytes_per_layer: int) -> Policy:
        """Serving-side: keep a layer's KV block resident in VMEM during the
        decode kernel only if it fits the budget share; else stream it."""
        if kv_bytes_per_layer <= self.chip.vmem_budget // 4:
            return Policy.RESIDENT
        return Policy.STREAM


def make_engine(
    mode: str = "adaptive",
    allocation_bypass: bool = True,
    rinse: bool = True,
    chip: str = "tpu-v5e",
    plan_cache: PlanCache | None = None,
) -> CachePolicyEngine:
    return CachePolicyEngine(
        EngineConfig(
            mode=StaticMode(mode),
            allocation_bypass=allocation_bypass,
            rinse=rinse,
            chip_name=chip,
        ),
        plan_cache=plan_cache,
    )
