"""Row-locality-aware rinsing (§VII.B) adapted to HBM write-burst contiguity.

The paper attaches a *dirty-block index* to the GPU L2: evicting one dirty
block triggers writeback of every dirty block in the same DRAM row, so
writebacks arrive at the memory controller as row-local bursts.

On TPU the analogue is twofold:

1. **Static** (`plan_grid_order`): pick the kernel grid iteration order so
   output tiles are written in address order — coalesced writebacks sweep
   HBM contiguously instead of scattering across rows.
2. **Dynamic** (`DirtyIndex`): for software-managed dirty state that *is*
   flushed on events (KV-cache pages, gradient-accumulation buckets), keep a
   dirty index per contiguous HBM region and flush whole regions together.
   `repro.train` uses this to schedule bucketed ("rinsed") gradient
   reductions.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from repro import hw
from repro.core.policy import Assignment, OpSpec, Policy


# ---------------------------------------------------------------------------
# Static: grid-order planning for kernels
# ---------------------------------------------------------------------------

def plan_grid_order(
    op: OpSpec,
    assignment: Assignment,
    chip: hw.Chip = hw.V5E,
    rinse: bool = True,
) -> tuple[tuple[str, ...], float]:
    """Loop-nest order (innermost last) + estimated write contiguity."""
    if op.kind in ("matmul", "conv2d"):
        out = op.outputs[0]
        accum = assignment[out.name] is Policy.RESIDENT_ACCUM
        if accum:
            # k innermost: each (m, n) tile written exactly once, and with
            # rinse the (m, n) sweep is row-major => address-ordered bursts.
            order = ("m", "n", "k") if rinse else ("n", "m", "k")
        else:
            # Write-through partials: k outermost revisits the whole output
            # per k step — inherently scattered revisits.
            order = ("k", "m", "n")
        contig = _matmul_contiguity(op, order, rinse, chip)
        return order, contig
    if op.kind == "attention":
        return ("batch_head", "q", "kv"), 0.98 if rinse else 0.8
    return ("e",), 1.0


def _matmul_contiguity(
    op: OpSpec, order: tuple[str, ...], rinse: bool, chip: hw.Chip
) -> float:
    n = op.meta.get("n", 1)
    bn = op.meta.get("bn", n)
    eb = hw.dtype_bytes(op.outputs[0].dtype)
    run = min(bn, n) * eb  # contiguous run per tile row
    base = min(1.0, run / chip.hbm_burst_bytes)
    if order[0] == "k":      # revisiting partial writes
        base *= 0.6
    if order[0] == "n":      # column-major tile sweep: rows interleave
        base *= 0.7
    if rinse:
        base = max(base, 0.95)
    return base


# ---------------------------------------------------------------------------
# Dynamic: dirty-region index for event-driven flushes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Extent:
    addr: int
    size: int

    @property
    def end(self) -> int:
        return self.addr + self.size


class DirtyIndex:
    """Dirty-block index over contiguous HBM regions (paper's DBI [58])."""

    def __init__(self, region_bytes: int = 4096):
        assert region_bytes > 0
        self.region_bytes = region_bytes
        self._dirty: dict[int, dict[int, Extent]] = defaultdict(dict)
        self._tile_region: dict[int, int] = {}

    def _region(self, addr: int) -> int:
        return addr // self.region_bytes

    def mark(self, tile_id: int, addr: int, size: int) -> None:
        """Record tile_id as dirty over [addr, addr+size)."""
        r = self._region(addr)
        self._dirty[r][tile_id] = Extent(addr, size)
        self._tile_region[tile_id] = r

    @property
    def dirty_tiles(self) -> int:
        return sum(len(v) for v in self._dirty.values())

    def evict(self, tile_id: int, rinse: bool = True) -> list[tuple[int, Extent]]:
        """Flush list triggered by evicting ``tile_id``.

        With rinsing, every dirty tile in the same region flushes together
        (address-sorted); without, only the evicted tile flushes.
        """
        if tile_id not in self._tile_region:
            return []
        r = self._tile_region[tile_id]
        if rinse:
            victims = sorted(self._dirty[r].items(), key=lambda kv: kv[1].addr)
            for tid, _ in victims:
                del self._tile_region[tid]
            del self._dirty[r]
            return victims
        ext = self._dirty[r].pop(tile_id)
        del self._tile_region[tile_id]
        if not self._dirty[r]:
            del self._dirty[r]
        return [(tile_id, ext)]

    def flush_all(self, rinse: bool = True) -> list[tuple[int, Extent]]:
        out: list[tuple[int, Extent]] = []
        regions = sorted(self._dirty) if rinse else list(self._dirty)
        for r in regions:
            items = self._dirty[r].items()
            items = sorted(items, key=lambda kv: kv[1].addr) if rinse else list(items)
            out.extend(items)
        self._dirty.clear()
        self._tile_region.clear()
        return out


def write_contiguity(
    flushes: list[Extent], burst_bytes: int = hw.V5E.hbm_burst_bytes
) -> float:
    """Fraction of flushed bytes that land in contiguous runs >= one burst.

    Evaluates the *sequence* (not the set) of writes: only back-to-back
    address-adjacent extents merge into a run.
    """
    if not flushes:
        return 1.0
    total = 0
    covered = 0
    run = 0
    prev_end: int | None = None
    for e in flushes:
        total += e.size
        if prev_end is not None and e.addr == prev_end:
            run += e.size
        else:
            covered += (run // burst_bytes) * burst_bytes
            run = e.size
        prev_end = e.end
    covered += (run // burst_bytes) * burst_bytes
    return covered / total if total else 1.0


def bucket_flush_schedule(
    sizes: list[int], bucket_bytes: int
) -> list[list[int]]:
    """Group gradient tensors (by index) into contiguous flush buckets.

    The distributed-training use of rinsing: instead of one collective per
    tensor (scattered small flushes) or one giant end-of-step flush (no
    overlap), dirty tensors flush in contiguous, size-bounded buckets.
    """
    buckets: list[list[int]] = []
    cur: list[int] = []
    acc = 0
    for i, s in enumerate(sizes):
        if cur and acc + s > bucket_bytes:
            buckets.append(cur)
            cur, acc = [], 0
        cur.append(i)
        acc += s
    if cur:
        buckets.append(cur)
    return buckets
