"""Activation caching policies for training — the paper's question, one level up.

Saving activations for the backward pass IS a caching decision: HBM is the
"cache" for the backward pass's recompute stream.  The same
characterize->predict->plan structure assigns a per-layer policy:

* ``SAVE_ALL``   -> RESIDENT: keep every activation (fast bwd, max HBM)
* ``SAVE_DOTS``  -> selective: keep matmul outputs only (the reuse-dense
  accesses — the PCby criterion applied to activations)
* ``RECOMPUTE``  -> STREAM: full rematerialization (min HBM, ~+33% FLOPs)

``choose_policy`` applies allocation-bypass logic to the HBM budget: prefer
residency, demote toward recompute only under capacity pressure, never "OOM
stall".
"""
from __future__ import annotations

import enum

import jax


class RematPolicy(enum.Enum):
    SAVE_ALL = "save_all"
    SAVE_DOTS = "save_dots"
    RECOMPUTE = "recompute"


def apply_remat(fn, policy: RematPolicy):
    """Wrap a layer-apply function with the chosen activation policy."""
    if policy is RematPolicy.SAVE_ALL:
        return fn
    if policy is RematPolicy.SAVE_DOTS:
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def choose_policy(
    activation_bytes_per_layer: float,
    n_layers: int,
    hbm_free_bytes: float,
    safety_frac: float = 0.9,
) -> RematPolicy:
    """Pick the most residency-friendly policy that fits the HBM budget.

    ``activation_bytes_per_layer`` is the per-device saved-activation
    footprint of one layer under SAVE_ALL; SAVE_DOTS is modeled at ~45% of
    that (matmul outputs only); RECOMPUTE at ~6% (layer boundaries only).
    """
    budget = hbm_free_bytes * safety_frac
    full = activation_bytes_per_layer * n_layers
    if full <= budget:
        return RematPolicy.SAVE_ALL
    if full * 0.45 <= budget:
        return RematPolicy.SAVE_DOTS
    return RematPolicy.RECOMPUTE


def extra_flops_factor(policy: RematPolicy) -> float:
    """Forward-recompute overhead factor on total train-step FLOPs."""
    return {
        RematPolicy.SAVE_ALL: 1.0,
        RematPolicy.SAVE_DOTS: 1.12,
        RematPolicy.RECOMPUTE: 1.33,
    }[policy]
