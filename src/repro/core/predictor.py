"""Site-based policy prediction — the PC-based L2 bypass analogue (§VII.C).

The paper uses the load instruction's program counter to index a reuse
predictor [54].  On a statically-scheduled TPU the natural "PC" is the *op
site*: (op kind, operand role, size class, reuse class, dtype) — every
texturally distinct access site in the traced program maps to one key.

The predictor is seeded from the analytical cost model — the *exact*
lattice optimum of ``core.sweep`` (never worse than the greedy walk;
DESIGN.md §3) — then updated with observed benefit via saturating
confidence counters, mirroring the hardware predictor's
increment/decrement behaviour.  State persists to JSON — the software
equivalent of the paper's own methodology of reusing MIOpen's tuned-kernel
database across runs.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os

from repro import hw
from repro.core.cost_model import CALIB, CostCalib
from repro.core.policy import Assignment, OperandProfile, OpSpec, Policy

_CONF_MAX = 3    # 2-bit saturating counter, as in [54]
_CONF_INIT = 2
_CONF_FLIP = 0


@dataclasses.dataclass(frozen=True)
class SiteKey:
    op_kind: str
    operand: str
    role: str
    size_class: int     # log2 bucket of unique bytes
    reuse_class: int    # log2 bucket of reuse factor
    dtype: str

    @classmethod
    def from_profile(cls, op: OpSpec, o: OperandProfile) -> "SiteKey":
        return cls(
            op_kind=op.kind,
            operand=o.name,
            role=o.role,
            size_class=int(math.log2(max(o.unique_bytes, 1))),
            reuse_class=int(math.log2(max(o.reuse_factor, 1.0)) + 0.5),
            dtype=str(o.dtype),
        )

    def encode(self) -> str:
        return "|".join(
            [self.op_kind, self.operand, self.role, str(self.size_class),
             str(self.reuse_class), self.dtype]
        )

    @classmethod
    def decode(cls, s: str) -> "SiteKey":
        k, operand, role, sc, rc, dt = s.split("|")
        return cls(k, operand, role, int(sc), int(rc), dt)


@dataclasses.dataclass
class _Entry:
    policy: str
    confidence: int = _CONF_INIT
    updates: int = 0


class PolicyPredictor:
    """Per-site policy table with saturating-counter feedback."""

    def __init__(
        self,
        chip: hw.Chip = hw.V5E,
        calib: CostCalib = CALIB,
        planner=None,
    ):
        self.chip = chip
        self.calib = calib
        self.table: dict[SiteKey, _Entry] = {}
        if planner is None:
            from repro.core.planner import Planner  # local: avoid cycle

            planner = Planner(chip=chip, calib=calib)
        self.planner = planner

    # -- prediction ---------------------------------------------------------

    def predict(
        self,
        op: OpSpec,
        allocation_bypass: bool = True,
        rinse: bool = True,
    ) -> Assignment:
        """Site-table prediction, seeded from the lattice optimum under the
        machine model actually in force (AB/rinse knobs)."""
        seed = self.planner.optimal_assignment(
            op, allocation_bypass=allocation_bypass, rinse=rinse
        )
        out: Assignment = {}
        for o in op.operands:
            key = SiteKey.from_profile(op, o)
            entry = self.table.get(key)
            if entry is None:
                entry = _Entry(policy=seed[o.name].value)
                self.table[key] = entry
            out[o.name] = Policy(entry.policy)
        return out

    # -- feedback -----------------------------------------------------------

    def update(self, op: OpSpec, assignment: Assignment, benefit: float) -> None:
        """Reinforce or decay each site's decision.

        ``benefit`` > 0: the chosen assignment beat the bypass baseline.
        ``benefit`` < 0: it lost — decrement; at zero confidence the site
        flips to STREAM (bypass), like the hardware predictor's default.
        """
        for o in op.operands:
            key = SiteKey.from_profile(op, o)
            entry = self.table.get(key)
            if entry is None:
                entry = _Entry(policy=assignment[o.name].value)
                self.table[key] = entry
            if Policy(entry.policy) is not assignment[o.name]:
                # Feedback describes a policy this site no longer uses.
                continue
            entry.updates += 1
            if benefit >= 0:
                entry.confidence = min(_CONF_MAX, entry.confidence + 1)
            else:
                entry.confidence -= 1
                if entry.confidence <= _CONF_FLIP and (
                    Policy(entry.policy) is not Policy.STREAM
                ):
                    # Losing caching policies flip to bypass and stay — the
                    # safe default, exactly the hardware predictor's bias.
                    entry.policy = Policy.STREAM.value
                    entry.confidence = _CONF_INIT

    # -- persistence --------------------------------------------------------

    def save(self, path: str) -> None:
        blob = {
            k.encode(): dataclasses.asdict(v) for k, v in self.table.items()
        }
        tmp = path + ".tmp"
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
        os.replace(tmp, path)

    def load(self, path: str) -> "PolicyPredictor":
        with open(path) as f:
            blob = json.load(f)
        self.table = {
            SiteKey.decode(k): _Entry(**v) for k, v in blob.items()
        }
        return self

    def __len__(self) -> int:
        return len(self.table)
