"""Non-blocking VMEM budget planner — the Allocation-Bypass analogue (§VII.A).

Turns a policy assignment into concrete MXU-aligned block shapes whose total
VMEM claim (double-buffered stream tiles + pinned resident operands + output
accumulators) fits the chip's VMEM budget.

The paper's insight, transplanted: when allocation would "block" (here: the
resident set over-subscribes VMEM), **do not stall** — demote the
least-valuable resident operand to STREAM (a bypass request) instead of
squeezing compute tiles below MXU-efficient sizes.  With
``allocation_bypass=False`` (the paper's blocking baseline) the planner keeps
residency and shrinks compute tiles instead; every halving is recorded as a
shrink event (the cache-stall proxy reported by the Fig 8/12 benchmarks).
"""
from __future__ import annotations

import dataclasses

from repro import hw
from repro.core import rinse as rinse_mod
from repro.core.policy import (
    Assignment,
    KernelPlan,
    OpSpec,
    Policy,
    reuse_density,
)

MIN_BLOCK = 128          # MXU-aligned floor; shrinking below this is a "stall"
HARD_MIN_BLOCK = 8       # absolute floor (vector sublane)


@dataclasses.dataclass
class _Claim:
    name: str
    bytes_fn: object        # callable(block: dict[str,int]) -> int
    demotable: bool
    density: float          # reuse traffic saved per byte claimed


def _align_down(x: int, align: int) -> int:
    if x <= align:
        return x
    return (x // align) * align


def _default_blocks(op: OpSpec) -> dict[str, int]:
    m = op.meta
    if op.kind in ("matmul", "conv2d"):
        return {
            "bm": min(512, m["m"]),
            "bn": min(512, m["n"]),
            "bk": min(512, m["k"]),
        }
    if op.kind == "attention":
        return {"bq": min(512, m["sq"]), "bkv": min(512, m["skv"])}
    if op.kind in ("elementwise", "rowwise", "window"):
        elems = m.get("elems", m.get("rows", 1) * m.get("row_len", 1))
        return {"be": min(elems, 512 * 1024)}
    return {"be": 512 * 1024}


def _vmem_claim(
    op: OpSpec,
    assignment: Assignment,
    block: dict[str, int],
    elem_accum_dtype_bytes: int = 4,
) -> tuple[int, dict[str, int]]:
    """Total VMEM bytes claimed, and the per-operand claims."""
    eb = hw.dtype_bytes(op.dtype)
    per: dict[str, int] = {}
    kind = op.kind
    if kind in ("matmul", "conv2d"):
        tiles = {
            "a": block["bm"] * block["bk"],
            "b": block["bk"] * block["bn"],
            "out": block["bm"] * block["bn"],
        }
        default_tile = block["bm"] * block["bn"]
    elif kind == "attention":
        d = op.meta["head_dim"]
        tiles = {
            "q": block["bq"] * d,
            "k": block["bkv"] * d,
            "v": block["bkv"] * d,
            "out": block["bq"] * d,
        }
        default_tile = None
    else:
        tiles = {}
        default_tile = block["be"]
    for o in op.operands:
        pol = assignment[o.name]
        tile_elems = tiles.get(o.name, default_tile)
        tile_elems = min(tile_elems, max(1, o.unique_bytes // eb))
        if o.is_output:
            if pol is Policy.RESIDENT_ACCUM:
                per[o.name] = tile_elems * elem_accum_dtype_bytes
            else:
                per[o.name] = 2 * tile_elems * eb
        elif pol is Policy.RESIDENT:
            per[o.name] = o.window_bytes
        else:
            per[o.name] = 2 * tile_elems * eb
    return sum(per.values()), per


def plan_op(
    op: OpSpec,
    assignment: Assignment,
    chip: hw.Chip = hw.V5E,
    allocation_bypass: bool = True,
    rinse: bool = True,
) -> KernelPlan:
    """Produce a VMEM-feasible KernelPlan for ``op`` under ``assignment``."""
    assignment = dict(assignment)
    block = _default_blocks(op)
    budget = chip.vmem_budget
    demotions: list[str] = []
    shrink_events = 0
    density = reuse_density
    while True:
        claim, per = _vmem_claim(op, assignment, block)
        if claim <= budget:
            break
        # Allocation bypass: demote the least reuse-dense resident — but
        # only when demotion actually shrinks the claim (its window costs
        # more than the stream double-buffer it would get instead).
        residents = [
            o for o in op.inputs
            if assignment[o.name] is Policy.RESIDENT
        ]
        if allocation_bypass and residents:
            trial = dict(assignment)
            victim = min(residents, key=density)
            trial[victim.name] = Policy.STREAM
            new_claim, _ = _vmem_claim(op, trial, block)
            if new_claim < claim:
                assignment = trial
                demotions.append(victim.name)
                continue
        # Blocking baseline (or nothing left to demote): shrink the largest
        # block dim.  Below MIN_BLOCK this is MXU-starving — a stall.
        dim = max(block, key=lambda d: block[d])
        if block[dim] <= HARD_MIN_BLOCK:
            # Physically infeasible residency: forced demotion even in the
            # blocking baseline (a GPU would thrash; we record max stalls).
            if residents:
                victim = min(residents, key=density)
                assignment[victim.name] = Policy.STREAM
                demotions.append(victim.name)
                shrink_events += 4
                continue
            break
        new = _align_down(block[dim] // 2, MIN_BLOCK) if block[dim] > MIN_BLOCK else block[dim] // 2
        block[dim] = max(new, HARD_MIN_BLOCK)
        shrink_events += 1

    order, contiguity = rinse_mod.plan_grid_order(op, assignment, chip, rinse=rinse)
    claim, _ = _vmem_claim(op, assignment, block)
    return KernelPlan(
        op=op,
        assignment=assignment,
        block=block,
        grid_order=order,
        vmem_bytes=claim,
        demotions=tuple(demotions),
        shrink_events=shrink_events,
        rinse=rinse,
        notes=f"write_contiguity≈{contiguity:.2f}",
    )


def mxu_efficiency(plan: KernelPlan, chip: hw.Chip = hw.V5E) -> float:
    """Compute-efficiency factor implied by the plan's block shapes."""
    if plan.op.kind in ("matmul", "conv2d"):
        dims = ("bm", "bn", "bk")
    elif plan.op.kind == "attention":
        dims = ("bq", "bkv")
    else:
        return 1.0
    eff = 1.0
    for d in dims:
        eff *= min(1.0, plan.block[d] / chip.mxu_dim)
    return max(eff, 1e-3)
