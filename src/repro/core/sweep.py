"""Vectorized policy-lattice sweep (DESIGN.md §3).

Evaluates the *entire* per-operand policy lattice for a batch of ops in a
handful of NumPy array operations, instead of one pure-Python
characterize -> plan_residency -> op_cost walk per (op, assignment) query.

The lattice for one op is

    {STREAM, RESIDENT}^inputs x {STREAM, RESIDENT_ACCUM}^outputs
        x {AB off/on} x {rinse off/on}

— every static mode, every greedy/adaptive choice and every ablation the
benchmarks query is one row of it.  The math mirrors
:func:`repro.core.cost_model.op_cost` + ``plan_residency`` term for term
(the only differences are float summation order, ~1 ulp); correctness is
pinned by tests comparing against the scalar reference.

``optimal_assignment`` replaces the greedy ``adaptive_assignment`` with an
exact argmin over the lattice.  The returned assignment is re-scored with
the *scalar* cost model against the greedy assignment, so the invariant

    t_total(exact) <= t_total(greedy)

holds exactly, ulps included, and ties keep the greedy choice (stable
seeding for the PCby predictor).  Ops wider than ``max_exact_operands``
inputs fall back to greedy (the lattice is 2^n).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import hw
from repro.core import cost_model
from repro.core.characterize import OpTensors, operand_tensors
from repro.core.cost_model import CALIB, CostCalib, _peak_flops, _stream_tile
from repro.core.policy import (
    Assignment,
    OpSpec,
    Policy,
    StaticMode,
    static_assignment,
)

# Lattice width guards: 4 * 2^inputs * 2^outputs rows per op; beyond these
# bounds the exact search falls back to greedy and SweepTable serves
# queries from the scalar cost model instead.  The joint bound caps the
# row count (4 * 2^14 rows ~ a few MB of float64 per column), which the
# per-side bounds alone would not (12 inputs + 8 outputs -> 2^20 rows).
MAX_EXACT_INPUTS = 12
MAX_EXACT_OUTPUTS = 8
MAX_EXACT_LATTICE_BITS = 14


def exact_lattice_ok(op: OpSpec) -> bool:
    ni, no = len(op.inputs), len(op.outputs)
    return (ni <= MAX_EXACT_INPUTS and no <= MAX_EXACT_OUTPUTS
            and ni + no <= MAX_EXACT_LATTICE_BITS)

# (allocation_bypass, rinse) combo axis, folded into the row index.
COMBOS = ((False, False), (False, True), (True, False), (True, True))


def _combo_index(allocation_bypass: bool, rinse: bool) -> int:
    return (2 if allocation_bypass else 0) + (1 if rinse else 0)


@dataclasses.dataclass
class BatchSweep:
    """All policy-lattice costs for a batch of ops, as [n_ops, R] arrays.

    Row layout: ``r = (combo << (I_max + O_max)) | (in_bits << O_max) | out_bits``
    where input bit *j* marks the *j*-th density-ordered input RESIDENT and
    output bit *j* marks the *j*-th output RESIDENT_ACCUM.
    """

    ops: list[OpSpec]
    chip: hw.Chip
    calib: CostCalib
    tensors: list[OpTensors]
    imax: int
    omax: int
    t_compute: np.ndarray      # [n_ops]
    t_hbm: np.ndarray          # [n_ops, R]
    t_overhead0: np.ndarray    # [n_ops, R] launch-free (stall * t_hbm)
    t_total0: np.ndarray       # [n_ops, R] launch-free
    read_bytes: np.ndarray     # [n_ops, R]
    write_bytes: np.ndarray    # [n_ops, R]
    contiguity: np.ndarray     # [n_ops, R]
    stall: np.ndarray          # [n_ops, R]
    demotions: np.ndarray      # [n_ops, R] int
    vmem: np.ndarray           # [n_ops, R]
    valid: np.ndarray          # [n_ops, R] bool

    # -- row addressing -----------------------------------------------------

    def row(self, in_bits: int, out_bits: int,
            allocation_bypass: bool, rinse: bool) -> int:
        c = _combo_index(allocation_bypass, rinse)
        return (c << (self.imax + self.omax)) | (in_bits << self.omax) | out_bits

    def bits_of_assignment(self, op_i: int, a: Assignment) -> tuple[int, int]:
        t = self.tensors[op_i]
        in_bits = sum(
            1 << j for j, name in enumerate(t.in_names)
            if a[name] is Policy.RESIDENT
        )
        out_bits = sum(
            1 << j for j, name in enumerate(t.out_names)
            if a[name] is Policy.RESIDENT_ACCUM
        )
        return in_bits, out_bits

    def bits_of_mode(self, op_i: int, mode: StaticMode) -> tuple[int, int]:
        t = self.tensors[op_i]
        if mode is StaticMode.UNCACHED:
            return 0, 0
        if mode is StaticMode.CACHER:
            return (1 << t.n_inputs) - 1, 0
        if mode is StaticMode.CACHERW:
            return (1 << t.n_inputs) - 1, (1 << t.n_outputs) - 1
        raise ValueError("adaptive mode has no fixed lattice row; use best()")

    def assignment_at(self, op_i: int, in_bits: int, out_bits: int) -> Assignment:
        t = self.tensors[op_i]
        a: Assignment = {}
        for j, name in enumerate(t.in_names):
            a[name] = Policy.RESIDENT if (in_bits >> j) & 1 else Policy.STREAM
        for j, name in enumerate(t.out_names):
            a[name] = (
                Policy.RESIDENT_ACCUM if (out_bits >> j) & 1 else Policy.STREAM
            )
        return a

    # -- queries ------------------------------------------------------------

    def breakdown(
        self,
        op_i: int,
        mode: StaticMode | None = None,
        assignment: Assignment | None = None,
        allocation_bypass: bool = True,
        rinse: bool = True,
        launches: int = 1,
    ) -> cost_model.CostBreakdown:
        if assignment is not None:
            in_bits, out_bits = self.bits_of_assignment(op_i, assignment)
        else:
            in_bits, out_bits = self.bits_of_mode(
                op_i, mode or StaticMode.UNCACHED
            )
        r = self.row(in_bits, out_bits, allocation_bypass, rinse)
        return self.breakdown_at(op_i, r, launches)

    def breakdown_at(
        self, op_i: int, r: int, launches: int = 1
    ) -> cost_model.CostBreakdown:
        t_over = (
            self.t_overhead0[op_i, r]
            + launches * self.calib.launch_overhead_s
        )
        tc = self.t_compute[op_i]
        th = self.t_hbm[op_i, r]
        return cost_model.CostBreakdown(
            t_compute=float(tc),
            t_hbm=float(th),
            t_overhead=float(t_over),
            t_total=float(max(tc, th) + t_over),
            read_bytes=float(self.read_bytes[op_i, r]),
            write_bytes=float(self.write_bytes[op_i, r]),
            write_contiguity=float(self.contiguity[op_i, r]),
            stall_frac=float(self.stall[op_i, r]),
            launches=launches,
            demotions=int(self.demotions[op_i, r]),
            vmem_claimed=int(self.vmem[op_i, r]),
        )

    def best(
        self, op_i: int, allocation_bypass: bool = True, rinse: bool = True
    ) -> tuple[Assignment, float]:
        """Exact lattice argmin for one op under one (AB, rinse) combo."""
        c = _combo_index(allocation_bypass, rinse)
        width = 1 << (self.imax + self.omax)
        lo = c * width
        seg = np.where(
            self.valid[op_i, lo:lo + width],
            self.t_total0[op_i, lo:lo + width],
            np.inf,
        )
        r = int(np.argmin(seg))
        out_bits = r & ((1 << self.omax) - 1)
        in_bits = r >> self.omax
        return self.assignment_at(op_i, in_bits, out_bits), float(seg[r])


def sweep_ops(
    ops: list[OpSpec],
    chip: hw.Chip = hw.V5E,
    calib: CostCalib = CALIB,
) -> BatchSweep:
    """Evaluate the full policy lattice for a batch of ops, vectorized."""
    tensors = [operand_tensors(op) for op in ops]
    n = len(ops)
    imax = max((t.n_inputs for t in tensors), default=0)
    omax = max((t.n_outputs for t in tensors), default=0)
    if imax > MAX_EXACT_INPUTS:
        raise ValueError(
            f"op with {imax} inputs exceeds the exact-lattice bound "
            f"({MAX_EXACT_INPUTS}); use the greedy fallback"
        )
    if omax > MAX_EXACT_OUTPUTS or imax + omax > MAX_EXACT_LATTICE_BITS:
        raise ValueError(
            f"op lattice 2^({imax}+{omax}) exceeds the exact bounds "
            f"(<= {MAX_EXACT_INPUTS} inputs, <= {MAX_EXACT_OUTPUTS} outputs,"
            f" <= {MAX_EXACT_LATTICE_BITS} joint); use the greedy fallback"
        )
    R = 4 * (1 << imax) * (1 << omax)
    tile = _stream_tile(chip, calib)
    B = float(chip.vmem_budget)

    # Padded per-op operand arrays ([n, imax] / [n, omax]; padding is
    # zero-byte operands that drop out of every sum).
    in_u = np.zeros((n, imax)); in_t = np.zeros((n, imax))
    in_w = np.zeros((n, imax)); in_sbuf = np.zeros((n, imax))
    out_u = np.zeros((n, omax)); out_wt = np.zeros((n, omax))
    out_c = np.zeros((n, omax))
    claim_acc = np.zeros((n, omax)); claim_str = np.zeros((n, omax))
    t_compute = np.zeros(n)
    ni = np.array([t.n_inputs for t in tensors])
    no = np.array([t.n_outputs for t in tensors])
    for i, (op, t) in enumerate(zip(ops, tensors)):
        k, o = t.n_inputs, t.n_outputs
        in_u[i, :k] = t.in_unique
        in_t[i, :k] = t.in_touched
        in_w[i, :k] = t.in_window
        in_sbuf[i, :k] = 2 * np.minimum(t.in_unique, tile)
        out_u[i, :o] = t.out_unique
        out_wt[i, :o] = t.out_writethrough
        out_c[i, :o] = t.out_contiguity
        claim_acc[i, :o] = np.minimum(
            t.out_unique * 2, calib.accum_tile_bytes
        )
        claim_str[i, :o] = np.minimum(t.out_unique, tile)
        eff = (
            calib.achieved_compute_frac
            if t.achieved_eff is None else t.achieved_eff
        )
        t_compute[i] = t.flops / (_peak_flops(chip, op.dtype) * max(eff, 1e-3))

    # Row decode (shared across ops).
    r_all = np.arange(R)
    out_bits = r_all & ((1 << omax) - 1)
    in_bits = (r_all >> omax) & ((1 << imax) - 1)
    combo = r_all >> (imax + omax)
    ab_row = combo >= 2
    rinse_row = (combo & 1) == 1
    res = ((in_bits[:, None] >> np.arange(imax)[None, :]) & 1).astype(bool)
    acc = ((out_bits[:, None] >> np.arange(omax)[None, :]) & 1).astype(bool)
    valid = (
        (in_bits[None, :] < (1 << ni)[:, None])
        & (out_bits[None, :] < (1 << no)[:, None])
    )

    # --- residency planning (plan_residency, vectorized) -------------------
    # Mandatory claims: output accumulators/stream buffers + double buffers
    # for every STREAMed input; then greedy window allocation densest-first
    # (inputs are pre-sorted by density) via a masked cumulative sum.
    mand = (
        np.einsum("oj,rj->or", in_sbuf, ~res)
        + np.einsum("oj,rj->or", claim_str, ~acc)
        + np.einsum("oj,rj->or", claim_acc, acc)
    )
    budget0 = np.maximum(B - mand, 0.0)                       # [n, R]
    W = in_w[:, None, :] * res[None, :, :]                    # [n, R, I]
    prev = np.cumsum(W, axis=2) - W
    take = np.clip(budget0[:, :, None] - prev, 0.0, W)
    frac = take / np.maximum(in_w, 1.0)[:, None, :]
    vmem = np.minimum(mand, B) + take.sum(axis=2)
    demotions = (
        res[None, :, :] & (frac < calib.demote_threshold)
    ).sum(axis=2)

    # --- read traffic + allocation stalls ----------------------------------
    read_per = np.where(
        res[None, :, :],
        in_t[:, None, :] - (in_t - in_u)[:, None, :] * frac,
        in_t[:, None, :],
    )
    read = read_per.sum(axis=2)
    stall_per = np.where(
        res[None, :, :] & (frac < 1.0),
        calib.max_stall_frac * (1.0 - frac),
        0.0,
    )
    stall = stall_per.max(axis=2, initial=0.0)
    stall = np.where(ab_row[None, :], 0.0, stall)

    # --- write traffic + burst contiguity ----------------------------------
    traffic = np.where(acc[None, :, :], out_u[:, None, :], out_wt[:, None, :])
    c_acc = np.where(
        rinse_row[None, :, None],
        np.maximum(calib.rinse_contiguity, out_c[:, None, :]),
        out_c[:, None, :] * calib.coalesce_contiguity,
    )
    c_str = out_c[:, None, :] * (1.0 - stall[:, :, None])
    c_per = np.where(acc[None, :, :], c_acc, c_str)
    write = traffic.sum(axis=2)
    with np.errstate(invalid="ignore", divide="ignore"):
        contig = np.where(
            write > 0, (c_per * traffic).sum(axis=2) / write, 1.0
        )

    # --- roofline ----------------------------------------------------------
    bw_eff = calib.burst_floor + (1.0 - calib.burst_floor) * contig
    t_hbm = read / chip.hbm_bw + write / (chip.hbm_bw * bw_eff)
    t_over0 = stall * t_hbm
    t_total0 = np.maximum(t_compute[:, None], t_hbm) + t_over0

    return BatchSweep(
        ops=list(ops), chip=chip, calib=calib, tensors=tensors,
        imax=imax, omax=omax,
        t_compute=t_compute, t_hbm=t_hbm, t_overhead0=t_over0,
        t_total0=t_total0, read_bytes=read, write_bytes=write,
        contiguity=contig, stall=stall, demotions=demotions, vmem=vmem,
        valid=valid,
    )


def optimal_assignment(
    op: OpSpec,
    chip: hw.Chip = hw.V5E,
    calib: CostCalib = CALIB,
    allocation_bypass: bool = True,
    rinse: bool = True,
    max_exact_inputs: int = MAX_EXACT_INPUTS,
    table: "SweepTable | None" = None,
) -> Assignment:
    """Exact lattice-optimal per-operand assignment (greedy on ties/overflow).

    Guarantee: the returned assignment's scalar ``op_cost(...).t_total`` is
    <= the greedy ``adaptive_assignment``'s, because the lattice candidate
    is re-scored with the scalar model and greedy wins ties.

    Pass a shared ``table`` to reuse already-swept lattice rows instead of
    sweeping this op privately.
    """
    greedy = cost_model.adaptive_assignment(op, chip, calib)
    # The caller's bound can only tighten the hard module bounds (sweep_ops
    # enforces them regardless, so a looser bound would just crash there).
    if (len(op.inputs) > min(max_exact_inputs, MAX_EXACT_INPUTS)
            or not exact_lattice_ok(op)):
        return greedy
    if table is not None:
        cand = table.best_assignment(
            op, allocation_bypass=allocation_bypass, rinse=rinse
        )
    else:
        bs = sweep_ops([op], chip=chip, calib=calib)
        cand, _ = bs.best(0, allocation_bypass=allocation_bypass, rinse=rinse)

    def score(a: Assignment) -> float:
        return cost_model.op_cost(
            op, assignment=a, chip=chip,
            allocation_bypass=allocation_bypass, rinse=rinse,
            launches=0, calib=calib,
        ).t_total

    return cand if score(cand) < score(greedy) else greedy


class SweepTable:
    """Fingerprint-deduplicated sweep store serving workload/op queries.

    Ops are batched into :class:`BatchSweep` chunks on first sight; two ops
    with the same fingerprint (e.g. an RNN cell re-launched 150x, or a
    dgrad op shaped like its forward) share one set of lattice rows.
    """

    def __init__(self, chip: hw.Chip = hw.V5E, calib: CostCalib = CALIB):
        self.chip = chip
        self.calib = calib
        self._index: dict[int, tuple[BatchSweep, int]] = {}
        # Query-level memos (values are shared read-only instances).
        self._bd: dict[tuple, cost_model.CostBreakdown] = {}
        self._best: dict[tuple, Assignment] = {}
        self.hits = 0
        self.misses = 0

    def add(self, ops: list[OpSpec]) -> None:
        from repro.core.planner import fingerprint_id

        # Bucket by operand width: batch arrays are padded to the widest
        # member, so co-batching a wide op with narrow ones would make
        # every row table pay the wide op's 2^n lattice.
        buckets: dict[tuple[int, int], tuple[list[OpSpec], list[int]]] = {}
        seen = set(self._index)
        for op in ops:
            if not exact_lattice_ok(op):
                continue   # wide ops are served by the scalar fallback
            fid = fingerprint_id(op)
            if fid not in seen:
                seen.add(fid)
                fresh, fids = buckets.setdefault(
                    (len(op.inputs), len(op.outputs)), ([], [])
                )
                fresh.append(op)
                fids.append(fid)
        for fresh, fids in buckets.values():
            bs = sweep_ops(fresh, chip=self.chip, calib=self.calib)
            for i, fid in enumerate(fids):
                self._index[fid] = (bs, i)

    def _lookup(self, op: OpSpec) -> tuple[BatchSweep, int]:
        from repro.core.planner import fingerprint_id

        fid = fingerprint_id(op)
        hit = self._index.get(fid)
        if hit is None:
            self.misses += 1
            self.add([op])
            return self._index[fid]
        self.hits += 1
        return hit

    def op_cost(
        self,
        op: OpSpec,
        mode: StaticMode | None = None,
        assignment: Assignment | None = None,
        allocation_bypass: bool = True,
        rinse: bool = True,
        launches: int = 1,
    ) -> cost_model.CostBreakdown:
        if not exact_lattice_ok(op):
            # Wide-op scalar fallback (greedy for adaptive, exact costs).
            if mode is StaticMode.ADAPTIVE and assignment is None:
                assignment = cost_model.adaptive_assignment(
                    op, self.chip, self.calib
                )
                mode = None
            return cost_model.op_cost(
                op, assignment=assignment, mode=mode, chip=self.chip,
                allocation_bypass=allocation_bypass, rinse=rinse,
                launches=launches, calib=self.calib,
            )
        bs, i = self._lookup(op)
        if mode is StaticMode.ADAPTIVE and assignment is None:
            bkey = (id(bs), i, allocation_bypass, rinse)
            assignment = self._best.get(bkey)
            if assignment is None:
                assignment, _ = bs.best(i, allocation_bypass, rinse)
                self._best[bkey] = assignment
            mode = None
        if assignment is not None:
            in_bits, out_bits = bs.bits_of_assignment(i, assignment)
        else:
            in_bits, out_bits = bs.bits_of_mode(
                i, mode or StaticMode.UNCACHED
            )
        key = (id(bs), i, in_bits, out_bits, allocation_bypass, rinse,
               launches)
        bd = self._bd.get(key)
        if bd is None:
            r = bs.row(in_bits, out_bits, allocation_bypass, rinse)
            bd = bs.breakdown_at(i, r, launches)
            self._bd[key] = bd
        return bd

    def workload_cost(
        self,
        ops: list[OpSpec],
        mode: StaticMode = StaticMode.UNCACHED,
        allocation_bypass: bool | None = None,
        rinse: bool | None = None,
        launches_per_op: int = 1,
    ) -> cost_model.CostBreakdown:
        """Drop-in analogue of ``cost_model.workload_cost`` over the table."""
        adaptive = mode is StaticMode.ADAPTIVE
        ab = adaptive if allocation_bypass is None else allocation_bypass
        rn = adaptive if rinse is None else rinse
        total = cost_model.CostBreakdown()
        for op in ops:
            total.add(self.op_cost(
                op, mode=mode, allocation_bypass=ab, rinse=rn,
                launches=launches_per_op,
            ))
        return total

    def best_assignment(
        self, op: OpSpec, allocation_bypass: bool = True, rinse: bool = True
    ) -> Assignment:
        if not exact_lattice_ok(op):
            return cost_model.adaptive_assignment(op, self.chip, self.calib)
        bs, i = self._lookup(op)
        a, _ = bs.best(i, allocation_bypass, rinse)
        return a

    def stats(self) -> dict:
        n = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "unique_ops": len(self._index),
            "hit_rate": self.hits / n if n else 0.0,
        }


# ---------------------------------------------------------------------------
# Serve-tier policy lattice (adaptive serve cache policy, DESIGN.md §5.7)
# ---------------------------------------------------------------------------
#
# The serve tier's analogue of the op lattice above: for one workload
# class, every (warm-retention fraction x eviction rank x bypass) combo is
# a row, the expected prefill work per arrival is the cost column, and
# the policy choice is an exact vectorized argmin — the same
# adaptive-matches-best-static shape the paper establishes for GPU cache
# policies, applied to KV page retention.  Ties resolve to the FIRST row
# (np.argmin), so the axis ordering below doubles as the no-signal
# default: retain the full budget, LRU rank, no bypass — optimistic
# retention until the counters prove a class is churn.

SERVE_WARM_FRACS = (1.0, 0.5, 0.0)      # descending: row 0 is optimistic
SERVE_EVICT_RANKS = ("lru", "reuse")    # warm-eviction ordering
SERVE_BYPASS = (False, True)            # bypass: never retain this class

SERVE_COMBOS = tuple(
    (wf, rank, byp)
    for wf in SERVE_WARM_FRACS
    for rank in SERVE_EVICT_RANKS
    for byp in SERVE_BYPASS
)

_SERVE_FEATURE_DEFAULTS = {
    "prompt_mean": 0.0,       # mean prompt tokens per arrival of the class
    "shared_tokens": 0.0,     # mean full-page-prefix tokens shareable/arrival
    "hit_rate": 0.0,          # observed retained-then-reattached rate (0..1)
    "churn": 0.0,             # observed retained-never-hit rate (0..1)
    "reuse_signal": 0.0,      # 1.0 when re-arrival intervals were observed
    "spec_acceptance": 0.0,   # accepted draft tokens per verify round
    "spec_k": 0,              # draft length (0 = spec off)
    "warm_budget": 0,         # allocator warm-tier budget, pages
    "page_size": 1,           # tokens per page
}


def serve_policy_argmin(features: dict) -> tuple[tuple, float]:
    """Exact argmin over the serve-policy lattice for one workload class.

    ``features`` are the runtime counters ``serve.adaptive`` accumulates
    (missing keys take the zero-signal defaults above).  The cost column
    is expected prefill work per arrival, in tokens:

        cost = prompt_mean
               - p_hit * shared_tokens                  (warm/prefix hits)
               + churn * retained_pages * page_size * w (dead retention)

    where ``p_hit = hit_rate * min(1, retained_tokens / shared_tokens)``
    (a chain the budget can't cover can't hit), retention is zero under
    bypass, the reuse-distance rank halves the churn penalty only when
    re-arrival intervals were actually observed (no signal -> no edge
    over LRU, so ties keep the default), and ``w = 1 / (1 +
    spec_acceptance * spec_k)`` — when speculation is absorbing decode
    cost, dead retained pages matter less relative to prefill savings.
    Returns ``(combo, cost)`` with ``combo`` a ``SERVE_COMBOS`` row.
    Placement-only by construction: the choice moves pages, never
    tokens.
    """
    f = {**_SERVE_FEATURE_DEFAULTS, **features}
    wf = np.array([c[0] for c in SERVE_COMBOS])
    reuse_rank = np.array([c[1] == "reuse" for c in SERVE_COMBOS])
    bypass = np.array([c[2] for c in SERVE_COMBOS])

    retained_tokens = np.where(
        bypass, 0.0, wf * f["warm_budget"] * f["page_size"]
    )
    coverage = np.minimum(
        1.0, retained_tokens / max(float(f["shared_tokens"]), 1.0)
    )
    p_hit = f["hit_rate"] * coverage
    rank_discount = np.where(reuse_rank & (f["reuse_signal"] > 0), 0.5, 1.0)
    churn_w = 1.0 / (1.0 + f["spec_acceptance"] * f["spec_k"])
    cost = (
        f["prompt_mean"]
        - p_hit * f["shared_tokens"]
        + f["churn"] * retained_tokens * churn_w * rank_discount
    )
    r = int(np.argmin(cost))
    return SERVE_COMBOS[r], float(cost[r])
