"""Analytical access-pattern characterization of MI operators.

This module builds :class:`OperandProfile`/:class:`OpSpec` descriptions of the
operator kinds that make up the paper's 17 workloads and our model zoo, and
implements the paper's §VI.A three-way workload classification.

All reuse math assumes a canonical blocked schedule with MXU-aligned default
tiles (the same defaults the allocator starts from), because on TPU the
schedule — not a hardware replacement policy — determines how many times an
operand is fetched.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import hw
from repro.core.policy import (
    OperandProfile,
    OpSpec,
    StaticMode,
    WorkloadClass,
    reuse_density,
    static_assignment,
)

# Canonical tile sizes used for reuse accounting (allocator defaults).
DEF_BM = 256
DEF_BN = 256
DEF_BK = 256
DEF_BQ = 256
DEF_BKV = 256


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def matmul_op(
    m: int,
    k: int,
    n: int,
    dtype: str = "bf16",
    out_dtype: str | None = None,
    bm: int = DEF_BM,
    bn: int = DEF_BN,
    bk: int = DEF_BK,
    split_k: int = 1,
    name: str = "matmul",
) -> OpSpec:
    """C[M,N] = A[M,K] @ B[K,N] under an (m, n, k) blocked schedule.

    Output revisits: register/VMEM accumulation over the in-kernel k loop is
    intrinsic to any GEMM kernel (not a cache-policy choice), so the output
    is written once unless the schedule splits K across grid workers
    (``split_k`` > 1), in which case partial sums write through per split —
    that is the access the write-coalescing policy targets.
    """
    eb = hw.dtype_bytes(dtype)
    ob = hw.dtype_bytes(out_dtype or dtype)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    n_blocks = _ceil_div(n, bn)
    m_blocks = _ceil_div(m, bm)
    k_rev = max(1, split_k)
    # Reuse windows are BAND-sized, not operand-sized: a blocked schedule
    # captures A's cross-(n-block) reuse by keeping one m-band of A (bm x K)
    # live, and B's cross-(m-block) reuse with one k-band of B (bk x N).
    # This is what lets the paper's 4MB GPU L2 cut FwFc DRAM traffic 93%
    # even though the whole B matrix is 37x the cache.
    a = OperandProfile(
        name="a", role="input", shape=(m, k), dtype=dtype,
        unique_bytes=m * k * eb,
        touched_bytes_stream=m * k * eb * n_blocks,   # refetched per n-block
        reuse_window_bytes=min(m, bm) * k * eb,       # one m-band of A
        contiguity=1.0,
    )
    b = OperandProfile(
        name="b", role="input", shape=(k, n), dtype=dtype,
        unique_bytes=k * n * eb,
        touched_bytes_stream=k * n * eb * m_blocks,   # refetched per m-block
        reuse_window_bytes=min(k, bk) * n * eb,       # one k-band of B
        contiguity=1.0,
    )
    c = OperandProfile(
        name="out", role="output", shape=(m, n), dtype=out_dtype or dtype,
        unique_bytes=m * n * ob,
        touched_bytes_stream=m * n * ob,
        revisits=k_rev,
        contiguity=1.0,
    )
    return OpSpec(
        kind="matmul", name=name, operands=(a, b, c),
        flops=2.0 * m * n * k, dtype=dtype,
        meta={"m": m, "n": n, "k": k, "bm": bm, "bn": bn, "bk": bk},
    )


def attention_op(
    batch: int,
    q_heads: int,
    kv_heads: int,
    sq: int,
    skv: int,
    head_dim: int,
    causal: bool = True,
    dtype: str = "bf16",
    bq: int = DEF_BQ,
    bkv: int = DEF_BKV,
    name: str = "attention",
) -> OpSpec:
    """Flash-style attention: outer loop over q blocks, inner over kv blocks."""
    eb = hw.dtype_bytes(dtype)
    bq, bkv = min(bq, sq), min(bkv, skv)
    q_blocks = _ceil_div(sq, bq)
    kv_rev = _ceil_div(skv, bkv)
    frac = 0.5 if (causal and sq == skv) else 1.0
    group = max(1, q_heads // max(1, kv_heads))
    q = OperandProfile(
        name="q", role="input", shape=(batch, q_heads, sq, head_dim), dtype=dtype,
        unique_bytes=batch * q_heads * sq * head_dim * eb,
        touched_bytes_stream=batch * q_heads * sq * head_dim * eb,
        reuse_window_bytes=bq * head_dim * eb,
    )
    # K/V are refetched for each q block of each of the `group` q heads that
    # share them (GQA reuse) — per (batch, kv_head) the window is skv*d.
    kv_unique = batch * kv_heads * skv * head_dim * eb
    kv_touch = int(kv_unique * q_blocks * group * frac)
    k = OperandProfile(
        name="k", role="input", shape=(batch, kv_heads, skv, head_dim), dtype=dtype,
        unique_bytes=kv_unique, touched_bytes_stream=max(kv_unique, kv_touch),
        reuse_window_bytes=skv * head_dim * eb,
    )
    v = OperandProfile(
        name="v", role="input", shape=(batch, kv_heads, skv, head_dim), dtype=dtype,
        unique_bytes=kv_unique, touched_bytes_stream=max(kv_unique, kv_touch),
        reuse_window_bytes=skv * head_dim * eb,
    )
    o = OperandProfile(
        name="out", role="output", shape=(batch, q_heads, sq, head_dim), dtype=dtype,
        unique_bytes=batch * q_heads * sq * head_dim * eb,
        touched_bytes_stream=batch * q_heads * sq * head_dim * eb,
        revisits=max(1, int(kv_rev * frac)),
    )
    return OpSpec(
        kind="attention", name=name, operands=(q, k, v, o),
        flops=4.0 * batch * q_heads * sq * skv * head_dim * frac, dtype=dtype,
        meta={
            "batch": batch, "q_heads": q_heads, "kv_heads": kv_heads,
            "sq": sq, "skv": skv, "head_dim": head_dim, "causal": causal,
            "bq": bq, "bkv": bkv,
        },
    )


def elementwise_op(
    elems: int,
    n_inputs: int = 1,
    n_outputs: int = 1,
    flops_per_elem: float = 1.0,
    dtype: str = "bf16",
    name: str = "elementwise",
) -> OpSpec:
    """Pure streaming map (activations, residual adds, scaling): reuse = 1."""
    eb = hw.dtype_bytes(dtype)
    ops = []
    for i in range(n_inputs):
        ops.append(OperandProfile(
            name=f"in{i}", role="input", shape=(elems,), dtype=dtype,
            unique_bytes=elems * eb, touched_bytes_stream=elems * eb,
        ))
    for i in range(n_outputs):
        ops.append(OperandProfile(
            name=f"out{i}" if n_outputs > 1 else "out", role="output",
            shape=(elems,), dtype=dtype,
            unique_bytes=elems * eb, touched_bytes_stream=elems * eb, revisits=1,
        ))
    return OpSpec(kind="elementwise", name=name, operands=tuple(ops),
                  flops=flops_per_elem * elems, dtype=dtype,
                  meta={"elems": elems})


def rowwise_op(
    rows: int,
    row_len: int,
    passes: int = 3,
    flops_per_elem: float = 4.0,
    dtype: str = "bf16",
    name: str = "softmax",
) -> OpSpec:
    """Multi-pass row reduction+map (softmax, layer/batch-norm apply).

    Streaming executes ``passes`` sweeps over the input (max, sum,
    normalize); caching a row (window = one row) captures the reuse.
    """
    eb = hw.dtype_bytes(dtype)
    elems = rows * row_len
    x = OperandProfile(
        name="x", role="input", shape=(rows, row_len), dtype=dtype,
        unique_bytes=elems * eb, touched_bytes_stream=elems * eb * passes,
        reuse_window_bytes=row_len * eb,
    )
    o = OperandProfile(
        name="out", role="output", shape=(rows, row_len), dtype=dtype,
        unique_bytes=elems * eb, touched_bytes_stream=elems * eb, revisits=1,
    )
    return OpSpec(kind="rowwise", name=name, operands=(x, o),
                  flops=flops_per_elem * elems * passes, dtype=dtype,
                  meta={"rows": rows, "row_len": row_len, "passes": passes})


def window_op(
    elems: int,
    window: int,
    stride_elems: int,
    reuse_distance_elems: int,
    loads_per_out: float | None = None,
    out_elems: int | None = None,
    flops_per_out: float = 2.0,
    dtype: str = "bf16",
    name: str = "window",
) -> OpSpec:
    """Windowed gather ops (pooling, LRN): each output reads ``window`` inputs.

    ``reuse_distance_elems`` is the element spacing between successive touches
    of the same input (stride-1 spatial window -> small; cross-channel LRN ->
    H*W, typically exceeding VMEM, making the reuse unrealizable — the
    paper's FwLRN case).
    """
    eb = hw.dtype_bytes(dtype)
    out_elems = out_elems if out_elems is not None else max(1, elems // max(1, stride_elems))
    loads = loads_per_out if loads_per_out is not None else float(window)
    touched = int(out_elems * loads * eb)
    x = OperandProfile(
        name="x", role="input", shape=(elems,), dtype=dtype,
        unique_bytes=elems * eb, touched_bytes_stream=max(elems * eb, touched),
        reuse_window_bytes=max(1, reuse_distance_elems) * eb,
        contiguity=1.0 if reuse_distance_elems <= 4096 else 0.8,
    )
    o = OperandProfile(
        name="out", role="output", shape=(out_elems,), dtype=dtype,
        unique_bytes=out_elems * eb, touched_bytes_stream=out_elems * eb, revisits=1,
    )
    return OpSpec(kind="window", name=name, operands=(x, o),
                  flops=flops_per_out * out_elems, dtype=dtype,
                  meta={"elems": elems, "window": window,
                        "reuse_distance_elems": reuse_distance_elems})


def conv2d_op(
    n: int, c_in: int, h: int, w: int, c_out: int, kh: int, kw: int,
    stride: int = 1, dtype: str = "bf16", name: str = "conv2d",
) -> OpSpec:
    """Conv as implicit GEMM: M = N*Ho*Wo, K = Cin*kh*kw, N = Cout."""
    ho, wo = max(1, h // stride), max(1, w // stride)
    op = matmul_op(n * ho * wo, c_in * kh * kw, c_out, dtype=dtype, name=name)
    # im2col touches each input element kh*kw/stride^2 times with a small
    # reuse window (rows of the image).
    eb = hw.dtype_bytes(dtype)
    in_elems = n * c_in * h * w
    x = OperandProfile(
        name="a", role="input", shape=(n, c_in, h, w), dtype=dtype,
        unique_bytes=in_elems * eb,
        touched_bytes_stream=int(in_elems * eb * max(1.0, kh * kw / stride**2)),
        reuse_window_bytes=c_in * kw * w * eb * kh,
    )
    ops = tuple(x if o.name == "a" else o for o in op.operands)
    return OpSpec(kind="conv2d", name=name, operands=ops, flops=op.flops,
                  dtype=dtype, meta={**op.meta, "kh": kh, "kw": kw})


# ---------------------------------------------------------------------------
# Vectorized operand tensors (consumed by core.sweep)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpTensors:
    """Per-operand byte/flop arrays for one op, precomputed once.

    Inputs are stored in *residency-priority order* (reuse density
    descending) — the order ``plan_residency`` allocates the VMEM budget —
    so the sweep kernel can realize partial residency with a cumulative
    sum instead of re-sorting per assignment.
    """

    in_names: tuple[str, ...]        # density-ordered
    in_unique: np.ndarray            # [I] float64
    in_touched: np.ndarray           # [I]
    in_window: np.ndarray            # [I]
    out_names: tuple[str, ...]
    out_unique: np.ndarray           # [O]
    out_writethrough: np.ndarray     # [O] unique * max(1, 2*revisits - 1)
    out_contiguity: np.ndarray       # [O]
    flops: float
    achieved_eff: float | None       # meta override, None -> calib default

    @property
    def n_inputs(self) -> int:
        return len(self.in_names)

    @property
    def n_outputs(self) -> int:
        return len(self.out_names)


def operand_tensors(op: OpSpec) -> OpTensors:
    """Build the sweep kernel's array view of one op (calib-independent)."""
    ins = sorted(op.inputs, key=reuse_density, reverse=True)
    outs = op.outputs
    eff = op.meta.get("achieved_eff")
    return OpTensors(
        in_names=tuple(o.name for o in ins),
        in_unique=np.array([o.unique_bytes for o in ins], dtype=np.float64),
        in_touched=np.array(
            [o.touched_bytes_stream for o in ins], dtype=np.float64
        ),
        in_window=np.array([o.window_bytes for o in ins], dtype=np.float64),
        out_names=tuple(o.name for o in outs),
        out_unique=np.array([o.unique_bytes for o in outs], dtype=np.float64),
        out_writethrough=np.array(
            [o.unique_bytes * max(1, 2 * o.revisits - 1) for o in outs],
            dtype=np.float64,
        ),
        out_contiguity=np.array([o.contiguity for o in outs], dtype=np.float64),
        flops=op.flops,
        achieved_eff=None if eff is None else float(eff),
    )


# ---------------------------------------------------------------------------
# Workload classification (paper §VI.A)
# ---------------------------------------------------------------------------

def classify_workload(
    ops: list[OpSpec],
    chip: hw.Chip = hw.V5E,
    threshold: float = 0.05,
    memoize: bool = True,
    plan_cache=None,
    cost_fn=None,
) -> WorkloadClass:
    """Reproduce the paper's 3-way grouping from modeled policy sensitivity.

    ``cost_fn(ops, mode) -> CostBreakdown`` overrides the cost evaluator
    (e.g. a vectorized :class:`~repro.core.sweep.SweepTable`); the default
    is the scalar (memoized) ``workload_cost``.
    """
    from repro.core.cost_model import workload_cost  # local: avoid import cycle

    if cost_fn is None:
        def cost_fn(ops, mode):
            return workload_cost(ops, mode=mode, chip=chip, launches_per_op=0,
                                 memoize=memoize, plan_cache=plan_cache)

    times = {
        # Launch overhead excluded: classification concerns memory behaviour.
        mode: cost_fn(ops, mode).t_total
        for mode in (StaticMode.UNCACHED, StaticMode.CACHER, StaticMode.CACHERW)
    }
    t_unc = times[StaticMode.UNCACHED]
    t_best = min(times.values())
    t_worst = max(times.values())
    if t_best <= 0 or (t_worst - t_best) / max(t_best, 1e-30) < threshold:
        return WorkloadClass.MEMORY_INSENSITIVE
    cached_best = min(times[StaticMode.CACHER], times[StaticMode.CACHERW])
    if cached_best < t_unc * (1 - 1e-9) and (t_unc - cached_best) / t_unc >= threshold:
        return WorkloadClass.REUSE_SENSITIVE
    return WorkloadClass.THROUGHPUT_SENSITIVE


def op_table(ops: list[OpSpec]) -> list[dict]:
    """Characterization rows (Fig 4/5 analogue): intensity + demand per op."""
    rows = []
    for op in ops:
        unique = op.unique_bytes()
        stream = sum(o.hbm_bytes(p) for o, p in
                     zip(op.operands, [static_assignment(op, StaticMode.UNCACHED)[o.name]
                                       for o in op.operands]))
        rows.append({
            "name": op.name or op.kind,
            "kind": op.kind,
            "flops": op.flops,
            "unique_bytes": unique,
            "stream_bytes": stream,
            "arith_intensity_cached": op.arithmetic_intensity(),
            "arith_intensity_stream": op.flops / max(stream, 1),
        })
    return rows
