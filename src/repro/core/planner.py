"""Memoized policy planning — the PlanCache and Planner (DESIGN.md §3).

Policy planning (characterize -> predict -> allocate -> cost) is pure: the
same op under the same (assignment, chip, calibration, AB, rinse) knobs
always produces the same :class:`KernelPlan` and :class:`CostBreakdown`.
The paper's workloads launch the *same* kernel hundreds of times (the RNN
suites re-launch one cell kernel 150-363x; a transformer plans one layer's
ops n_layers times), so the planner memoizes on a structural fingerprint of
the op rather than object identity.

Cache-key scheme (DESIGN.md §3):

    (namespace, fingerprint(op), assignment, chip, calib, ab, rinse)

(chip and calib are interned by *content* — dataclasses.astuple — so two
same-named chips with different parameters never alias entries)

* ``fingerprint(op)`` — SiteKey-style structural hash of the OpSpec: kind,
  dtype, flops and the full per-operand access profile (role, bytes,
  reuse window, contiguity, revisits) plus the scalar ``meta`` entries that
  feed the allocator's default blocks and the cost model's achieved
  efficiency.  The op's *name* is deliberately excluded: FwBwLSTM's dgrad
  op fingerprints identically to its forward op and shares one plan.
* costs are cached launch-free; launch overhead is re-applied on retrieval
  (it is the only term that varies with launch count).

Hit/miss counters feed the benchmark JSON (``plan_cache_hit_rate``).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable

from repro import hw
from repro.core import allocator, cost_model
from repro.core.policy import (
    Assignment,
    KernelPlan,
    OpSpec,
    Policy,
    StaticMode,
    static_assignment,
)


# Fingerprints are interned to small ints (`fingerprint_id`) so hot cache
# keys hash a couple of machine words instead of a large nested tuple on
# every lookup.  The interned id is stashed on the OpSpec itself (a frozen
# dataclass is still a plain object underneath; `dataclasses.replace`
# copies drop the stash and re-fingerprint, so staleness is impossible as
# long as nothing mutates operand profiles in place).
_FP_IDS: dict[tuple, int] = {}
_FID_ATTR = "_planner_fid"


def fingerprint_op(op: OpSpec) -> tuple:
    """Structural, hashable identity of an op for plan/cost memoization."""
    return _fingerprint_op(op)


def fingerprint_id(op: OpSpec) -> int:
    """Small interned equivalent of :func:`fingerprint_op` (equal
    fingerprints map to the same id, across distinct OpSpec objects)."""
    fid = op.__dict__.get(_FID_ATTR)
    if fid is not None:
        return fid
    fp = _fingerprint_op(op)
    fid = _FP_IDS.get(fp)
    if fid is None:
        fid = len(_FP_IDS)
        _FP_IDS[fp] = fid
    object.__setattr__(op, _FID_ATTR, fid)
    return fid


def _fingerprint_op(op: OpSpec) -> tuple:
    meta = tuple(sorted(
        (k, v) for k, v in op.meta.items()
        if isinstance(v, (int, float, str, bool))
    ))
    operands = tuple(
        (o.name, o.role, o.dtype, o.shape, o.unique_bytes,
         o.touched_bytes_stream, o.contiguity, o.revisits,
         o.reuse_window_bytes)
        for o in op.operands
    )
    return (op.kind, op.dtype, op.flops, operands, meta)


def assignment_key(op: OpSpec, assignment: Assignment) -> tuple:
    """Canonical (operand-ordered) encoding of a policy assignment."""
    return tuple(assignment[o.name].value for o in op.operands)


def calib_key(calib: cost_model.CostCalib) -> tuple:
    return dataclasses.astuple(calib)


_CALIB_IDS: dict[tuple, int] = {}


def _calib_id(calib: cost_model.CostCalib) -> int:
    k = calib_key(calib)
    cid = _CALIB_IDS.get(k)
    if cid is None:
        cid = len(_CALIB_IDS)
        _CALIB_IDS[k] = cid
    return cid


_CHIP_IDS: dict[tuple, int] = {}


def _chip_id(chip: hw.Chip) -> int:
    """Interned content id: two chips with equal parameters share an id,
    while same-named chips with different parameters do NOT alias cache
    entries (hw.Chip fields all default, so names collide easily)."""
    k = dataclasses.astuple(chip)
    cid = _CHIP_IDS.get(k)
    if cid is None:
        cid = len(_CHIP_IDS)
        _CHIP_IDS[k] = cid
    return cid


_MISSING = object()


class PlanCache:
    """Bounded LRU memo for plans, costs and lattice optima, with counters."""

    def __init__(self, max_entries: int = 1 << 16):
        self.max_entries = max_entries
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def lookup(self, key):
        """Fast-path probe: the cached value, or the ``_MISSING`` sentinel."""
        val = self._d.get(key, _MISSING)
        if val is not _MISSING:
            self.hits += 1
            self._d.move_to_end(key)
        return val

    def store(self, key, val):
        self.misses += 1
        self._d[key] = val
        if len(self._d) > self.max_entries:
            self._d.popitem(last=False)
        return val

    def get_or_compute(self, key, fn: Callable):
        val = self.lookup(key)
        if val is _MISSING:
            val = self.store(key, fn())
        return val

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "entries": len(self._d),
            "hit_rate": self.hit_rate,
        }

    def clear(self) -> None:
        self._d.clear()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._d)


# Shared process-wide cache: the sweep/benchmark/engine default.  Safe
# because entries are immutable-by-convention (retrieval returns copies).
DEFAULT_CACHE = PlanCache()


def _copy_plan(plan: KernelPlan) -> KernelPlan:
    return dataclasses.replace(
        plan, assignment=dict(plan.assignment), block=dict(plan.block)
    )


def _apply_launches(
    bd: cost_model.CostBreakdown, launches: int, calib: cost_model.CostCalib
) -> cost_model.CostBreakdown:
    """Re-apply launch overhead to a launch-free cached breakdown.

    Reconstructs t_overhead/t_total with the same expression shape as
    ``op_cost`` so cached results are bit-identical to cold ones.
    """
    out = dataclasses.replace(bd)
    out.launches = launches
    out.t_overhead = bd.stall_frac * bd.t_hbm + launches * calib.launch_overhead_s
    out.t_total = max(bd.t_compute, bd.t_hbm) + out.t_overhead
    return out


class Planner:
    """Memoized planning facade over allocator/cost_model/sweep."""

    def __init__(
        self,
        chip: hw.Chip = hw.V5E,
        calib: cost_model.CostCalib = cost_model.CALIB,
        cache: PlanCache | None = None,
        table=None,
    ):
        self.chip = chip
        self.calib = calib
        self.cache = DEFAULT_CACHE if cache is None else cache
        self._ck = _calib_id(calib)
        self._chipk = _chip_id(chip)
        # Shared vectorized lattice store (core.sweep.SweepTable); created
        # lazily on the first exact search if not provided.
        self._table = table

    # -- memoized primitives ------------------------------------------------

    def plan(
        self,
        op: OpSpec,
        assignment: Assignment,
        allocation_bypass: bool = True,
        rinse: bool = True,
    ) -> KernelPlan:
        key = ("plan", fingerprint_id(op), assignment_key(op, assignment),
               self._chipk, self._ck, allocation_bypass, rinse)
        plan = self.cache.get_or_compute(
            key,
            lambda: allocator.plan_op(
                op, assignment, chip=self.chip,
                allocation_bypass=allocation_bypass, rinse=rinse,
            ),
        )
        return _copy_plan(plan)

    def cost(
        self,
        op: OpSpec,
        assignment: Assignment | None = None,
        mode: StaticMode | None = None,
        allocation_bypass: bool = True,
        rinse: bool = True,
        launches: int = 1,
    ) -> cost_model.CostBreakdown:
        if assignment is None:
            assignment = static_assignment(op, mode or StaticMode.UNCACHED)
        key = ("cost", fingerprint_id(op), assignment_key(op, assignment),
               self._chipk, self._ck, allocation_bypass, rinse)
        bd = self.cache.get_or_compute(
            key,
            lambda: cost_model.op_cost(
                op, assignment=assignment, chip=self.chip,
                allocation_bypass=allocation_bypass, rinse=rinse,
                launches=0, calib=self.calib,
            ),
        )
        return _apply_launches(bd, launches, self.calib)

    def launch_plan(
        self,
        op: OpSpec,
        allocation_bypass: bool = True,
        rinse: bool = True,
    ) -> tuple[KernelPlan, cost_model.CostBreakdown]:
        """One-stop per-launch query: adaptive plan + its one-launch cost.

        This is the hot serve-time path (one query per kernel launch), so
        the returned objects are the *shared cached instances* — treat them
        as read-only.  Use :meth:`plan`/:meth:`cost` when a private copy is
        needed.
        """
        key = ("launch", fingerprint_id(op), self._chipk, self._ck,
               allocation_bypass, rinse)
        val = self.cache.lookup(key)
        if val is not _MISSING:
            return val
        plan = allocator.plan_op(
            op,
            self.optimal_assignment(
                op, allocation_bypass=allocation_bypass, rinse=rinse
            ),
            chip=self.chip,
            allocation_bypass=allocation_bypass, rinse=rinse,
        )
        bd = cost_model.op_cost(
            op, assignment=plan.assignment, chip=self.chip,
            allocation_bypass=allocation_bypass, rinse=rinse,
            launches=1, calib=self.calib,
        )
        return self.cache.store(key, (plan, bd))

    def optimal_assignment(
        self,
        op: OpSpec,
        allocation_bypass: bool = True,
        rinse: bool = True,
    ) -> Assignment:
        """Exact lattice-optimal assignment (memoized; see core.sweep)."""
        from repro.core import sweep  # local: sweep depends on cost_model

        if self._table is None:
            self._table = sweep.SweepTable(chip=self.chip, calib=self.calib)
        key = ("opt", fingerprint_id(op), self._chipk, self._ck,
               allocation_bypass, rinse)
        a = self.cache.get_or_compute(
            key,
            lambda: sweep.optimal_assignment(
                op, chip=self.chip, calib=self.calib,
                allocation_bypass=allocation_bypass, rinse=rinse,
                table=self._table,
            ),
        )
        return dict(a)

    def stats(self) -> dict:
        return self.cache.stats()
