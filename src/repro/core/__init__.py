"""The paper's primary contribution: adaptive memory policies for MI ops.

Public API:
    Policy, StaticMode, WorkloadClass, OperandProfile, OpSpec, KernelPlan
    characterize.{matmul_op, attention_op, elementwise_op, rowwise_op,
                  window_op, conv2d_op, classify_workload}
    cost_model.{op_cost, workload_cost, adaptive_assignment}
    allocator.plan_op, rinse.DirtyIndex, predictor.PolicyPredictor
    engine.{CachePolicyEngine, make_engine}
    planner.{PlanCache, Planner, fingerprint_op}
    sweep.{sweep_ops, optimal_assignment, SweepTable}
"""
from repro.core.policy import (  # noqa: F401
    Assignment,
    KernelPlan,
    OperandProfile,
    OpSpec,
    Policy,
    StaticMode,
    WorkloadClass,
    static_assignment,
)
from repro.core.engine import CachePolicyEngine, EngineConfig, make_engine  # noqa: F401
from repro.core.planner import PlanCache, Planner, fingerprint_op  # noqa: F401
from repro.core.sweep import SweepTable, optimal_assignment, sweep_ops  # noqa: F401
