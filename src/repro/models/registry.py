"""Architecture registry: ``--arch <id>`` -> (config, model builder)."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401
from repro.models import common as cm

_CONFIG_MODULES = {
    "yi-9b": "repro.configs.yi_9b",
    "granite-20b": "repro.configs.granite_20b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "qwen2.5-32b": "repro.configs.qwen25_32b",
    "whisper-small": "repro.configs.whisper_small",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "mamba2-1.3b": "repro.configs.mamba2_13b",
    "zamba2-2.7b": "repro.configs.zamba2_27b",
    "llama-3.2-vision-90b": "repro.configs.llama32_vision_90b",
}

ARCHS = tuple(_CONFIG_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_CONFIG_MODULES[arch])
    return mod.SMOKE if smoke else mod.CONFIG


def build_model(cfg: ModelConfig) -> cm.ModelApply:
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer

        return transformer.build(cfg)
    if cfg.family == "ssm":
        from repro.models import mamba2

        return mamba2.build(cfg)
    if cfg.family == "hybrid":
        from repro.models import zamba2

        return zamba2.build(cfg)
    if cfg.family == "encdec":
        from repro.models import whisper

        return whisper.build(cfg)
    raise ValueError(f"unknown family: {cfg.family}")


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells after the principled skips:
    long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            if shape_name == "long_500k" and not cfg.subquadratic:
                continue
            cells.append((arch, shape_name))
    return cells
