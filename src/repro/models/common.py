"""Shared model blocks: norms, RoPE, GQA attention, MLP, MoE — pure JAX.

Models are parameterized as nested dicts of jnp arrays (stacked over layers
for scan).  Weight layouts keep named logical axes so the sharding rules in
``repro.distributed.sharding`` can map them onto the mesh:

    attention:  wq (d, hq, dh)   wk/wv (d, hkv, dh)   wo (hq, dh, d)
    mlp:        wg/wu (d, f)     wd (f, d)
    moe:        router (d, e)    wg/wu (e, d, f)      wd (e, f, d)
    embed:      (v, d)           unembed (d, v)

The XLA path (these functions) is what trains and what the dry-run lowers;
the Pallas kernels in ``repro.kernels`` are the TPU hot-spot implementations
validated against the same math.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = dict[str, Any]

# ---------------------------------------------------------------------------
# Scan wrapper: dry-run cost counting needs fully-unrolled loops because XLA
# cost_analysis counts a while body once regardless of trip count.  Models
# call cm.scan(...); launch/dryrun flips the flag for the reduced-depth
# counting lowers only.
# ---------------------------------------------------------------------------

_SCAN_UNROLL = False


def set_scan_unroll(flag: bool) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = flag


def scan(body, carry, xs, length: int | None = None):
    if _SCAN_UNROLL:
        n = length
        if n is None:
            n = jax.tree_util.tree_leaves(xs)[0].shape[0]
        return jax.lax.scan(body, carry, xs, length=length, unroll=n)
    return jax.lax.scan(body, carry, xs, length=length)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size: int, dtype) -> jnp.ndarray:
    scale = in_axis_size ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: int | None = None) -> Params:
    d = d or cfg.d_model
    p = {"w": jnp.ones((d,), jnp.float32)}
    if cfg.norm_kind == "layer":
        p["b"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    if cfg.norm_kind == "layer":
        mu = jnp.mean(h, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
        y = (h - mu) * jax.lax.rsqrt(var + cfg.norm_eps) * p["w"] + p["b"]
    else:
        ms = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
        y = h * jax.lax.rsqrt(ms + cfg.norm_eps) * p["w"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (b, s, h, dh); positions: (b, s) or (s,)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (b, s, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA self / cross), train + cached decode
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, kv_d_model: int | None = None) -> Params:
    d = cfg.d_model
    kd = kv_d_model or d
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq, dh), d, dt),
        "wk": dense_init(ks[1], (kd, hkv, dh), kd, dt),
        "wv": dense_init(ks[2], (kd, hkv, dh), kd, dt),
        "wo": dense_init(ks[3], (hq, dh, d), hq * dh, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, dh), dt)
        p["bk"] = jnp.zeros((hkv, dh), dt)
        p["bv"] = jnp.zeros((hkv, dh), dt)
    return p


# Above this many score elements per (batch, head), attention switches to
# the blocked online-softmax path (never materializes s x t scores) — the
# XLA-graph twin of the flash_attention Pallas kernel's STREAM-KV /
# RESIDENT_ACCUM-output policy.  Large-t decode also chunks: the KV stream
# is huge even though s=1.
_SDPA_CHUNK_THRESHOLD = 4096 * 2048
_SDPA_DECODE_T = 8192


def _ambient_model_axis() -> int | None:
    """Size of the 'model' axis of the ambient mesh (with mesh:), if any."""
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty and "model" in mesh.axis_names:
            return int(mesh.shape["model"])
    except Exception:
        pass
    return None


def _ambient_mesh():
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    return None


def _maybe_shard(x, spec_dims: tuple):
    """with_sharding_constraint if an ambient mesh provides the axes and
    every named dim divides evenly; no-op otherwise (tests, single dev)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    from jax.sharding import PartitionSpec as P

    for i, axis in enumerate(spec_dims):
        if axis is None:
            continue
        names = (axis,) if isinstance(axis, str) else tuple(axis)
        size = 1
        for n in names:
            if n not in mesh.axis_names:
                return x
            size *= mesh.shape[n]
        if x.shape[i] % size != 0:
            return x
    return jax.lax.with_sharding_constraint(x, P(*spec_dims))


def _offset_rows(q_offset) -> jnp.ndarray:
    """Normalize a query-position offset to a (B,) vector, B in {1, b}.

    A scalar offset is the uniform-cursor case; a (b,) vector carries the
    per-slot ragged cursors of continuous batching."""
    off = jnp.asarray(q_offset)
    return off[None] if off.ndim == 0 else off


def _sdpa_naive(q, k, v, causal: bool, q_offset, kv_len=None):
    """q: (b, s, hq, dh); k/v: (b, t, hkv, dh). fp32 softmax."""
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qf = q.astype(jnp.float32) * (dh ** -0.5)
    qf = qf.reshape(b, s, hkv, group, dh)
    logits = jnp.einsum("bshgd,bthd->bhgst", qf, k.astype(jnp.float32))
    if causal:
        off = _offset_rows(q_offset)                          # (1,) or (b,)
        qi = off[:, None, None] + jnp.arange(s)[None, :, None]
        ki = jnp.arange(t)[None, None, :]
        mask = ki <= qi                                       # (B, s, t)
        logits = jnp.where(mask[:, None, None], logits, -1e30)
    if kv_len is not None:
        mask = jnp.arange(t)[None, :] < kv_len[:, None]          # (b, t)
        logits = jnp.where(mask[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, hq, dh).astype(q.dtype)


def _chunk_sizes(s: int, t: int) -> tuple[int, int]:
    """Block shapes bounding both the live logits buffer (b*heads*qb*ck)
    and the loop trip count (<= ~8x8)."""
    qb = min(s, max(1024, -(-s // 8)))
    ck = min(t, max(1024, -(-t // 8)))
    return qb, ck


def _sdpa_chunked(q, k, v, causal: bool, q_offset, kv_len=None,
                  chunk: int | None = None, q_block: int | None = None,
                  shard_rows: bool = False):
    """Blocked online-softmax attention: outer scan over q blocks, inner
    scan over KV chunks.  ``shard_rows`` hints GSPMD to reduce-scatter the
    per-chunk logits over `model` along q rows (used when heads are not
    TP-shardable, e.g. minicpm/whisper/qwen head counts)."""
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qb, ck = _chunk_sizes(s, t)
    if chunk is not None:
        ck = chunk
    if q_block is not None:
        qb = min(q_block, s)
    qpad, tpad = (-s) % qb, (-t) % ck
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if tpad:
        k = jnp.pad(k, ((0, 0), (0, tpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, tpad), (0, 0), (0, 0)))
    nq, nc = (s + qpad) // qb, (t + tpad) // ck
    qf = (q.astype(jnp.float32) * (dh ** -0.5)).reshape(
        b, nq, qb, hkv, group, dh
    )
    qf = jnp.moveaxis(qf, 1, 0)                               # (nq, b, qb, ...)
    kc = jnp.moveaxis(k.astype(jnp.float32).reshape(b, nc, ck, hkv, dh), 1, 0)
    vc = jnp.moveaxis(v.astype(jnp.float32).reshape(b, nc, ck, hkv, dh), 1, 0)
    valid = kv_len if kv_len is not None else jnp.full((b,), t)
    off = _offset_rows(q_offset)                              # (1,) or (b,)

    def q_body(_, q_in):
        qblk, iq = q_in                                       # (b, qb, hkv, g, dh)
        qi = iq * qb + jnp.arange(qb)[None, :, None] + off[:, None, None]

        def kv_body(carry, inp):
            m_prev, l_prev, acc = carry
            kj, vj, j = inp
            ki = j * ck + jnp.arange(ck)[None, :]             # (1, ck)
            logits = jnp.einsum("bshgd,bthd->bhgst", qblk, kj)
            logits = _maybe_shard(
                logits, (None, None, None, "model" if shard_rows else None,
                         None),
            )
            mask = ki[None] < valid[:, None, None]            # (b, 1, ck)
            if causal:
                mask = mask & (ki[None] <= qi)                # (b, qb, ck)
            logits = jnp.where(mask[:, None, None], logits, -1e30)
            m_cur = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
            alpha = jnp.exp(m_prev - m_cur)
            p = jnp.exp(logits - m_cur[..., None])
            l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgst,bthd->bhgsd", p, vj
            )
            return (m_cur, l_cur, acc), None

        m0 = jnp.full((b, hkv, group, qb), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, group, qb), jnp.float32)
        acc0 = jnp.zeros((b, hkv, group, qb, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, acc0), (kc, vc, jnp.arange(nc))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]          # (b,hkv,g,qb,dh)
        return None, out

    _, outs = jax.lax.scan(q_body, None, (qf, jnp.arange(nq)))
    # (nq, b, hkv, g, qb, dh) -> (b, s, hq, dh)
    outs = jnp.moveaxis(outs, 0, 1).reshape(b, nq, hq, qb, dh)
    outs = jnp.moveaxis(outs, 2, 3).reshape(b, nq * qb, hq, dh)
    return outs[:, :s].astype(q.dtype)


def _sdpa(q, k, v, causal: bool, q_offset, kv_len=None):
    b, s, hq, dh = q.shape
    t = k.shape[1]
    # Row-shard the logits when heads cannot be TP-sharded (minicpm 36H,
    # whisper 12H, qwen 40H): the dh-contraction then reduce-scatters
    # instead of all-reducing, bounding the per-chip buffer.
    tp = _ambient_model_axis()
    shard_rows = tp is not None and hq % tp != 0
    if s * t > _SDPA_CHUNK_THRESHOLD or t > _SDPA_DECODE_T:
        return _sdpa_chunked(q, k, v, causal, q_offset, kv_len,
                             shard_rows=shard_rows)
    return _sdpa_naive(q, k, v, causal, q_offset, kv_len)


def seg_mask(s: int, seg_lens: jnp.ndarray | None) -> jnp.ndarray | None:
    """(b, s) validity mask for a ragged block: col i valid iff i < seg_lens[b]."""
    if seg_lens is None:
        return None
    return jnp.arange(s)[None, :] < seg_lens[:, None]


def last_valid_slice(x: jnp.ndarray, seg_lens: jnp.ndarray | None) -> jnp.ndarray:
    """Gather each slot's last *valid* position: x (b, s, d) -> (b, 1, d).

    seg_lens None means the whole block is valid (uniform prefill) — the
    seed's ``x[:, -1:]``.  Slots with seg_lens == 0 return row 0 (garbage
    by contract; the serve engine never reads them)."""
    if seg_lens is None:
        return x[:, -1:]
    idx = jnp.clip(seg_lens - 1, 0, x.shape[1] - 1)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)


def append_kv(cache_kv: jnp.ndarray, new: jnp.ndarray, lengths: jnp.ndarray,
              seg_lens: jnp.ndarray | None) -> jnp.ndarray:
    """Scatter a (b, s, ...) block into a (b, S, ...) ring at per-slot cursors.

    Row i of slot b lands at position lengths[b] + i.  Invalid rows
    (i >= seg_lens[b]) and overflow (pos >= S) are redirected out of bounds
    and DROPPED by the scatter — padding never lands in the cache and a
    full slot can never clobber its own valid tail."""
    b, s = new.shape[:2]
    S = cache_kv.shape[1]
    pos = lengths[:, None] + jnp.arange(s)[None, :]           # (b, s)
    valid = seg_mask(s, seg_lens)
    if valid is not None:
        pos = jnp.where(valid, pos, S)
    return cache_kv.at[jnp.arange(b)[:, None], pos].set(
        new.astype(cache_kv.dtype), mode="drop"
    )


# ---------------------------------------------------------------------------
# Paged KV layout (DESIGN.md §5.2): K/V live in an (n_pages, page_size, ...)
# pool shared across slots; a per-slot page table (b, pages_per_slot) maps
# logical page indices to physical page ids (-1 = unmapped).  The serve
# engine's host-side free-list assigns pages at admission, so HBM cost
# follows each request's actual footprint instead of slots x max_len.
#
# Nothing here knows whether two tables alias the same physical page:
# gather/scatter are pure functions of (pool, table), so prefix sharing
# (DESIGN.md §5.4) is entirely a host-side page-table/refcount concern —
# slots whose tables map a shared page read identical bytes, and write
# isolation holds because the engine only ever shares pages that sit
# wholly below every sharer's cursor (the scatter never writes below
# `lengths`, and drop-semantics fence everything else).
# ---------------------------------------------------------------------------

def paged_kv_spec(batch: int, max_len: int, page_size: int,
                  n_pages: int | None = None) -> tuple[int, int]:
    """(pages_per_slot, n_pages) for a paged pool over ``batch`` slots.

    ``n_pages`` None sizes the pool to full contiguous capacity (every slot
    can hold max_len); the serve engine passes a smaller pool to
    oversubscribe."""
    per_slot = -(-max_len // page_size)
    return per_slot, (batch * per_slot if n_pages is None else n_pages)


def paged_kv_buffers(lead: tuple, batch: int, max_len: int, cfg,
                     n_pages: int | None = None):
    """Zeroed paged K/V pool with leading stack axes ``lead``, plus the
    all-unmapped (batch, pages_per_slot) page table — the shared cache-init
    path for every paged cache family."""
    per_slot, N = paged_kv_spec(batch, max_len, cfg.kv_page_size, n_pages)
    shape = (*lead, N, cfg.kv_page_size, cfg.n_kv_heads, cfg.head_dim_)
    dt = jnp.dtype(cfg.dtype)
    kv = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    return kv, jnp.full((batch, per_slot), -1, jnp.int32)


def append_kv_paged(pool: jnp.ndarray, new: jnp.ndarray, lengths: jnp.ndarray,
                    seg_lens: jnp.ndarray | None,
                    pages: jnp.ndarray) -> jnp.ndarray:
    """Scatter a (b, s, ...) block into an (N, page_size, ...) page pool.

    Row i of slot b lands at logical position ``lengths[b] + i``, translated
    through ``pages`` (b, P) to physical page ``pages[b, pos // page_size]``,
    offset ``pos % page_size``.  Invalid rows (i >= seg_lens[b]), positions
    beyond the mapped page range, and unmapped pages (-1) all redirect to
    physical page N and are DROPPED by the scatter — the paged twin of
    :func:`append_kv`'s overflow semantics."""
    b, s = new.shape[:2]
    N, psz = pool.shape[0], pool.shape[1]
    P = pages.shape[1]
    pos = lengths[:, None] + jnp.arange(s)[None, :]           # (b, s)
    pi, wi = pos // psz, pos % psz
    phys = jnp.take_along_axis(pages, jnp.clip(pi, 0, P - 1), axis=1)
    drop = (pi >= P) | (phys < 0)
    valid = seg_mask(s, seg_lens)
    if valid is not None:
        drop = drop | ~valid
    phys = jnp.where(drop, N, phys)
    return pool.at[phys.reshape(-1), wi.reshape(-1)].set(
        new.reshape((b * s,) + new.shape[2:]).astype(pool.dtype), mode="drop"
    )


def gather_pages(pool: jnp.ndarray, pages: jnp.ndarray) -> jnp.ndarray:
    """(N, page_size, ...) pool + (b, P) table -> dense (b, P*page_size, ...).

    Unmapped entries (-1) clamp to page 0: their content is garbage by
    contract and masked by the caller's ``kv_len``, exactly like the stale
    tail bytes of the contiguous ring.  With page_size dividing max_len the
    gathered width equals the contiguous ring width, so the downstream
    online-softmax is bit-identical between layouts.

    Layout-pure under sharing: the gather depends only on (pool bytes,
    table entries), never on which slot "owns" a page — tables that alias
    the same physical page (prefix sharing, DESIGN.md §5.4) materialize
    bit-identical rows for the aliased positions, including within the
    admission dispatch that writes them (the scatter's output pool is the
    gather's input, so a same-wave sharer reads the owner's fresh K/V)."""
    N, psz = pool.shape[0], pool.shape[1]
    b, P = pages.shape
    g = jnp.take(pool, jnp.clip(pages, 0, N - 1), axis=0)     # (b, P, psz, ...)
    return g.reshape((b, P * psz) + pool.shape[2:])


def _decode_step_kernel(q, kc, vc, kv_len, cfg, pages):
    """Route the s == 1 decode step through the Pallas split-KV kernels.

    A single causal query sits at its slot's cursor, so the causal mask is
    subsumed by the length mask (``ki <= cursor``  <=>  ``ki < kv_len``):
    the kernels' per-slot ``lengths`` masking reproduces ``_sdpa``'s
    causal + ``kv_len`` masking exactly.

    ``pallas_paged`` on a paged cache dereferences the page table inside
    the kernel (no ``gather_pages`` copy — the pool is read in place);
    any other non-"xla" value (``pallas_gather``) runs the same kernel
    math over the dense gathered view with the KV block pinned to the
    page size, which makes it the bit-identity reference for the paged
    path (see kernels/decode_attention).  On a contiguous cache both fall
    back to the dense kernel over the ring.
    """
    from repro.kernels.decode_attention import ops as dec_ops

    q1 = q[:, 0]                                       # (b, hq, dh)
    if pages is not None:
        psz, n_pages = kc.shape[1], pages.shape[1]
        splits = max(1, min(
            cfg.decode_splits or dec_ops.plan_splits(n_pages * psz, psz),
            n_pages,
        ))
        if cfg.decode_kernel == "pallas_paged":
            out = dec_ops.paged_decode_attention(
                q1, kc, vc, pages, kv_len, splits=splits
            )
        else:
            kd = jnp.swapaxes(gather_pages(kc, pages), 1, 2)
            vd = jnp.swapaxes(gather_pages(vc, pages), 1, 2)
            out = dec_ops.decode_attention(
                q1, kd, vd, kv_len, bkv=psz, splits=splits
            )
    else:
        t = kc.shape[1]
        bkv = min(512, t)
        splits = cfg.decode_splits or dec_ops.plan_splits(t, bkv)
        out = dec_ops.decode_attention(
            q1, jnp.swapaxes(kc, 1, 2), jnp.swapaxes(vc, 1, 2), kv_len,
            bkv=bkv, splits=splits,
        )
    return out[:, None]                                # (b, 1, hq, dh)


def apply_attn(
    p: Params,
    x: jnp.ndarray,                   # (b, s, d)
    cfg: ModelConfig,
    positions: jnp.ndarray,           # (b, s) or (s,)
    kv_src: jnp.ndarray | None = None,  # cross-attn source (b, t, d)
    cache: Params | None = None,      # {"k","v": (b, S, hkv, dh), "lengths": (b,)}
    causal: bool = True,
    use_rope: bool = True,
    seg_lens: jnp.ndarray | None = None,  # (b,) valid new tokens per slot
) -> tuple[jnp.ndarray, Params | None]:
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    src = x if kv_src is None else kv_src
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", src, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", src, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if use_rope and kv_src is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    kv_len = None
    kernel_out = None
    q_offset: Any = 0
    is_cross_cached = cache is not None and "lengths" not in cache
    if cache is not None:
        if kv_src is None and not is_cross_cached:
            # Self-attention decode/prefill-append: scatter at per-slot
            # cursors.  cache["lengths"] is the (b,) int32 ragged cursor
            # vector; slots free and re-admit independently.  Positions at
            # or beyond each slot's valid length hold stale bytes but are
            # masked out via kv_len below and overwritten as the cursor
            # advances.
            lengths = cache["lengths"]
            if "pages" in cache:
                # Paged pool: scatter through the page table.  The XLA
                # path then gathers a dense per-slot view for the masked
                # online-softmax; the Pallas decode-step path below reads
                # the pool in place instead — no gather copy.
                pages = cache["pages"]
                kc = append_kv_paged(cache["k"], k, lengths, seg_lens, pages)
                vc = append_kv_paged(cache["v"], v, lengths, seg_lens, pages)
            else:
                kc = append_kv(cache["k"], k, lengths, seg_lens)
                vc = append_kv(cache["v"], v, lengths, seg_lens)
            kv_len = lengths + (
                jnp.int32(s) if seg_lens is None else seg_lens
            )
            new_cache = {"k": kc, "v": vc}
            q_offset = lengths
            if cfg.decode_kernel != "xla" and s == 1 and causal:
                kernel_out = _decode_step_kernel(
                    q, kc, vc, kv_len, cfg, cache.get("pages")
                )
            elif "pages" in cache:
                k = gather_pages(kc, pages)
                v = gather_pages(vc, pages)
            else:
                k, v = kc, vc
        else:
            # Cross-attention: cache holds precomputed source K/V.
            k, v = cache["k"], cache["v"]
            new_cache = cache
    is_cross = kv_src is not None or is_cross_cached
    if kernel_out is not None:
        out = kernel_out
    else:
        out = _sdpa(q, k, v, causal=causal and not is_cross,
                    q_offset=q_offset, kv_len=kv_len)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "wg": dense_init(ks[0], (d, f), d, dt),
            "wu": dense_init(ks[1], (d, f), d, dt),
            "wd": dense_init(ks[2], (f, d), f, dt),
        }
    return {
        "wu": dense_init(ks[0], (d, f), d, dt),
        "wd": dense_init(ks[1], (f, d), f, dt),
    }


def apply_mlp(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    else:
        h = jax.nn.gelu(x @ p["wu"])
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# MoE (top-k routing, capacity-based dense dispatch — GShard style)
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), d, jnp.float32),
        "wg": dense_init(ks[1], (e, d, f), d, dt),
        "wu": dense_init(ks[2], (e, d, f), d, dt),
        "wd": dense_init(ks[3], (e, f, d), f, dt),
    }


def _route(p, xf, cfg: ModelConfig):
    e, k = cfg.n_experts, cfg.top_k
    logits = (xf.astype(jnp.float32)) @ p["router"]          # (t, e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                  # (t, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)        # (t, k, e)
    density = jnp.mean(onehot.sum(1), axis=0)
    prob_mean = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_weight * e * jnp.sum(density * prob_mean)
    return gate_vals, idx, onehot, aux


def apply_moe(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    """Returns (y, aux_loss).

    Two dispatch schedules (cfg.moe_dispatch):

    * ``dense`` — one-hot masked einsum: every expert processes every
      token.  Simple and robust under pjit, but compute scales with E
      (useful-FLOP ratio ~ top_k/E: 0.125 for olmoe).  This is the
      paper-faithful baseline.
    * ``sorted`` — capacity-based sorted dispatch (MegaBlocks/GShard
      style): (token, slot) pairs sort by expert, gather into (E, C, d)
      capacity buffers, batched expert GEMM, scatter back.  Compute
      scales with top_k * capacity_factor — the E/(k*cf) FLOP cut the A4
      §Perf iteration quantifies.  Tokens overflowing an expert's
      capacity are dropped (standard GShard semantics).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(b * s, d)
    t = b * s
    gate_vals, idx, onehot, aux = _route(p, xf, cfg)

    if cfg.moe_dispatch == "sorted":
        cap = int(-(-t * k * cfg.capacity_factor // e))
        cap = min(max(128, -(-cap // 128) * 128), t * k)
        eidx = idx.reshape(-1)                                # (t*k,)
        gates = gate_vals.reshape(-1).astype(jnp.float32)
        tok = jnp.repeat(jnp.arange(t), k)
        order = jnp.argsort(eidx)                             # stable
        eidx_s, tok_s, gate_s = eidx[order], tok[order], gates[order]
        counts = jnp.bincount(eidx, length=e)
        starts = jnp.cumsum(counts) - counts                  # exclusive
        pos = jnp.arange(t * k) - starts[eidx_s]              # rank in expert
        keep = pos < cap
        pos_c = jnp.where(keep, pos, 0)
        eidx_c = jnp.where(keep, eidx_s, 0)
        xe = jnp.zeros((e, cap, d), x.dtype).at[
            eidx_c, pos_c
        ].add(xf[tok_s] * keep[:, None].astype(x.dtype))      # (e, C, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) * jnp.einsum(
            "ecd,edf->ecf", xe, p["wu"]
        )
        ye = jnp.einsum("ecf,efd->ecd", h, p["wd"])           # (e, C, d)
        contrib = ye[eidx_c, pos_c] * (
            gate_s * keep.astype(jnp.float32)
        )[:, None].astype(x.dtype)
        y = jnp.zeros((t, d), x.dtype).at[tok_s].add(contrib)
        return y.reshape(b, s, d), aux

    # Dispatch with the 0/1 mask, combine with the gates POST-expert
    # (y = sum_i g_i * expert_i(x) — standard MoE semantics).
    mask = jnp.max(onehot, axis=1)                            # (t, e) in {0,1}
    combine = jnp.einsum("tk,tke->te", gate_vals, onehot)     # (t, e)
    xe = jnp.einsum("te,td->etd", mask.astype(x.dtype), xf)   # (e, t, d)
    h = jax.nn.silu(jnp.einsum("etd,edf->etf", xe, p["wg"])) * jnp.einsum(
        "etd,edf->etf", xe, p["wu"]
    )
    ye = jnp.einsum("etf,efd->etd", h, p["wd"])               # (e, t, d)
    y = jnp.einsum("etd,te->td", ye, combine.astype(x.dtype))
    return y.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init_params(key, cfg: ModelConfig) -> Params:
    v, d = cfg.padded_vocab, cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2)
    p = {"tok": embed_init(ks[0], (v, d), dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (d, v), d, dt)
    return p


def embed(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: Params, h: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, p["tok"])
    return jnp.einsum("bsd,dv->bsv", h, p["unembed"])


# Vocab-chunked logsumexp above this size: never materializes the fp32
# logit tensor (a 51k-vocab, 65k-token device batch would need 12.5 GiB).
_CE_CHUNK_VOCAB = 16384


def _chunked_logsumexp(logits: jnp.ndarray, vocab_valid: int) -> jnp.ndarray:
    v = logits.shape[-1]
    chunk = _CE_CHUNK_VOCAB
    pad = (-v) % chunk
    nc = (v + pad) // chunk
    lead = logits.shape[:-1]
    lc = jnp.moveaxis(
        jnp.pad(logits, [(0, 0)] * (logits.ndim - 1) + [(0, pad)],
                constant_values=-1e30).reshape(*lead, nc, chunk),
        -2, 0,
    )

    def body(carry, inp):
        m_prev, l_prev = carry
        lj, j = inp
        idx = j * chunk + jnp.arange(chunk)
        x = jnp.where(idx < vocab_valid, lj.astype(jnp.float32), -1e30)
        m_cur = jnp.maximum(m_prev, jnp.max(x, axis=-1))
        l_cur = l_prev * jnp.exp(m_prev - m_cur) + jnp.sum(
            jnp.exp(x - m_cur[..., None]), axis=-1
        )
        return (m_cur, l_cur), None

    m0 = jnp.full(lead, -1e30, jnp.float32)
    l0 = jnp.zeros(lead, jnp.float32)
    (m, l), _ = jax.lax.scan(body, (m0, l0), (lc, jnp.arange(nc)))
    return m + jnp.log(jnp.maximum(l, 1e-30))


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, vocab_valid: int
) -> jnp.ndarray:
    """Mean next-token loss; padded vocab entries masked out."""
    v = logits.shape[-1]
    if logits.size > 256 * 1024**2 // 4 and v > _CE_CHUNK_VOCAB:
        logz = _chunked_logsumexp(logits, vocab_valid)
        gold = jnp.take_along_axis(
            logits, labels[..., None], axis=-1
        )[..., 0].astype(jnp.float32)
        return jnp.mean(logz - gold)
    lf = logits.astype(jnp.float32)
    if vocab_valid < v:
        pad_mask = jnp.arange(v) < vocab_valid
        lf = jnp.where(pad_mask, lf, -1e30)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


@dataclasses.dataclass
class ModelApply:
    """Bundle returned by each model module.

    ``prefill``/``decode_step`` accept an optional keyword ``seg_lens``
    ((b,) int32): the number of valid new tokens per slot in this call.
    None means the whole block is valid for every slot (the uniform path).
    ``seg_lens[b] == 0`` leaves slot b's cache state untouched — how the
    serve engine parks finished slots inside a decode chunk.

    ``prefill(..., all_logits=True)`` returns logits for every position of
    the block ((b, s, v) instead of the last-valid (b, 1, v)) — the
    speculative verify path scores all draft positions in one dispatch
    (DESIGN.md §5.3).  Rows at or beyond ``seg_lens[b]`` are garbage by
    contract, exactly like ``last_valid_slice`` on a parked slot.

    ``reset_slots(cache, mask)`` clears per-slot recurrent state (cursor,
    SSM/conv state) for slots where mask is True, so a freed slot can be
    re-admitted mid-stream without a fresh cache allocation."""

    config: ModelConfig
    init: Any            # (key) -> params
    forward: Any         # (params, tokens, extras) -> logits
    loss: Any            # (params, batch) -> (loss, metrics)
    init_cache: Any      # (params, batch, max_len, extras) -> cache
    prefill: Any         # (params, cache, tokens, seg_lens) -> (logits, cache)
    decode_step: Any     # (params, cache, tokens, seg_lens) -> (logits, cache)
    reset_slots: Any = None  # (cache, mask (b,) bool) -> cache


def reset_lengths(cache: Params, mask: jnp.ndarray) -> Params:
    """Default reset: rewind the ragged cursor; stale KV is masked/overwritten."""
    cache = dict(cache)
    cache["lengths"] = jnp.where(mask, 0, cache["lengths"]).astype(jnp.int32)
    return cache


def reset_recurrent(cache: Params, mask: jnp.ndarray,
                    state_keys: tuple = ("ssm", "conv")) -> Params:
    """reset_lengths plus zeroed recurrent-state leaves.

    Unlike KV buffers, SSM/conv state has no validity mask — a re-admitted
    slot must start from genuinely zero state.  Each ``state_keys`` entry is
    either a key (batch expected on axis 1, the (L, b, ...) stacked-layer
    layout) or a ``(key, axis)`` pair; the leaf's shape is checked against
    the mask so a cache family with a different batch axis fails loudly
    instead of silently corrupting parked slots.  Leaves not named (e.g.
    zamba2's "kv") pass through untouched."""
    out = reset_lengths(cache, mask)
    b = mask.shape[0]
    keep = ~mask
    for entry in state_keys:
        key, axis = entry if isinstance(entry, tuple) else (entry, 1)
        leaf = cache[key]
        if leaf.ndim <= axis or leaf.shape[axis] != b:
            raise ValueError(
                f"reset_recurrent: cache leaf '{key}' has shape "
                f"{tuple(leaf.shape)} but the batch axis ({axis}) must have "
                f"size {b}; pass (key, axis) in state_keys for this layout"
            )
        shape = [1] * leaf.ndim
        shape[axis] = b
        out[key] = leaf * keep.astype(leaf.dtype).reshape(shape)
    return out
