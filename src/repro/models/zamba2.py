"""Zamba2-style hybrid: Mamba-2 backbone + ONE shared attention block
applied every k SSM layers — arXiv:2411.15242.

Simplifications vs the released model (recorded in DESIGN.md §5): the shared
block operates at d_model (the release concatenates [hidden, embedding] at
2*d_model before projecting), and per-invocation LoRA deltas are omitted.
Weight sharing is exact: one parameter set, ``n_layers/k`` invocations, each
with its own KV cache (weights shared, cache not).

Decode state is O(1) for the SSM layers plus k-th-layer KV caches — the
sub-quadratic property that qualifies zamba2 for the long_500k cell, where
the shared-block caches are read with sequence-parallel flash-decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.remat import RematPolicy, apply_remat
from repro.models import common as cm
from repro.models import mamba2 as mb


def init(key, cfg: ModelConfig):
    assert cfg.shared_attn_every > 0
    ks = jax.random.split(key, 4)
    n_groups = cfg.n_layers // cfg.shared_attn_every
    params = {
        "embed": cm.embed_init_params(ks[0], cfg),
        "ln_f": cm.norm_init(cfg),
        "layers": jax.vmap(lambda k2: mb._layer_init(k2, cfg))(
            jax.random.split(ks[1], cfg.n_layers)
        ),
        # The single shared transformer block (params counted once).
        "shared": {
            "ln1": cm.norm_init(cfg),
            "attn": cm.attn_init(ks[2], cfg),
            "ln2": cm.norm_init(cfg),
            "mlp": cm.mlp_init(ks[3], cfg),
        },
    }
    del n_groups
    return params


def _shared_block(p, x, cfg, positions, cache=None, seg_lens=None):
    h, new_cache = cm.apply_attn(
        p["attn"], cm.apply_norm(p["ln1"], x, cfg), cfg, positions, cache=cache,
        seg_lens=seg_lens,
    )
    x = x + h
    x = x + cm.apply_mlp(p["mlp"], cm.apply_norm(p["ln2"], x, cfg), cfg)
    return x, new_cache


def _group_view(tree, n_groups: int, k: int):
    """Reshape stacked (L, ...) layer params to (G, k, ...)."""
    return jax.tree_util.tree_map(
        lambda a: a.reshape(n_groups, k, *a.shape[1:]), tree
    )


def forward(params, tokens, cfg: ModelConfig,
            remat: RematPolicy = RematPolicy.SAVE_DOTS):
    k = cfg.shared_attn_every
    g = cfg.n_layers // k
    x = cm.embed(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]
    glayers = _group_view(params["layers"], g, k)
    shared = params["shared"]

    def group_body(h, gp):
        def one_mamba(hh, lp):
            y, _ = mb.apply_mamba(
                lp["mamba"], cm.apply_norm(lp["ln"], hh, cfg), cfg
            )
            return hh + y, None

        h, _ = cm.scan(one_mamba, h, gp)
        h, _ = _shared_block(shared, h, cfg, positions)
        return h, None

    body = apply_remat(group_body, remat)
    x, _ = cm.scan(body, x, glayers)
    x = cm.apply_norm(params["ln_f"], x, cfg)
    return cm.unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: ModelConfig,
            remat: RematPolicy = RematPolicy.SAVE_DOTS):
    logits, aux = forward(params, batch["tokens"], cfg, remat=remat)
    ce = cm.cross_entropy(logits, batch["labels"], cfg.vocab)
    return ce + aux, {"ce": ce, "aux": aux}


def init_cache(params, cfg: ModelConfig, batch: int, max_len: int, vis=None,
               n_pages=None):
    k = cfg.shared_attn_every
    g = cfg.n_layers // k
    h, ds, dh = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    dt = jnp.dtype(cfg.dtype)
    if cfg.cache_layout == "paged":
        kv, pages = cm.paged_kv_buffers((g,), batch, max_len, cfg, n_pages)
    else:
        kv_shape = (g, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
        kv = {"k": jnp.zeros(kv_shape, dt), "v": jnp.zeros(kv_shape, dt)}
        pages = None
    cache = {
        "ssm": jnp.zeros((cfg.n_layers, batch, h, ds, dh), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch), dt),
        "kv": kv,
        "lengths": jnp.zeros((batch,), jnp.int32),
    }
    if pages is not None:
        cache["pages"] = pages
    return cache


def prefill(params, cache, tokens, cfg: ModelConfig, seg_lens=None,
            all_logits=False):
    b, s = tokens.shape
    k = cfg.shared_attn_every
    g = cfg.n_layers // k
    x = cm.embed(params["embed"], tokens)
    lengths = cache["lengths"]
    pages = cache.get("pages")
    positions = lengths[:, None] + jnp.arange(s)[None, :]
    glayers = _group_view(params["layers"], g, k)
    gssm = cache["ssm"].reshape(g, k, *cache["ssm"].shape[1:])
    gconv = cache["conv"].reshape(g, k, *cache["conv"].shape[1:])
    shared = params["shared"]

    def group_body(h, inp):
        gp, ssm_g, conv_g, kv_g = inp

        def one_mamba(hh, inp2):
            lp, st, cv = inp2
            y, (nst, ncv) = mb.apply_mamba(
                lp["mamba"], cm.apply_norm(lp["ln"], hh, cfg), cfg,
                state=st, conv_prev=cv, seg_lens=seg_lens,
            )
            return hh + y, (nst, ncv)

        h, (nssm, nconv) = cm.scan(one_mamba, h, (gp, ssm_g, conv_g))
        kv_in = {"k": kv_g["k"], "v": kv_g["v"], "lengths": lengths}
        if pages is not None:
            kv_in["pages"] = pages
        h, nkv = _shared_block(
            shared, h, cfg, positions, cache=kv_in, seg_lens=seg_lens
        )
        return h, (nssm, nconv, nkv)

    x, (nssm, nconv, nkv) = cm.scan(
        group_body, x,
        (glayers, gssm, gconv,
         {"k": cache["kv"]["k"], "v": cache["kv"]["v"]}),
    )
    x = cm.apply_norm(params["ln_f"], x, cfg)
    out = x if all_logits else cm.last_valid_slice(x, seg_lens)
    logits = cm.unembed(params["embed"], out, cfg)
    new_cache = {
        "ssm": nssm.reshape(cfg.n_layers, *nssm.shape[2:]),
        "conv": nconv.reshape(cfg.n_layers, *nconv.shape[2:]),
        "kv": {"k": nkv["k"], "v": nkv["v"]},
        "lengths": lengths + (s if seg_lens is None else seg_lens),
    }
    if pages is not None:
        new_cache["pages"] = pages
    return logits, new_cache


def decode_step(params, cache, tokens, cfg: ModelConfig, seg_lens=None):
    return prefill(params, cache, tokens, cfg, seg_lens=seg_lens)


def build(cfg: ModelConfig) -> cm.ModelApply:
    return cm.ModelApply(
        config=cfg,
        init=functools.partial(init, cfg=cfg),
        forward=functools.partial(forward, cfg=cfg),
        loss=functools.partial(loss_fn, cfg=cfg),
        init_cache=functools.partial(init_cache, cfg=cfg),
        prefill=functools.partial(prefill, cfg=cfg),
        decode_step=functools.partial(decode_step, cfg=cfg),
        reset_slots=cm.reset_recurrent,
    )
