"""Decoder-only transformer LM covering dense GQA, MoE, and VLM variants.

One implementation serves yi-9b / granite-20b / minicpm-2b / qwen2.5-32b
(dense), phi3.5-moe / olmoe (MoE FFN), and llama-3.2-vision (interleaved
cross-attention to stub vision-patch embeddings).

Layers are stacked and scanned (``jax.lax.scan``) so trace/compile time is
O(1) in depth; the activation (remat) policy comes from the cache-policy
engine and wraps the scan body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.remat import RematPolicy, apply_remat
from repro.models import common as cm


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": cm.norm_init(cfg),
        "attn": cm.attn_init(ks[0], cfg),
        "ln2": cm.norm_init(cfg),
    }
    if cfg.family == "moe" and not cross:
        p["moe"] = cm.moe_init(ks[1], cfg)
    else:
        p["mlp"] = cm.mlp_init(ks[1], cfg)
    return p


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    params = {"embed": cm.embed_init_params(ks[0], cfg), "ln_f": cm.norm_init(cfg)}
    if cfg.cross_attn_every:
        g = cfg.n_layers // cfg.cross_attn_every
        span = cfg.cross_attn_every - 1
        self_keys = jax.random.split(ks[1], g * span).reshape(g, span, 2)
        cross_keys = jax.random.split(ks[2], g)
        params["self_layers"] = jax.vmap(
            lambda kk: jax.vmap(lambda k2: _layer_init(k2, cfg))(kk)
        )(self_keys)
        params["cross_layers"] = jax.vmap(
            lambda k2: _layer_init(k2, cfg, cross=True)
        )(cross_keys)
        params["vis_proj"] = cm.dense_init(
            ks[3], (cfg.d_model, cfg.d_model), cfg.d_model, jnp.dtype(cfg.dtype)
        )
    else:
        layer_keys = jax.random.split(ks[1], cfg.n_layers)
        params["layers"] = jax.vmap(lambda k2: _layer_init(k2, cfg))(layer_keys)
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _self_block(p, x, cfg: ModelConfig, positions, cache=None, seg_lens=None):
    h, new_cache = cm.apply_attn(
        p["attn"], cm.apply_norm(p["ln1"], x, cfg), cfg, positions, cache=cache,
        seg_lens=seg_lens,
    )
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    h2 = cm.apply_norm(p["ln2"], x, cfg)
    if "moe" in p:
        m, aux = cm.apply_moe(p["moe"], h2, cfg)
    else:
        m = cm.apply_mlp(p["mlp"], h2, cfg)
    return x + m, aux, new_cache


def _cross_block(p, x, cfg: ModelConfig, positions, vis, cache=None):
    h, new_cache = cm.apply_attn(
        p["attn"], cm.apply_norm(p["ln1"], x, cfg), cfg, positions,
        kv_src=vis, cache=cache, causal=False, use_rope=False,
    )
    x = x + h
    m = cm.apply_mlp(p["mlp"], cm.apply_norm(p["ln2"], x, cfg), cfg)
    return x + m, new_cache


# ---------------------------------------------------------------------------
# Stacks (train/no-cache and cached paths)
# ---------------------------------------------------------------------------

def _stack_nocache(params, x, cfg: ModelConfig, positions, vis,
                   remat: RematPolicy):
    if cfg.cross_attn_every:
        span = cfg.cross_attn_every - 1

        def group_body(carry, gp):
            h, aux = carry

            def one_self(c, lp):
                hh, a = c
                hh, da, _ = _self_block(lp, hh, cfg, positions)
                return (hh, a + da), None

            (h, aux), _ = cm.scan(one_self, (h, aux), gp["self"])
            h, _ = _cross_block(gp["cross"], h, cfg, positions, vis)
            return (h, aux), None

        body = apply_remat(group_body, remat)
        (x, aux), _ = cm.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            {"self": params["self_layers"], "cross": params["cross_layers"]},
        )
        return x, aux

    def body(carry, lp):
        h, aux = carry
        h, da, _ = _self_block(lp, h, cfg, positions)
        return (h, aux + da), None

    body = apply_remat(body, remat)
    (x, aux), _ = cm.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["layers"]
    )
    return x, aux


def _stack_cached(params, x, cfg: ModelConfig, positions, vis, cache,
                  seg_lens=None):
    """Scan over layers threading per-layer KV caches (stacked leading dim).

    ``cache["lengths"]`` is the (b,) ragged cursor vector shared by every
    layer (each layer sees the same tokens); per-layer caches carry only
    the K/V buffers."""
    lengths = cache["lengths"]
    pages = cache.get("pages")            # paged layout: (b, P) page table
    s = x.shape[1]
    new_lengths = lengths + (s if seg_lens is None else seg_lens)

    def kv_in(lc):
        c = {"k": lc["k"], "v": lc["v"], "lengths": lengths}
        if pages is not None:
            c["pages"] = pages
        return c

    def out_cache(layers):
        out = {"layers": layers, "lengths": new_lengths}
        if pages is not None:
            out["pages"] = pages
        return out

    if cfg.cross_attn_every:
        def group_body(h, inp):
            gp, gcache = inp

            def one_self(hh, inp2):
                lp, lc = inp2
                hh, _, nc = _self_block(
                    lp, hh, cfg, positions, cache=kv_in(lc),
                    seg_lens=seg_lens,
                )
                return hh, nc

            h, new_self = cm.scan(
                one_self, h, (gp["self"], gcache["self"])
            )
            # The nested scan's stacked KV output loses its sharding
            # through the outer while loop, replicating per-chip temps
            # ~33x the cache size (EXPERIMENTS.md §Perf S2).  Pin it.
            # Contiguous (span, b, S, hkv, dh) shards batch over "data";
            # the paged pool (span, N, psz, hkv, dh) has no batch axis —
            # any slot may reference any page — so only heads are pinned.
            spec = ((None, None, None, ("model",), None) if pages is not None
                    else (None, ("data",), ("model",), None, None))
            for key in ("k", "v"):
                new_self[key] = cm._maybe_shard(new_self[key], spec)
            h, new_cross = _cross_block(
                gp["cross"], h, cfg, positions, vis, cache=gcache["cross"]
            )
            return h, {"self": new_self, "cross": new_cross}

        x, new_cache = cm.scan(
            group_body, x,
            ({"self": params["self_layers"], "cross": params["cross_layers"]},
             cache["layers"]),
        )
        return x, out_cache(new_cache)

    def body(h, inp):
        lp, lc = inp
        h, _, nc = _self_block(
            lp, h, cfg, positions, cache=kv_in(lc), seg_lens=seg_lens,
        )
        return h, nc

    x, new_layers = cm.scan(body, x, (params["layers"], cache["layers"]))
    return x, out_cache(new_layers)


# ---------------------------------------------------------------------------
# Public model functions
# ---------------------------------------------------------------------------

def forward(params, tokens, cfg: ModelConfig, vis=None,
            remat: RematPolicy = RematPolicy.SAVE_DOTS):
    b, s = tokens.shape
    x = cm.embed(params["embed"], tokens)
    if cfg.cross_attn_every:
        assert vis is not None, "vlm forward needs vision embeddings"
        vis = vis.astype(x.dtype) @ params["vis_proj"]
    positions = jnp.arange(s)[None, :]
    x, aux = _stack_nocache(params, x, cfg, positions, vis, remat)
    x = cm.apply_norm(params["ln_f"], x, cfg)
    return cm.unembed(params["embed"], x, cfg), aux


def loss_fn(params, batch, cfg: ModelConfig,
            remat: RematPolicy = RematPolicy.SAVE_DOTS):
    logits, aux = forward(
        params, batch["tokens"], cfg, vis=batch.get("vis"), remat=remat
    )
    ce = cm.cross_entropy(logits, batch["labels"], cfg.vocab)
    return ce + aux, {"ce": ce, "aux": aux}


def init_cache(params, cfg: ModelConfig, batch: int, max_len: int, vis=None,
               n_pages=None):
    hkv, dh = cfg.n_kv_heads, cfg.head_dim_
    dt = jnp.dtype(cfg.dtype)
    pages = None

    def kv(*lead):
        nonlocal pages
        if cfg.cache_layout == "paged":
            kvs, pages = cm.paged_kv_buffers(lead, batch, max_len, cfg,
                                             n_pages)
            return kvs
        shape = (*lead, batch, max_len, hkv, dh)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    def with_pages(cache):
        cache["lengths"] = jnp.zeros((batch,), jnp.int32)
        if pages is not None:
            cache["pages"] = pages
        return cache

    if cfg.cross_attn_every:
        g = cfg.n_layers // cfg.cross_attn_every
        span = cfg.cross_attn_every - 1
        assert vis is not None, "vlm cache needs vision embeddings"
        visp = vis.astype(dt) @ params["vis_proj"]
        # Precompute cross K/V once per cross layer (reused every step —
        # the RESIDENT operand of VLM decoding).  Cross K/V stay contiguous
        # regardless of layout: they are fixed-source and never appended.
        def cross_kv(lp):
            k = jnp.einsum("btd,dhk->bthk", visp, lp["attn"]["wk"])
            v = jnp.einsum("btd,dhk->bthk", visp, lp["attn"]["wv"])
            if cfg.qkv_bias:
                k = k + lp["attn"]["bk"]
                v = v + lp["attn"]["bv"]
            return {"k": k, "v": v}

        cross = jax.vmap(cross_kv)(params["cross_layers"])
        return with_pages({
            "layers": {"self": kv(g, span), "cross": cross}, "vis": visp,
        })
    return with_pages({"layers": kv(cfg.n_layers)})


def prefill(params, cache, tokens, cfg: ModelConfig, vis=None, seg_lens=None,
            all_logits=False):
    b, s = tokens.shape
    x = cm.embed(params["embed"], tokens)
    positions = cache["lengths"][:, None] + jnp.arange(s)[None, :]
    visp = cache.get("vis") if cfg.cross_attn_every else None
    x, new_cache = _stack_cached(
        params, x, cfg, positions, visp, cache, seg_lens=seg_lens
    )
    if cfg.cross_attn_every:
        new_cache["vis"] = cache["vis"]
    x = cm.apply_norm(params["ln_f"], x, cfg)
    out = x if all_logits else cm.last_valid_slice(x, seg_lens)
    logits = cm.unembed(params["embed"], out, cfg)
    return logits, new_cache


def decode_step(params, cache, tokens, cfg: ModelConfig, seg_lens=None):
    return prefill(params, cache, tokens, cfg, seg_lens=seg_lens)


def build(cfg: ModelConfig) -> cm.ModelApply:
    return cm.ModelApply(
        config=cfg,
        init=functools.partial(init, cfg=cfg),
        forward=functools.partial(forward, cfg=cfg),
        loss=functools.partial(loss_fn, cfg=cfg),
        init_cache=functools.partial(init_cache, cfg=cfg),
        prefill=functools.partial(prefill, cfg=cfg),
        decode_step=functools.partial(decode_step, cfg=cfg),
        reset_slots=cm.reset_lengths,
    )
