"""Mamba-2 LM (SSD, attention-free) — arXiv:2405.21060.

The XLA training path uses the same chunked SSD math as the Pallas kernel
(`repro.kernels.ssd`), implemented as a `lax.scan` over chunks so the
(b, h, Q, Q) intra-chunk attention temp is bounded to one chunk at a time —
the inter-chunk state (b, h, ds, dh) is the RESIDENT_ACCUM carry.

Decode is O(1) in context length: conv buffer (width-1 tokens) + SSM state.
This is why mamba2 (and zamba2) run the long_500k shape cell that pure
full-attention architectures skip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.remat import RematPolicy, apply_remat
from repro.kernels.ssd.ssd import ssd_decode_step
from repro.models import common as cm


# ---------------------------------------------------------------------------
# Chunked SSD (jnp; mirrors kernels/ssd math)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, D=None, chunk: int = 128, init_state=None):
    """x (b,l,h,dh), dt (b,l,h), A (h,), B/C (b,l,g,ds) -> (y, final_state)."""
    b, l, h, dh = x.shape
    g, ds = B.shape[2], B.shape[3]
    hpg = h // g
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (l + pad) // q

    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    alog = dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :]

    def chunk_view(t, extra):  # (b, nc*q, ...) -> (nc, b, q, ...)
        return jnp.moveaxis(t.reshape(b, nc, q, *extra), 1, 0)

    xdt_c = chunk_view(xdt, (h, dh))
    alog_c = chunk_view(alog, (h,))
    b_c = chunk_view(B.astype(jnp.float32), (g, ds))
    c_c = chunk_view(C.astype(jnp.float32), (g, ds))

    ti = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril = si <= ti

    def step(S, inp):
        xd, al, bb, cc = inp           # (b,q,h,dh) (b,q,h) (b,q,g,ds) (b,q,g,ds)
        cum = jnp.cumsum(al, axis=1)   # (b, q, h)
        cumT = jnp.moveaxis(cum, 1, 2)  # (b, h, q)
        diff = cumT[:, :, :, None] - cumT[:, :, None, :]
        # Mask BEFORE exp: the s>t lanes have positive diffs that overflow
        # and would poison gradients through the where.
        lmat = jnp.exp(jnp.where(tril[None, None], diff, -jnp.inf))
        cb = jnp.einsum("btgd,bsgd->bgts", cc, bb)      # (b, g, t, s)
        cb = jnp.repeat(cb, hpg, axis=1)                 # (b, h, t, s)
        y_intra = jnp.einsum("bhts,bshd->bthd", cb * lmat, xd)
        cch = jnp.repeat(cc, hpg, axis=2)                # (b, q, h, ds)
        y_inter = jnp.moveaxis(jnp.exp(cumT), 1, 2)[..., None] * jnp.einsum(
            "bthn,bhnd->bthd", cch, S
        )
        total = cumT[:, :, -1]                           # (b, h)
        bbh = jnp.repeat(bb, hpg, axis=2)                # (b, s, h, ds)
        b_scaled = bbh * jnp.exp(total[:, None, :] - cum)[..., None]
        S = S * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bshn,bshd->bhnd", b_scaled, xd
        )
        return S, y_intra + y_inter

    S0 = (
        jnp.zeros((b, h, ds, dh), jnp.float32)
        if init_state is None else init_state.astype(jnp.float32)
    )
    S, ys = cm.scan(step, S0, (xdt_c, alog_c, b_c, c_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * q, h, dh)[:, :l]
    if D is not None:
        y = y + D[None, None, :, None] * x[:, :l].astype(jnp.float32)
    return y.astype(x.dtype), S


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------

def mamba_init(key, cfg: ModelConfig) -> cm.Params:
    d, di = cfg.d_model, cfg.d_inner
    g, ds, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * g * ds
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": cm.dense_init(
            ks[0], (d, 2 * di + 2 * g * ds + h), d, dt
        ),
        "conv_w": cm.dense_init(ks[1], (cfg.ssm_conv, conv_ch), cfg.ssm_conv, dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ),
        "D": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": cm.dense_init(ks[2], (di, d), di, dt),
    }


def _split_in_proj(z_all, cfg: ModelConfig):
    di, g, ds, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = z_all[..., :di]
    xbc = z_all[..., di:di + di + 2 * g * ds]
    dt = z_all[..., -h:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, prev=None, seg_lens=None):
    """Depthwise causal conv1d.  xbc (b, l, ch); w (width, ch).

    ``prev`` (b, width-1, ch) continues a streaming sequence; returns
    (out, new_prev).  With ragged ``seg_lens``, each slot's new window ends
    at its own last valid token: ext[b, seg_lens[b] : seg_lens[b]+width-1]
    (the first width-1 entries of ext are ``prev``, so seg_lens == 0 keeps
    the window untouched — a parked slot)."""
    width = w.shape[0]
    if prev is None:
        prev = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    ext = jnp.concatenate([prev, xbc], axis=1)          # (b, l+w-1, ch)
    out = sum(
        ext[:, i:i + xbc.shape[1]] * w[i][None, None] for i in range(width)
    ) + b[None, None]
    if width == 1:
        new_prev = prev
    elif seg_lens is None:
        new_prev = ext[:, -(width - 1):]
    else:
        new_prev = jax.vmap(
            lambda e, n: jax.lax.dynamic_slice_in_dim(e, n, width - 1, axis=0)
        )(ext, seg_lens)
    return out, new_prev


def apply_mamba(p, x, cfg: ModelConfig, state=None, conv_prev=None,
                seg_lens=None):
    """x (b, l, d) -> (y, (ssm_state, conv_prev)).

    Ragged blocks gate dt to zero on invalid positions: the SSD update
    with dt == 0 is the identity (decay exp(0)=1, zero input), so padding
    — and parked slots with seg_lens == 0 — never touch the SSM state."""
    b, l, d = x.shape
    di, g, ds, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    dh = cfg.ssm_headdim
    zall = x @ p["in_proj"]
    z, xbc, dtr = _split_in_proj(zall, cfg)
    xbc, new_conv = _causal_conv(
        xbc, p["conv_w"], p["conv_b"], conv_prev, seg_lens=seg_lens
    )
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(b, l, h, dh)
    B = xbc[..., di:di + g * ds].reshape(b, l, g, ds)
    C = xbc[..., di + g * ds:].reshape(b, l, g, ds)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    valid = cm.seg_mask(l, seg_lens)
    if valid is not None:
        dt = dt * valid.astype(dt.dtype)[..., None]
    A = -jnp.exp(p["A_log"])
    if l == 1 and state is not None:
        y1, new_state = ssd_decode_step(
            xs[:, 0], dt[:, 0], A, B[:, 0], C[:, 0], p["D"], state
        )
        y = y1[:, None]
    else:
        # Adaptive chunk: bound the scan trip count (<=16) while keeping the
        # intra-chunk (b, h, Q, Q) buffer head-sharded and modest.
        chunk = min(max(128, l // 16), 1024)
        y, new_state = ssd_chunked(
            xs, dt, A, B, C, p["D"], chunk=chunk, init_state=state
        )
    y = y.reshape(b, l, di)
    gated = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(gated), axis=-1, keepdims=True)
    y = (gated * jax.lax.rsqrt(ms + cfg.norm_eps) * p["norm_w"]).astype(x.dtype)
    return y @ p["out_proj"], (new_state, new_conv)


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig):
    return {"ln": cm.norm_init(cfg), "mamba": mamba_init(key, cfg)}


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "embed": cm.embed_init_params(ks[0], cfg),
        "ln_f": cm.norm_init(cfg),
        "layers": jax.vmap(lambda k2: _layer_init(k2, cfg))(
            jax.random.split(ks[1], cfg.n_layers)
        ),
    }


def forward(params, tokens, cfg: ModelConfig,
            remat: RematPolicy = RematPolicy.SAVE_DOTS):
    x = cm.embed(params["embed"], tokens)

    def body(h, lp):
        y, _ = apply_mamba(lp["mamba"], cm.apply_norm(lp["ln"], h, cfg), cfg)
        return h + y, None

    body = apply_remat(body, remat)
    x, _ = cm.scan(body, x, params["layers"])
    x = cm.apply_norm(params["ln_f"], x, cfg)
    return cm.unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: ModelConfig,
            remat: RematPolicy = RematPolicy.SAVE_DOTS):
    logits, aux = forward(params, batch["tokens"], cfg, remat=remat)
    ce = cm.cross_entropy(logits, batch["labels"], cfg.vocab)
    return ce + aux, {"ce": ce, "aux": aux}


def init_cache(params, cfg: ModelConfig, batch: int, max_len: int, vis=None,
               n_pages=None):
    """``n_pages`` is accepted for serve-engine API uniformity; mamba2's
    decode state is O(1) per slot (no KV), so there is nothing to page."""
    h, ds, dh = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    L = cfg.n_layers
    return {
        "ssm": jnp.zeros((L, batch, h, ds, dh), jnp.float32),
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_ch), jnp.dtype(cfg.dtype)),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params, cache, tokens, cfg: ModelConfig, seg_lens=None,
            all_logits=False):
    b, s = tokens.shape
    x = cm.embed(params["embed"], tokens)

    def body(h, inp):
        lp, st, cv = inp
        y, (new_st, new_cv) = apply_mamba(
            lp["mamba"], cm.apply_norm(lp["ln"], h, cfg), cfg,
            state=st, conv_prev=cv, seg_lens=seg_lens,
        )
        return h + y, (new_st, new_cv)

    x, (new_ssm, new_conv) = cm.scan(
        body, x, (params["layers"], cache["ssm"], cache["conv"])
    )
    x = cm.apply_norm(params["ln_f"], x, cfg)
    out = x if all_logits else cm.last_valid_slice(x, seg_lens)
    logits = cm.unembed(params["embed"], out, cfg)
    return logits, {
        "ssm": new_ssm, "conv": new_conv,
        "lengths": cache["lengths"] + (s if seg_lens is None else seg_lens),
    }


def decode_step(params, cache, tokens, cfg: ModelConfig, seg_lens=None):
    return prefill(params, cache, tokens, cfg, seg_lens=seg_lens)


def build(cfg: ModelConfig) -> cm.ModelApply:
    return cm.ModelApply(
        config=cfg,
        init=functools.partial(init, cfg=cfg),
        forward=functools.partial(forward, cfg=cfg),
        loss=functools.partial(loss_fn, cfg=cfg),
        init_cache=functools.partial(init_cache, cfg=cfg),
        prefill=functools.partial(prefill, cfg=cfg),
        decode_step=functools.partial(decode_step, cfg=cfg),
        reset_slots=cm.reset_recurrent,
    )
