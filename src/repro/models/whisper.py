"""Whisper-style encoder-decoder backbone — arXiv:2212.04356.

The conv audio frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (b, enc_seq, d); sinusoidal positions
are added here.  Encoder: bidirectional self-attention; decoder: causal
self-attention (learned positions) + cross-attention to encoder states.
LayerNorm + GELU, pre-norm with final norms, per the architecture.

Policy note: the encoder output K/V are the canonical RESIDENT operands of
enc-dec serving — computed once, reused by every decode step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.remat import RematPolicy, apply_remat
from repro.models import common as cm

MAX_DEC_POS = 65536  # learned decoder position table (covers decode_32k)


def _sinusoid(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": cm.norm_init(cfg), "attn": cm.attn_init(ks[0], cfg),
        "ln2": cm.norm_init(cfg), "mlp": cm.mlp_init(ks[1], cfg),
    }


def _dec_layer_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": cm.norm_init(cfg), "self_attn": cm.attn_init(ks[0], cfg),
        "ln2": cm.norm_init(cfg), "cross_attn": cm.attn_init(ks[1], cfg),
        "ln3": cm.norm_init(cfg), "mlp": cm.mlp_init(ks[2], cfg),
    }


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    return {
        "embed": cm.embed_init_params(ks[0], cfg),
        "dec_pos": cm.embed_init(ks[3], (MAX_DEC_POS, cfg.d_model),
                                 jnp.dtype(cfg.dtype)),
        "enc_layers": jax.vmap(lambda k2: _enc_layer_init(k2, cfg))(
            jax.random.split(ks[1], cfg.enc_layers)
        ),
        "dec_layers": jax.vmap(lambda k2: _dec_layer_init(k2, cfg))(
            jax.random.split(ks[2], cfg.n_layers)
        ),
        "ln_enc": cm.norm_init(cfg),
        "ln_f": cm.norm_init(cfg),
    }


def encode(params, frames, cfg: ModelConfig,
           remat: RematPolicy = RematPolicy.SAVE_DOTS):
    """frames: stub embeddings (b, s_enc, d)."""
    b, s, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) + _sinusoid(s, d).astype(cfg.dtype)
    positions = jnp.arange(s)[None, :]

    def body(h, lp):
        a, _ = cm.apply_attn(
            lp["attn"], cm.apply_norm(lp["ln1"], h, cfg), cfg, positions,
            causal=False, use_rope=False,
        )
        h = h + a
        h = h + cm.apply_mlp(lp["mlp"], cm.apply_norm(lp["ln2"], h, cfg), cfg)
        return h, None

    body = apply_remat(body, remat)
    x, _ = cm.scan(body, x, params["enc_layers"])
    return cm.apply_norm(params["ln_enc"], x, cfg)


def _dec_block(lp, h, cfg, positions, enc_out, self_cache=None,
               cross_cache=None, seg_lens=None):
    a, new_self = cm.apply_attn(
        lp["self_attn"], cm.apply_norm(lp["ln1"], h, cfg), cfg, positions,
        cache=self_cache, causal=True, use_rope=False, seg_lens=seg_lens,
    )
    h = h + a
    c, new_cross = cm.apply_attn(
        lp["cross_attn"], cm.apply_norm(lp["ln2"], h, cfg), cfg, positions,
        kv_src=enc_out, cache=cross_cache, causal=False, use_rope=False,
    )
    h = h + c
    h = h + cm.apply_mlp(lp["mlp"], cm.apply_norm(lp["ln3"], h, cfg), cfg)
    return h, new_self, new_cross


def forward(params, tokens, cfg: ModelConfig, frames=None,
            remat: RematPolicy = RematPolicy.SAVE_DOTS):
    assert frames is not None, "whisper forward needs encoder frames"
    enc_out = encode(params, frames, cfg, remat)
    b, s = tokens.shape
    x = cm.embed(params["embed"], tokens) + params["dec_pos"][None, :s]
    positions = jnp.arange(s)[None, :]

    def body(h, lp):
        h, _, _ = _dec_block(lp, h, cfg, positions, enc_out)
        return h, None

    body = apply_remat(body, remat)
    x, _ = cm.scan(body, x, params["dec_layers"])
    x = cm.apply_norm(params["ln_f"], x, cfg)
    return cm.unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: ModelConfig,
            remat: RematPolicy = RematPolicy.SAVE_DOTS):
    logits, aux = forward(
        params, batch["tokens"], cfg, frames=batch["frames"], remat=remat
    )
    ce = cm.cross_entropy(logits, batch["labels"], cfg.vocab)
    return ce + aux, {"ce": ce, "aux": aux}


def init_cache(params, cfg: ModelConfig, batch: int, max_len: int, vis=None,
               frames=None, n_pages=None):
    """vis doubles as the encoder frames argument for API uniformity."""
    frames = frames if frames is not None else vis
    assert frames is not None, "whisper cache needs encoder frames"
    enc_out = encode(params, frames, cfg)
    hkv, dh = cfg.n_kv_heads, cfg.head_dim_
    dt = jnp.dtype(cfg.dtype)
    L = cfg.n_layers

    def cross_kv(lp):
        k = jnp.einsum("btd,dhk->bthk", enc_out, lp["cross_attn"]["wk"])
        v = jnp.einsum("btd,dhk->bthk", enc_out, lp["cross_attn"]["wv"])
        return {"k": k, "v": v}

    cross = jax.vmap(cross_kv)(params["dec_layers"])
    if cfg.cache_layout == "paged":
        self_kv, pages = cm.paged_kv_buffers((L,), batch, max_len, cfg,
                                             n_pages)
    else:
        shape = (L, batch, max_len, hkv, dh)
        self_kv = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
        pages = None
    cache = {
        "self": self_kv,
        "cross": cross,              # RESIDENT: reused by every decode step
        "lengths": jnp.zeros((batch,), jnp.int32),
    }
    if pages is not None:
        cache["pages"] = pages
    return cache


def prefill(params, cache, tokens, cfg: ModelConfig, seg_lens=None,
            all_logits=False):
    b, s = tokens.shape
    lengths = cache["lengths"]
    pages = cache.get("pages")
    positions = lengths[:, None] + jnp.arange(s)[None, :]     # (b, s)
    # Per-slot learned position rows (ragged cursors need a gather, not a
    # uniform dynamic slice); jnp.take clamps at the table edge.
    x = cm.embed(params["embed"], tokens) + jnp.take(
        params["dec_pos"], positions, axis=0
    )

    def body(h, inp):
        lp, sc, cc = inp
        self_cache = {"k": sc["k"], "v": sc["v"], "lengths": lengths}
        if pages is not None:
            self_cache["pages"] = pages
        h, new_self, _ = _dec_block(
            lp, h, cfg, positions, None, self_cache=self_cache, cross_cache=cc,
            seg_lens=seg_lens,
        )
        return h, {"k": new_self["k"], "v": new_self["v"]}

    x, new_self = cm.scan(
        body, x, (params["dec_layers"], cache["self"], cache["cross"])
    )
    x = cm.apply_norm(params["ln_f"], x, cfg)
    out = x if all_logits else cm.last_valid_slice(x, seg_lens)
    logits = cm.unembed(params["embed"], out, cfg)
    new_cache = {
        "self": new_self, "cross": cache["cross"],
        "lengths": lengths + (s if seg_lens is None else seg_lens),
    }
    if pages is not None:
        new_cache["pages"] = pages
    return logits, new_cache


def decode_step(params, cache, tokens, cfg: ModelConfig, seg_lens=None):
    return prefill(params, cache, tokens, cfg, seg_lens=seg_lens)


def build(cfg: ModelConfig) -> cm.ModelApply:
    return cm.ModelApply(
        config=cfg,
        init=functools.partial(init, cfg=cfg),
        forward=functools.partial(forward, cfg=cfg),
        loss=functools.partial(loss_fn, cfg=cfg),
        init_cache=functools.partial(init_cache, cfg=cfg),
        prefill=functools.partial(prefill, cfg=cfg),
        decode_step=functools.partial(decode_step, cfg=cfg),
        reset_slots=cm.reset_lengths,
    )
