"""Model zoo: pure-JAX definitions for the 10 assigned architectures."""
from repro.models.registry import ARCHS, build_model, get_config, runnable_cells  # noqa: F401
