"""Sharding rules: map every param/activation/cache tensor onto the mesh.

Mesh axes: ``(data, model)`` single-pod, ``(pod, data, model)`` multi-pod.
``pod`` is outer data parallelism (gradient all-reduce crosses pods).

Tensor-parallel policy (DESIGN.md §5):
* attention: shard heads over ``model`` when divisible (most archs);
  minicpm (36H) / whisper (12H) shard head_dim instead (contraction
  sharding).  K/V weights with few KV heads (GQA kv<16) are replicated —
  they are small — while the decode KV *cache* is sharded over ``model``
  along the sequence dim (distributed flash-decode; partial-softmax
  collectives), which is also how long_500k shards over ``data``.
* MLP: d_ff over ``model``; vocab (padded) over ``model``; MoE experts over
  ``model`` (EP); Mamba d_inner projections over ``model``.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _attn_dims(cfg: ModelConfig, n_heads: int, tp: int):
    """(head_spec, dh_spec) for a (…, heads, dh) QUERY/OUTPUT weight."""
    if n_heads % tp == 0:
        return "model", None
    if cfg.head_dim_ % tp == 0:
        return None, "model"
    return None, None


def _kv_dims(cfg: ModelConfig, tp: int):
    """(head_spec, dh_spec) for K/V weights — COUPLED to the Q rule.

    Mixing layouts (Q on heads, K/V on head_dim) makes the attention
    contraction unpartitionable and SPMD falls back to full
    rematerialization (activation-sized all-gathers per layer).  So:
    shard kv heads when divisible; otherwise follow Q exactly — replicated
    K/V weights when Q is heads-sharded (GQA K/V is small), dh-sharded when
    Q is dh-sharded."""
    qh, qd = _attn_dims(cfg, cfg.n_heads, tp)
    if cfg.n_kv_heads % tp == 0:
        return "model", None
    if qh == "model":
        return None, None        # replicate: q heads-sharded, kv tiny
    return None, qd              # dh-sharded with q, or fully replicated


def _add_fsdp(spec: P, shape: tuple[int, ...], mesh: Mesh,
              min_bytes: int = 1 << 20) -> P:
    """ZeRO-3/FSDP: additionally shard a big parameter's largest free,
    data-divisible dim over `data`.  GSPMD then all-gathers weights
    per-layer in the forward and reduce-scatters gradients — the standard
    way >16GB-per-TP-shard models fit v5e."""
    import math

    if math.prod(shape) * 2 < min_bytes:
        return spec
    full = tuple(spec) + (None,) * (len(shape) - len(spec))
    used = {a for part in full if part for a in
            ((part,) if isinstance(part, str) else part)}
    if "data" in used:
        return spec
    data = mesh.shape["data"]
    cands = [i for i, part in enumerate(full)
             if part is None and shape[i] % data == 0 and shape[i] >= data]
    if not cands:
        return spec
    i = max(cands, key=lambda j: shape[j])
    new = list(full)
    new[i] = "data"
    return P(*new)


def param_spec(path: str, shape: tuple[int, ...], cfg: ModelConfig,
               mesh: Mesh, fsdp: bool = False) -> P:
    """PartitionSpec for a parameter leaf (stacking dims auto-padded)."""
    tp = model_axis_size(mesh)
    d, f = cfg.d_model, cfg.d_ff
    dh = cfg.head_dim_

    def pad(rule: tuple) -> P:
        extra = len(shape) - len(rule)
        assert extra >= 0, (path, shape, rule)
        spec = P(*([None] * extra + list(rule)))
        return _add_fsdp(spec, shape, mesh) if fsdp else spec

    qh, qd = _attn_dims(cfg, cfg.n_heads, tp)
    kh, kd = _kv_dims(cfg, tp)
    if ("wq" in path or path.endswith("bq")) and shape[-1] == dh:
        return pad((None, qh, qd) if "wq" in path else (qh, qd))
    if any(k in path for k in ("wk", "wv", "bk", "bv")) and shape[-1] == dh:
        rule = (None, kh, kd) if ("wk" in path or "wv" in path) else (kh, kd)
        return pad(rule)
    if "wo" in path and shape[-1] == d:
        return pad((qh, qd, None))
    if "router" in path:
        return pad((None, "model" if cfg.n_experts % tp == 0 else None))
    if any(k in path for k in ("wg", "wu")):
        if len(shape) >= 3 and shape[-3] == cfg.n_experts and shape[-2] == d:
            # EP: experts over model (d_ff stays local per expert shard).
            return pad(("model" if cfg.n_experts % tp == 0 else None, None, None))
        return pad((None, "model" if f % tp == 0 else None))
    if "wd" in path:
        if len(shape) >= 3 and shape[-3] == cfg.n_experts and shape[-1] == d:
            return pad(("model" if cfg.n_experts % tp == 0 else None, None, None))
        return pad(("model" if f % tp == 0 else None, None))
    if "tok" in path or "unembed" in path:
        v = cfg.padded_vocab
        if "unembed" in path:
            return pad((None, "model" if v % tp == 0 else None))
        return pad(("model" if v % tp == 0 else None, None))
    if "in_proj" in path:
        return pad((None, "model" if shape[-1] % tp == 0 else None))
    if "out_proj" in path:
        return pad(("model" if shape[-2] % tp == 0 else None, None))
    if "conv_w" in path:
        return pad((None, "model" if shape[-1] % tp == 0 else None))
    if "conv_b" in path or "norm_w" in path:
        return pad(("model" if shape[-1] % tp == 0 else None,))
    if "vis_proj" in path:
        return pad((None, "model" if d % tp == 0 else None))
    # norms, biases, A_log, D, dt_bias, dec_pos: replicated.
    return pad(())


def _path_str(path) -> str:
    return "/".join(
        str(getattr(k, "key", getattr(k, "idx", k))) for k in path
    )


def params_shardings(params_shape: Any, cfg: ModelConfig, mesh: Mesh,
                     fsdp: bool = False):
    """Tree of NamedShardings matching a params (shape-)pytree."""
    def leaf(path, x):
        spec = param_spec(_path_str(path), tuple(x.shape), cfg, mesh, fsdp=fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def fsdp_needed(cfg: ModelConfig, mesh: Mesh, train: bool,
                hbm_bytes: int = 16 * 1024**3) -> bool:
    """Napkin check: do params (+ fp32 optimizer state for training) fit a
    single TP shard without data-axis sharding?

    Serving uses a looser threshold: FSDP all-gathers per step are poison
    for decode latency and GSPMD may hoist them into a fully-replicated
    param buffer — keep weights TP-resident unless they truly can't fit."""
    n = cfg.param_count()
    tp = model_axis_size(mesh)
    per_chip = n * 2 / tp            # bf16 params
    if train:
        per_chip += n * 12 / tp      # fp32 grads + mu + nu
        return per_chip > 0.35 * hbm_bytes
    return per_chip > 0.8 * hbm_bytes


def _batch_rule(mesh: Mesh, global_batch: int):
    ba = batch_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in ba]))
    if global_batch % total == 0:
        return ba
    if global_batch % mesh.shape["data"] == 0:
        return ("data",)
    return None


def batch_spec(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> dict[str, P]:
    """Specs for a training/serving input batch."""
    b = _batch_rule(mesh, global_batch)
    return {
        "tokens": P(b, None),
        "labels": P(b, None),
        "vis": P(b, None, None),
        "frames": P(b, None, None),
    }


def cache_spec(cfg: ModelConfig, mesh: Mesh, global_batch: int,
               long_context: bool = False) -> dict[str, Any]:
    """Specs for the serving cache pytree (per leaf name).

    Decode KV caches shard the sequence dim: over ``model`` normally, and
    over ``data`` too for long_context batch-1 (sequence parallelism — the
    distributed flash-decode schedule).
    """
    b = _batch_rule(mesh, global_batch)
    seq_axis = ("data",) if (long_context and b is None) else ("model",)
    tp = model_axis_size(mesh)

    kh, _ = _kv_dims(cfg, tp)

    def leaf_spec(path, x):
        p = _path_str(path)
        nd = x.ndim
        if p.endswith("/k") or p.endswith("/v") or "/k/" in p or "/v/" in p:
            if "cross" in p:
                # Fixed-source (enc/vision) KV: small, reused every step —
                # the RESIDENT operand; shard kv heads if divisible.
                return P(*([None] * (nd - 4) + [b, None, kh, None]))
            # Self-attention cache (..., batch, S, hkv, dh): shard the
            # sequence dim — distributed flash-decode.  Long-context
            # batch-1 shards seq over `data` AND kv heads over `model`.
            head_axis = kh if long_context else None
            return P(*([None] * (nd - 4) + [b, seq_axis, head_axis, None]))
        if "ssm" in p:
            # (L, batch, h, ds, dh): shard heads over model.
            rule = [None] * (nd - 4) + [b, "model" if cfg.ssm_heads % tp == 0 else None, None, None]
            return P(*rule)
        if "conv" in p:
            rule = [None] * (nd - 3) + [b, None,
                                        "model" if (cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state) % tp == 0 else None]
            return P(*rule)
        if "vis" in p:
            return P(*([None] * (nd - 3) + [b, None, None]))
        return P(*([None] * nd))

    return leaf_spec
