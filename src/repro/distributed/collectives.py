"""Distributed-optimization collectives: rinsed (bucketed) reduction and
int8-compressed gradient all-reduce with error feedback.

These are shard_map-level building blocks (tested on a host mesh) that a
1000-node deployment would enable via TrainConfig:

* ``bucketed_all_reduce`` — instead of one collective per tensor (small
  scattered flushes) or one monolithic end-of-step flush, gradients are
  grouped into contiguous size-bounded buckets by the rinse scheduler
  (`repro.core.rinse.bucket_flush_schedule`) and reduced bucket-by-bucket —
  the distributed twin of the paper's row-locality-aware rinsing, and the
  unit at which reduction overlaps the backward pass.
* ``compressed_all_reduce`` — int8-quantized all-reduce with per-tensor
  scales and ERROR FEEDBACK (the quantization residual is carried into the
  next step), cutting gradient collective bytes 4x vs fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rinse import bucket_flush_schedule


def bucketed_all_reduce(grads_flat: list[jnp.ndarray], axis_name: str,
                        bucket_bytes: int = 32 * 1024 * 1024):
    """psum a list of tensors in rinse-scheduled contiguous buckets."""
    sizes = [int(np.prod(g.shape)) * g.dtype.itemsize for g in grads_flat]
    buckets = bucket_flush_schedule(sizes, bucket_bytes)
    out: list = [None] * len(grads_flat)
    for bucket in buckets:
        flat = jnp.concatenate(
            [grads_flat[i].reshape(-1) for i in bucket]
        )
        red = jax.lax.psum(flat, axis_name)
        off = 0
        for i in bucket:
            n = int(np.prod(grads_flat[i].shape))
            out[i] = red[off:off + n].reshape(grads_flat[i].shape)
            off += n
    return out


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_all_reduce(
    g: jnp.ndarray, error: jnp.ndarray, axis_name: str
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """int8 all-reduce with error feedback.

    Returns (reduced_mean, new_error).  A shared scale is agreed via a
    scalar pmax (negligible traffic), so the int32 psum dequantizes
    exactly; the local quantization residual is carried by the caller into
    the next step's gradient (error feedback keeps compression unbiased
    over time)."""
    g_fb = (g + error).astype(jnp.float32)
    scale = jax.lax.pmax(jnp.max(jnp.abs(g_fb)), axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g_fb / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_error = g_fb - deq
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    red = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
    return red * scale / n, new_error
