"""Sequence-parallel flash-decode across chips (shard_map).

The long_500k cell: batch=1, KV cache of 524288 tokens — no batch axis to
shard.  The cache's sequence dim is sharded over the ``data`` axis; every
chip computes flash-decode over its local KV shard and the partial
(acc, max, sum) triples merge with the same log-sum-exp combine the
split-KV kernel uses on-chip.  This makes decode bandwidth scale with the
number of chips — the STREAM policy executed fleet-wide.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels.decode_attention import ref as dec_ref


def _local_partials(q, k, v, lengths, shard_start, scale):
    """One shard's flash-decode partials over its local KV slice."""
    b, hq, d = q.shape
    s_local = k.shape[2]
    group = hq // k.shape[1]
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum(
        "bhd,bhsd->bhs", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) * scale
    pos = shard_start + jnp.arange(s_local)[None, None, :]
    mask = pos < lengths[:, None, None]
    logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1)
    p = jnp.where(mask, jnp.exp(logits - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhs,bhsd->bhd", p, vx.astype(jnp.float32))
    return acc, m, l


def sp_decode_attention(
    q: jnp.ndarray,        # (b, hq, d) replicated
    k: jnp.ndarray,        # (b, hkv, S, d) sharded over seq on `axis`
    v: jnp.ndarray,
    lengths: jnp.ndarray,  # (b,)
    mesh: Mesh,
    axis: str = "data",
    scale: float | None = None,
) -> jnp.ndarray:
    """Distributed flash-decode: partial softmax per shard + psum combine."""
    d = q.shape[-1]
    scale = float(scale if scale is not None else d ** -0.5)
    n_shards = mesh.shape[axis]
    s_local = k.shape[2] // n_shards

    def body(q_, k_, v_, len_):
        idx = jax.lax.axis_index(axis)
        acc, m, l = _local_partials(
            q_, k_, v_, len_, idx * s_local, scale
        )
        # Log-sum-exp combine across shards:
        m_glob = jax.lax.pmax(m, axis)
        w = jnp.exp(m - m_glob)
        num = jax.lax.psum(acc * w[..., None], axis)
        den = jax.lax.psum(l * w, axis)
        return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q_.dtype)

    spec_kv = P(None, None, axis, None)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), spec_kv, spec_kv, P()),
        out_specs=P(),
    )
    return fn(q, k, v, lengths)


def reference(q, k, v, lengths, scale=None):
    return dec_ref.decode_attention(q, k, v, lengths, scale=scale)
