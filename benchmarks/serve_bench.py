"""Serve-path benchmark: device-resident chunked engine vs per-token loop.

The seed `ServeEngine` paid one jit dispatch plus one device→host sync per
generated token.  The chunked engine decodes ``chunk_size`` tokens per
dispatch with on-device sampling and syncs once per chunk.  Both paths run
the same smoke model on the same request mix, warm (compile excluded), so
the ratio isolates the host-overhead cut — the throughput-sensitive decode
class the paper's Uncached policy targets.

Emitted metrics (also merged into ``benchmarks.run --json`` output):

* ``serve_tok_s``          — chunked engine, total tokens / wall
* ``serve_ttft_s``         — mean submit→first-token latency, warm
* ``host_syncs_per_token`` — total syncs / total tokens (chunked)
* ``seed_tok_s``           — per-token dispatch loop, total tokens / wall
* ``serve_speedup``        — serve_tok_s / seed_tok_s
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model, get_config
from repro.serve.engine import Request, ServeEngine, greedy_sample

SERVE_ARCH = "qwen2.5-32b"
SLOTS = 4
MAX_LEN = 64
CHUNK = 16
N_REQUESTS = 8
# 1 prefill token + 32 decode tokens = exactly two full chunks per slot.
MAX_NEW = 33


def _requests(cfg, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(4, 10, size=N_REQUESTS)
    return [
        Request(prompt=rng.integers(0, cfg.vocab, size=int(n)).astype(np.int32),
                max_new_tokens=MAX_NEW)
        for n in lens
    ]


def _seed_loop(cfg, model, params, requests):
    """The seed engine's schedule: static admission waves, one jitted
    dispatch + one host sync per generated token.  (Prompts are right-padded
    with seg_lens so outputs match the chunked engine bit-for-bit; the
    dispatch/sync pattern is the seed's.)"""
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    total = 0
    syncs = 0
    pending = list(requests)
    while pending:
        wave, pending = pending[:SLOTS], pending[SLOTS:]
        cache = model.init_cache(params, batch=SLOTS, max_len=MAX_LEN)
        pad = max(len(r.prompt) for r in wave)
        toks = np.zeros((SLOTS, pad), np.int32)
        seg = np.zeros((SLOTS,), np.int32)
        for i, r in enumerate(wave):
            toks[i, :len(r.prompt)] = r.prompt
            seg[i] = len(r.prompt)
        logits, cache = prefill(
            params, cache, jnp.asarray(toks), seg_lens=jnp.asarray(seg)
        )
        nxt = np.asarray(greedy_sample(logits))      # host sync
        syncs += 1
        for i, r in enumerate(wave):
            r.generated.append(int(nxt[i]))
            total += 1
        live = {i: r for i, r in enumerate(wave)
                if len(r.generated) < r.max_new_tokens}
        while live:
            step = np.zeros((SLOTS, 1), np.int32)
            seg1 = np.zeros((SLOTS,), np.int32)
            for i, r in live.items():
                step[i, 0] = r.generated[-1]
                seg1[i] = 1
            logits, cache = decode(
                params, cache, jnp.asarray(step), seg_lens=jnp.asarray(seg1)
            )
            nxt = np.asarray(greedy_sample(logits))  # host sync per token
            syncs += 1
            done = []
            for i, r in live.items():
                r.generated.append(int(nxt[i]))
                total += 1
                if len(r.generated) >= r.max_new_tokens:
                    done.append(i)
            for i in done:
                del live[i]
    return total, syncs


def serve_rows(chunk_size: int = CHUNK, reps: int = 3):
    """Warm both paths, time both best-of-``reps``, return (rows, summary).

    The timed windows are tens of milliseconds on the smoke model, so a
    single rep is noise-prone when other benchmarks share the process —
    best-of mirrors the sweep benchmark's noise guard."""
    cfg = get_config(SERVE_ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # -- chunked engine: warm run compiles, later runs are timed -----------
    eng = ServeEngine(cfg, params, batch_slots=SLOTS, max_len=MAX_LEN,
                      chunk_size=chunk_size)
    eng.run(_requests(cfg, seed=0))
    serve_wall = None
    for _ in range(max(1, reps)):
        base = dict(eng.stats)
        reqs = _requests(cfg, seed=1)
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        if serve_wall is None or dt < serve_wall:
            serve_wall = dt
            ttft = float(np.mean(
                [r.ttft_s for r in reqs if r.ttft_s is not None]
            ))
        delta = {k: eng.stats[k] - base[k] for k in eng.stats}
    serve_tokens = delta["decode_tokens"] + delta["prefill_tokens"]
    serve_tok_s = serve_tokens / serve_wall
    syncs_per_tok = delta["host_syncs"] / serve_tokens

    # -- seed-style per-token loop: warm, then timed best-of ---------------
    _seed_loop(cfg, model, params, _requests(cfg, seed=0))
    seed_wall = None
    for _ in range(max(1, reps)):
        seed_reqs = _requests(cfg, seed=1)
        t0 = time.perf_counter()
        seed_tokens, seed_syncs = _seed_loop(cfg, model, params, seed_reqs)
        dt = time.perf_counter() - t0
        seed_wall = dt if seed_wall is None else min(seed_wall, dt)
    seed_tok_s = seed_tokens / seed_wall

    # Both schedules must emit identical tokens (greedy, same weights).
    for a, b in zip(reqs, seed_reqs):
        assert a.generated == b.generated, "chunked != per-token output"

    summary = {
        "serve_arch": SERVE_ARCH,
        "serve_chunk_size": chunk_size,
        "serve_tok_s": serve_tok_s,
        "serve_ttft_s": ttft,
        "host_syncs_per_token": syncs_per_tok,
        "seed_tok_s": seed_tok_s,
        "seed_syncs_per_token": seed_syncs / seed_tokens,
        "serve_speedup": serve_tok_s / seed_tok_s,
    }
    rows = [
        {"name": "serve/chunked", "us_per_call": serve_wall * 1e6 / serve_tokens,
         "tok_s": serve_tok_s, "ttft_s": ttft,
         "host_syncs_per_token": syncs_per_tok},
        {"name": "serve/seed_per_token",
         "us_per_call": seed_wall * 1e6 / seed_tokens,
         "tok_s": seed_tok_s,
         "host_syncs_per_token": seed_syncs / seed_tokens},
    ]
    return rows, summary


if __name__ == "__main__":
    import json

    rows, summary = serve_rows()
    for r in rows:
        print(r)
    print(json.dumps(summary, indent=1))
