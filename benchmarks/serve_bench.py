"""Serve-path benchmark: device-resident chunked engine vs per-token loop.

The seed `ServeEngine` paid one jit dispatch plus one device→host sync per
generated token.  The chunked engine decodes ``chunk_size`` tokens per
dispatch with on-device sampling and syncs once per chunk.  Both paths run
the same smoke model on the same request mix, warm (compile excluded), so
the ratio isolates the host-overhead cut — the throughput-sensitive decode
class the paper's Uncached policy targets.

Emitted metrics (also merged into ``benchmarks.run --json`` output):

* ``serve_tok_s``          — chunked engine, total tokens / wall
* ``serve_ttft_s``         — mean admission→first-token latency (prefill
                             compute), warm
* ``serve_queue_wait_s``   — mean submit→admission latency (queueing only)
* ``host_syncs_per_token`` — total syncs / total tokens (chunked)
* ``seed_tok_s``           — per-token dispatch loop, total tokens / wall
* ``serve_speedup``        — serve_tok_s / seed_tok_s
* ``serve_families``       — per-arch breadth rows (mamba2/zamba2/whisper
                             cache families) with paged-vs-contiguous
                             bit-identity asserted where a KV cache exists,
                             plus paged/contiguous throughput ratio
* ``serve_spec``           — speculative decode on the repeat-heavy smoke
                             workload (``spec_rows``): acceptance rate,
                             tokens per verify round, and spec/non-spec
                             throughput ratio, with spec-vs-plain
                             bit-identity asserted (greedy AND seeded
                             temperature sampling)
* ``serve_prefix``         — prefix sharing on the many-slots-one-system-
                             prompt workload (``prefix_rows``): effective-
                             capacity multiple (worst-case pages vs pages
                             actually held, asserted >= 2x), suffix-only
                             TTFT vs full-prefill TTFT, with shared-vs-
                             unshared bit-identity asserted
* ``serve_chaos``          — lifecycle robustness (``chaos_rows``): an
                             undersized pool forcing real preemptions and
                             a seeded fault-injected run (alloc refusals +
                             forced preemptions), both asserted
                             bit-identical to the fault-free run with zero
                             leaked pages and engine invariants held
* ``serve_recovery``       — crash recovery (``recovery_rows``): every
                             cache family crashes mid-flight (journal +
                             injected ``ChaosCrash``, snapshot at wave 1,
                             late submits after the snapshot) and a FRESH
                             engine restores + finishes; shared-prefix
                             dense adds a {sharing on} leg and a
                             corruption leg (seeded device bit-flips
                             detected, quarantined, recompute-healed).
                             Every leg asserts bit-identity to the
                             uninterrupted run and zero leaked pages;
                             ``--recovery-report`` writes the rows as the
                             CI artifact
* ``serve_adaptive``       — adaptive cache policy (``adaptive_rows``):
                             the mixed re-arrival/churn trace run under
                             a static engine, pinned retain-always,
                             pinned bypass, and the free-running
                             adaptive controller — all asserted
                             bit-identical (adaptation is placement-
                             only), with adaptive <= the best static
                             stance on prefill work and the warm
                             re-arrival TTFT cut >= 1.2x over static
                             refcount-zero freeing
* ``serve_decode_kernel``  — paged decode-attention kernel identity
                             matrix (``decode_kernel_rows``):
                             ``pallas_paged`` (page table dereferenced
                             inside the kernel) vs ``pallas_gather``
                             (gather + dense split-KV kernel, the
                             reference semantics) asserted bit-identical
                             across {qwen, zamba2} x {prefix sharing
                             on/off} x {chaos off/on}, zero leaked pages
* ``serve_decode_context`` — tok/s vs resident-context length
                             (``decode_context_rows``): xla vs paged
                             kernel wall throughput plus the v5e
                             roofline-modeled advantage, asserted to
                             GROW with context (the gather copy is the
                             cost the paged kernel deletes)

``python -m benchmarks.serve_bench --identity-only`` runs only the
bit-identity checks (the CI gate) — paged vs contiguous, speculative vs
plain (greedy + seeded sampling) with the acceptance-rate floor,
shared-prefix vs unshared with the >= 2x effective-capacity floor, the
chaos leg (preemption + injected faults must not change a single token
and must leak zero pages), the adaptive leg (static/pinned/adaptive
engines bit-identical, adaptive <= best static on prefill work), and the
decode-kernel legs (paged kernel bit-identical to the gather path across
families x sharing x chaos; modeled paged advantage grows with resident
context) — and exits nonzero on any violation.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model, get_config
from repro.serve.engine import Request, ServeEngine, greedy_sample

SERVE_ARCH = "qwen2.5-32b"
SLOTS = 4
MAX_LEN = 64
CHUNK = 16
N_REQUESTS = 8
# 1 prefill token + 32 decode tokens = exactly two full chunks per slot.
MAX_NEW = 33
# Mixed long/short workload for the paged-pool leg: alternating budgets so
# short requests return pages while long ones keep decoding.
MAX_NEW_SHORT = 9
PAGED_PAGE = 16
# The paged leg provisions max_len for the worst tolerated request (128)
# but backs it with a pool sized to the workload's worst-case concurrent
# footprint: every request needs <= 41 positions -> 3 pages, 4 slots -> 12
# pages x 16 = 192 pooled positions vs 4 x 128 = 512 contiguous — 2.67x
# effective capacity with no admission gating, which is the paged layout's
# point: one long request's worst case no longer dictates every slot's
# HBM reservation.
PAGED_MAX_LEN = 128
PAGED_POOL = 12
# The d=64/L=2 smoke model is a worst case for layout overhead (the page
# gather is comparable to the whole layer's compute); the steady-state
# throughput comparison runs at a scale where per-layer compute resembles
# serving reality relative to KV traffic.
PAGED_BENCH_DIMS = dict(n_layers=4, d_model=256, d_ff=512, n_heads=8,
                        n_kv_heads=4, head_dim=32)

# Breadth sweep: one arch per serving cache family beyond the dense smoke
# config.  has_kv gates the paged-vs-contiguous identity check (mamba2's
# decode state is O(1) — nothing to page).
FAMILY_ARCHS = (
    ("qwen2.5-32b", True),       # dense GQA KV
    ("mamba2-1.3b", False),      # pure SSM: conv window + SSD state
    ("zamba2-2.7b", True),       # hybrid: shared-attention KV + SSM
    ("whisper-small", True),     # enc-dec: self KV + resident cross KV
)
FAMILY_SLOTS = 2
FAMILY_MAX_LEN = 32
FAMILY_PAGE = 8
# Pooled page budget: 5 pages x 8 tokens = 40 positions < slots x max_len
# = 64 — the oversubscription the paged layout exists for.
FAMILY_POOL = 5


def _requests(cfg, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(4, 10, size=N_REQUESTS)
    return [
        Request(prompt=rng.integers(0, cfg.vocab, size=int(n)).astype(np.int32),
                max_new_tokens=MAX_NEW)
        for n in lens
    ]


def _seed_loop(cfg, model, params, requests):
    """The seed engine's schedule: static admission waves, one jitted
    dispatch + one host sync per generated token.  (Prompts are right-padded
    with seg_lens so outputs match the chunked engine bit-for-bit; the
    dispatch/sync pattern is the seed's.)"""
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    total = 0
    syncs = 0
    pending = list(requests)
    while pending:
        wave, pending = pending[:SLOTS], pending[SLOTS:]
        cache = model.init_cache(params, batch=SLOTS, max_len=MAX_LEN)
        pad = max(len(r.prompt) for r in wave)
        toks = np.zeros((SLOTS, pad), np.int32)
        seg = np.zeros((SLOTS,), np.int32)
        for i, r in enumerate(wave):
            toks[i, :len(r.prompt)] = r.prompt
            seg[i] = len(r.prompt)
        logits, cache = prefill(
            params, cache, jnp.asarray(toks), seg_lens=jnp.asarray(seg)
        )
        nxt = np.asarray(greedy_sample(logits))      # host sync  # repro-lint: disable=R001 -- seed reference path: per-wave sync IS the measured baseline
        syncs += 1
        for i, r in enumerate(wave):
            r.generated.append(int(nxt[i]))
            total += 1
        live = {i: r for i, r in enumerate(wave)
                if len(r.generated) < r.max_new_tokens}
        while live:
            step = np.zeros((SLOTS, 1), np.int32)
            seg1 = np.zeros((SLOTS,), np.int32)
            for i, r in live.items():
                step[i, 0] = r.generated[-1]
                seg1[i] = 1
            logits, cache = decode(
                params, cache, jnp.asarray(step), seg_lens=jnp.asarray(seg1)
            )
            nxt = np.asarray(greedy_sample(logits))  # host sync per token  # repro-lint: disable=R001 -- seed reference path: per-token sync IS the measured baseline
            syncs += 1
            done = []
            for i, r in live.items():
                r.generated.append(int(nxt[i]))
                total += 1
                if len(r.generated) >= r.max_new_tokens:
                    done.append(i)
            for i in done:
                del live[i]
    return total, syncs


def serve_rows(chunk_size: int = CHUNK, reps: int = 3):
    """Warm both paths, time both best-of-``reps``, return (rows, summary).

    The timed windows are tens of milliseconds on the smoke model, so a
    single rep is noise-prone when other benchmarks share the process —
    best-of mirrors the sweep benchmark's noise guard."""
    cfg = get_config(SERVE_ARCH, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # -- chunked engine: warm run compiles, later runs are timed -----------
    eng = ServeEngine(cfg, params, batch_slots=SLOTS, max_len=MAX_LEN,
                      chunk_size=chunk_size)
    eng.run(_requests(cfg, seed=0))
    serve_wall = None
    for _ in range(max(1, reps)):
        base = dict(eng.stats)
        reqs = _requests(cfg, seed=1)
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        if serve_wall is None or dt < serve_wall:
            serve_wall = dt
            ttft = float(np.mean(
                [r.ttft_s for r in reqs if r.ttft_s is not None]
            ))
            queue_wait = float(np.mean(
                [r.queue_wait_s for r in reqs if r.queue_wait_s is not None]
            ))
        delta = {k: eng.stats[k] - base[k] for k in eng.stats}
    serve_tokens = delta["decode_tokens"] + delta["prefill_tokens"]
    serve_tok_s = serve_tokens / serve_wall
    syncs_per_tok = delta["host_syncs"] / serve_tokens

    # -- seed-style per-token loop: warm, then timed best-of ---------------
    _seed_loop(cfg, model, params, _requests(cfg, seed=0))
    seed_wall = None
    for _ in range(max(1, reps)):
        seed_reqs = _requests(cfg, seed=1)
        t0 = time.perf_counter()
        seed_tokens, seed_syncs = _seed_loop(cfg, model, params, seed_reqs)
        dt = time.perf_counter() - t0
        seed_wall = dt if seed_wall is None else min(seed_wall, dt)
    seed_tok_s = seed_tokens / seed_wall

    # Both schedules must emit identical tokens (greedy, same weights).
    for a, b in zip(reqs, seed_reqs):
        assert a.generated == b.generated, "chunked != per-token output"

    summary = {
        "serve_arch": SERVE_ARCH,
        "serve_chunk_size": chunk_size,
        "serve_tok_s": serve_tok_s,
        "serve_ttft_s": ttft,
        "serve_queue_wait_s": queue_wait,
        "host_syncs_per_token": syncs_per_tok,
        "seed_tok_s": seed_tok_s,
        "seed_syncs_per_token": seed_syncs / seed_tokens,
        "serve_speedup": serve_tok_s / seed_tok_s,
    }
    rows = [
        {"name": "serve/chunked", "us_per_call": serve_wall * 1e6 / serve_tokens,
         "tok_s": serve_tok_s, "ttft_s": ttft, "queue_wait_s": queue_wait,
         "host_syncs_per_token": syncs_per_tok},
        {"name": "serve/seed_per_token",
         "us_per_call": seed_wall * 1e6 / seed_tokens,
         "tok_s": seed_tok_s,
         "host_syncs_per_token": seed_syncs / seed_tokens},
    ]
    return rows, summary


# ---------------------------------------------------------------------------
# Paged pool under oversubscription (the acceptance workload)
# ---------------------------------------------------------------------------

def _mixed_requests(cfg, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(4, 10, size=N_REQUESTS)
    return [
        Request(prompt=rng.integers(0, cfg.vocab, size=int(n)).astype(np.int32),
                max_new_tokens=MAX_NEW if i % 2 == 0 else MAX_NEW_SHORT)
        for i, n in enumerate(lens)
    ]


def paged_rows(chunk_size: int = CHUNK, reps: int = 3, warm: bool = True):
    """Mixed long/short workload through a page pool at 2.67x effective
    capacity (192 pooled positions backing 4 slots x max_len 128).
    Asserts bit-identity and reports steady-state paged/contiguous
    throughput — the paged layout must stay within ~10% while pooling
    HBM across slots.  ``warm=False`` (the CI identity gate) skips the
    compile-absorbing warm-up wave."""
    cfg = dataclasses.replace(
        get_config(SERVE_ARCH, smoke=True), **PAGED_BENCH_DIMS
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok_s, outs = {}, {}
    for layout in ("contiguous", "paged"):
        if layout == "paged":
            c = dataclasses.replace(cfg, cache_layout="paged",
                                    kv_page_size=PAGED_PAGE)
            kw = {"n_pages": PAGED_POOL}
        else:
            c, kw = cfg, {}
        eng = ServeEngine(c, params, batch_slots=SLOTS,
                          max_len=PAGED_MAX_LEN, chunk_size=chunk_size, **kw)
        if warm:
            eng.run(_mixed_requests(cfg, seed=0))     # warm/compile
        best = 0.0
        for _ in range(max(1, reps)):
            reqs = _mixed_requests(cfg, seed=1)
            t0 = time.perf_counter()
            eng.run(reqs)
            wall = time.perf_counter() - t0
            best = max(best, sum(len(r.generated) for r in reqs) / wall)
        tok_s[layout] = best
        outs[layout] = [r.generated for r in reqs]
    assert outs["paged"] == outs["contiguous"], (
        "paged != contiguous on the mixed long/short workload"
    )
    ratio = tok_s["paged"] / tok_s["contiguous"]
    eff = (SLOTS * PAGED_MAX_LEN) / (PAGED_POOL * PAGED_PAGE)
    row = {
        "name": "serve/paged_mixed",
        "us_per_call": 1e6 / tok_s["paged"],
        "tok_s": tok_s["paged"],
        "contiguous_tok_s": tok_s["contiguous"],
        "paged_over_contiguous": ratio,
        "effective_capacity_x": eff,
        "bit_identical": True,
    }
    summary = {
        "serve_paged_tok_s": tok_s["paged"],
        "serve_paged_over_contiguous": ratio,
        "serve_paged_effective_capacity_x": eff,
    }
    return [row], summary


# ---------------------------------------------------------------------------
# Prefix sharing: many slots, one system prompt (DESIGN.md §5.4)
# ---------------------------------------------------------------------------

PREFIX_SLOTS = 8
PREFIX_PAGE = 16
PREFIX_SYS = 3 * PREFIX_PAGE   # 48-token system prompt = 3 full shared pages
PREFIX_NEW = 16
PREFIX_MAX_LEN = 80
# Worst case per request: 48 sys + 8 tail + 16 new - 1 = 71 positions -> 5
# pages; 8 unshared requests demand 40 pages.  Shared, the wave needs
# 5 (owner) + 7 x 2 (suffix-only) = 19 — so a 24-page pool admits all 8 at
# once where the unshared engine serializes at 4.
PREFIX_POOL = 24
# CI floor: worst-case page demand over pages actually held must stay >= 2x
# (measured 2.1x on this workload; deterministic page accounting, not wall
# time, so a drop signals an allocator/trie regression).
PREFIX_CAPACITY_FLOOR = 2.0


def _prefix_requests(cfg, seed=0):
    """One shared system prompt, per-request user tails: the prefix-hit
    serving shape (returns the system prompt too, for the TTFT primer)."""
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(0, cfg.vocab, size=PREFIX_SYS).astype(np.int32)
    reqs = [
        Request(prompt=np.concatenate(
            [sys_p, rng.integers(0, cfg.vocab, size=int(n)).astype(np.int32)]),
            max_new_tokens=PREFIX_NEW)
        for n in rng.integers(4, 9, size=PREFIX_SLOTS)
    ]
    return sys_p, reqs


def prefix_rows(reps: int = 3, identity_only: bool = False):
    """Shared-prefix serving vs unshared paged on the many-slots-one-
    system-prompt workload.

    Always asserts (the CI ``shared_prefix`` gate): bit-identical outputs,
    the >= ``PREFIX_CAPACITY_FLOOR`` effective-capacity multiple (unshared
    worst-case page demand over pages the shared engine actually held),
    and single-wave admission under a pool the unshared engine serializes
    on.  In full mode additionally measures suffix-only TTFT: a primer
    request keeps the system prompt resident, then a fresh wave admits
    against it — shared admissions prefill only their few-token tails."""
    base = get_config(SERVE_ARCH, smoke=True)
    if not identity_only:
        base = dataclasses.replace(base, **PAGED_BENCH_DIMS)
    paged_cfg = dataclasses.replace(
        base, cache_layout="paged", kv_page_size=PREFIX_PAGE
    )
    shared_cfg = dataclasses.replace(paged_cfg, prefix_sharing=True)
    params = build_model(base).init(jax.random.PRNGKey(0))

    def engine(c):
        return ServeEngine(c, params, batch_slots=PREFIX_SLOTS,
                           max_len=PREFIX_MAX_LEN, chunk_size=8,
                           n_pages=PREFIX_POOL)

    # -- identity + effective capacity (always run; the CI gate) -----------
    engines, outs = {}, {}
    for name, c in (("unshared", paged_cfg), ("shared", shared_cfg)):
        eng = engine(c)
        _, reqs = _prefix_requests(base, seed=1)
        eng.run(reqs)
        engines[name], outs[name] = eng, reqs
    mismatch = [
        (a.generated, b.generated)
        for a, b in zip(outs["unshared"], outs["shared"])
        if a.generated != b.generated
    ]
    assert not mismatch, (
        f"shared-prefix != unshared on {len(mismatch)} request(s): "
        f"{mismatch[0]}"
    )
    eng_s, eng_u = engines["shared"], engines["unshared"]
    demand = sum(eng_u._pages_needed(r) for r in outs["unshared"])
    # Snapshot the identity-phase peak NOW: the timed phase below admits a
    # primer on the same engine and raises the cumulative peak, and the
    # reported ratio must stay consistent with the pages it was computed
    # from.
    peak_shared = eng_s.stats["peak_pages_held"]
    capacity_x = demand / peak_shared
    assert capacity_x >= PREFIX_CAPACITY_FLOOR, (
        f"effective capacity {capacity_x:.2f}x dropped below the "
        f"{PREFIX_CAPACITY_FLOOR}x floor (demand {demand} pages, peak held "
        f"{peak_shared})"
    )
    assert eng_s.stats["admission_waves"] == 1, "shared wave split"
    assert eng_u.stats["admission_waves"] >= 2, (
        "unshared pool unexpectedly fit the whole wave — workload no "
        "longer exercises sharing"
    )
    assert eng_s.stats["prefix_hits"] == PREFIX_SLOTS - 1
    if identity_only:
        print(f"shared_prefix: bit-identical, effective capacity "
              f"{capacity_x:.2f}x >= floor {PREFIX_CAPACITY_FLOOR}x, "
              f"{PREFIX_SLOTS} slots in one admission wave")
        return [], {}

    # -- timed: suffix-only TTFT against a resident system prompt ----------
    # A primer keeps the system prompt's pages referenced while the wave
    # admits, so every shared admission prefills only its tail (the
    # pad bucket collapses from 64 to 8 wide).
    ttft, tok_s = {}, {}
    for name, eng in engines.items():
        best_ttft = best_tok = None
        # Rep -1 is an untimed warm-up: the primer-then-wave schedule
        # compiles the suffix-width prefill signature (and, shared, the
        # suffix x full-prompt history pad combo) that the identity run
        # above never exercised.
        for rep in range(-1, max(1, reps)):
            sys_p, reqs = _prefix_requests(base, seed=1)
            rng = np.random.default_rng(2 + rep)
            primer = Request(prompt=np.concatenate(
                [sys_p, rng.integers(0, base.vocab, size=4).astype(np.int32)]),
                max_new_tokens=24)
            eng.submit([primer])
            eng._admit_wave()
            eng.submit(reqs)
            t0 = time.perf_counter()
            eng.drain()
            wall = time.perf_counter() - t0
            if rep < 0:
                continue
            m = float(np.mean([r.ttft_s for r in reqs]))
            n_tok = sum(len(r.generated) for r in reqs + [primer])
            best_ttft = m if best_ttft is None else min(best_ttft, m)
            best_tok = (n_tok / wall if best_tok is None
                        else max(best_tok, n_tok / wall))
        ttft[name], tok_s[name] = best_ttft, best_tok
    row = {
        "name": "serve/prefix_shared_sysprompt",
        "us_per_call": 1e6 / tok_s["shared"],
        "tok_s": tok_s["shared"],
        "unshared_tok_s": tok_s["unshared"],
        "ttft_s": ttft["shared"],
        "unshared_ttft_s": ttft["unshared"],
        "ttft_cut_x": ttft["unshared"] / ttft["shared"],
        "effective_capacity_x": capacity_x,
        "peak_pages_shared": peak_shared,
        "worst_case_pages": demand,
        "prefix_hit_rate": eng_s.serve_stats()["prefix_hit_rate"],
        "bit_identical": True,
    }
    summary = {"serve_prefix": {k: v for k, v in row.items() if k != "name"}}
    return [row], summary


# ---------------------------------------------------------------------------
# Speculative decode on the repeat-heavy smoke workload (DESIGN.md §5.3)
# ---------------------------------------------------------------------------

# Same serving-scale dims as the paged leg (per-eval compute must dominate
# dispatch overhead for the verify-width tradeoff to resemble serving
# reality) with a small vocab so greedy streams reach their attractor
# cycles inside the probe budget.
SPEC_BENCH_DIMS = dict(PAGED_BENCH_DIMS, vocab=64)
SPEC_K = 4             # drafts per verify round
SPEC_NGRAM = 3
SPEC_PROBES = 8        # candidate streams probed for repetitive tails
SPEC_CUT = 60          # resume this deep inside each probed stream
SPEC_NEW = 33          # tokens decoded per workload request
SPEC_TOP = 3           # most-repetitive probes kept (cycled to N_REQUESTS)
# CI floor for n-gram acceptance on this workload (measured 1.00; the
# workload is fully deterministic, so a drop signals a proposer/verify
# regression, not noise).
SPEC_ACCEPT_FLOOR = 0.75


def _ngram_oracle(hist: list, start: int, g: int, k: int) -> float:
    """Simulated draft acceptance of ``hist[start:]`` given its prefix:
    the host-side twin of `serve.draft.ngram_propose` + greedy verify,
    used to rank probed streams by repeat-heaviness."""
    acc = tot = 0
    i = start
    while i < len(hist):
        suf = hist[i - g:i]
        best = -1
        for p in range(i - g):
            if hist[p:p + g] == suf:
                best = p
        a = 0
        for j in range(k):
            q = best + g + j
            d = hist[q] if best >= 0 and q < i else hist[i - 1]
            if i + j < len(hist) and d == hist[i + j]:
                a += 1
            else:
                break
        acc += a
        tot += k
        i += a + 1
    return acc / tot if tot else 0.0


def _spec_workload(cfg, params):
    """Build the repeat-heavy workload: probe greedy streams from seeded
    prompts, rank their tails by simulated n-gram acceptance, and resume
    the most repetitive ones SPEC_CUT tokens in.  By greedy determinism
    the continuation of ``prompt + own-output-prefix`` is exactly the rest
    of the probed stream, so the workload's acceptance profile is known —
    the serving shape speculative decode exists for (long generations deep
    inside repetitive spans).  Returns (request factory, probe engine)."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=6).astype(np.int32)
               for _ in range(SPEC_PROBES)]
    probe = [Request(prompt=p, max_new_tokens=SPEC_CUT + SPEC_NEW)
             for p in prompts]
    eng = ServeEngine(cfg, params, batch_slots=SLOTS, max_len=PAGED_MAX_LEN,
                      chunk_size=CHUNK)
    eng.run(probe)
    scored = sorted(
        ((_ngram_oracle(list(p) + q.generated, len(p) + SPEC_CUT,
                        SPEC_NGRAM, SPEC_K), p, q)
         for p, q in zip(prompts, probe)),
        key=lambda t: -t[0],
    )
    top = (scored[:SPEC_TOP] * (N_REQUESTS // SPEC_TOP)
           + scored[:N_REQUESTS % SPEC_TOP])

    def requests():
        return [
            Request(prompt=np.concatenate(
                [p, np.asarray(q.generated[:SPEC_CUT], np.int32)]),
                max_new_tokens=SPEC_NEW)
            for _, p, q in top
        ]

    return requests, eng


def spec_rows(reps: int = 3, identity_only: bool = False):
    """Speculative vs plain decode on the repeat-heavy smoke workload.

    Asserts bit-identity (greedy, and seeded temperature sampling — the
    verify pass replays the exact (seed, token-index) sampler decision)
    and the acceptance-rate floor; in full mode also times both paths
    best-of-``reps`` and reports the throughput ratio."""
    cfg = dataclasses.replace(
        get_config(SERVE_ARCH, smoke=True), **SPEC_BENCH_DIMS
    )
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    spec_cfg = dataclasses.replace(cfg, spec_k=SPEC_K, spec_ngram=SPEC_NGRAM)
    requests, eng = _spec_workload(cfg, params)
    seng = ServeEngine(spec_cfg, params, batch_slots=SLOTS,
                       max_len=PAGED_MAX_LEN,
                       chunk_size=2 * (SPEC_K + 1))

    # -- identity + acceptance (always run; the CI gate) -------------------
    base = requests()
    eng.run(base)
    got = requests()
    base_stats = dict(seng.stats)
    seng.run(got)
    for a, b in zip(base, got):
        assert a.generated == b.generated, (
            "speculative != plain greedy decode on the smoke workload"
        )
    d = {k: seng.stats[k] - base_stats[k] for k in seng.stats}
    acceptance = (d["draft_accepted"] / d["draft_proposed"]
                  if d["draft_proposed"] else 0.0)
    tokens_per_round = (d["decode_tokens"] / d["spec_rounds"]
                        if d["spec_rounds"] else 0.0)
    assert acceptance >= SPEC_ACCEPT_FLOOR, (
        f"spec acceptance {acceptance:.2f} dropped below the recorded "
        f"floor {SPEC_ACCEPT_FLOOR} on the repeat-heavy smoke workload"
    )

    # Sampling identity leg: seeded temperature streams must survive the
    # draft/verify/rollback machinery token-for-token too.
    tcfg = dataclasses.replace(cfg, sampling="temperature", temperature=0.8)
    tspec = dataclasses.replace(tcfg, spec_k=SPEC_K, spec_ngram=SPEC_NGRAM)

    def temp_run(c):
        rs = requests()
        for i, r in enumerate(rs):
            r.seed = 1000 + i
        ServeEngine(c, params, batch_slots=SLOTS, max_len=PAGED_MAX_LEN,
                    chunk_size=2 * (SPEC_K + 1)).run(rs)
        return [r.generated for r in rs]

    assert temp_run(tcfg) == temp_run(tspec), (
        "speculative != plain decode under seeded temperature sampling"
    )

    if identity_only:
        print(f"spec: bit-identical (greedy + seeded sampling), "
              f"acceptance {acceptance:.2f} >= floor {SPEC_ACCEPT_FLOOR}")
        return [], {}

    # -- timed: both engines warm, best-of reps ----------------------------
    walls = {}
    for name, e in (("plain", eng), ("spec", seng)):
        best = None
        for _ in range(max(1, reps)):
            rs = requests()
            t0 = time.perf_counter()
            e.run(rs)
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        walls[name] = (sum(len(r.generated) for r in rs), best)
    tok_s = {k: n / w for k, (n, w) in walls.items()}
    ratio = tok_s["spec"] / tok_s["plain"]
    row = {
        "name": "serve/spec_repeat_heavy",
        "us_per_call": 1e6 / tok_s["spec"],
        "tok_s": tok_s["spec"],
        "plain_tok_s": tok_s["plain"],
        "spec_over_plain": ratio,
        "acceptance_rate": acceptance,
        "tokens_per_round": tokens_per_round,
        "spec_k": SPEC_K,
        "spec_ngram": SPEC_NGRAM,
        "bit_identical": True,
    }
    summary = {"serve_spec": {k: v for k, v in row.items() if k != "name"}}
    return [row], summary


# ---------------------------------------------------------------------------
# Cache-family breadth + paged-vs-contiguous bit-identity
# ---------------------------------------------------------------------------

def _family_extras(cfg):
    if cfg.family == "encdec":
        return {"frames": np.asarray(jax.random.normal(
            jax.random.PRNGKey(4),
            (FAMILY_SLOTS, cfg.enc_seq, cfg.d_model), jnp.float32,
        ))}
    if cfg.family == "vlm":
        return {"vis": np.asarray(jax.random.normal(
            jax.random.PRNGKey(3),
            (FAMILY_SLOTS, cfg.n_vis_tokens, cfg.d_model), jnp.float32,
        ))}
    return {}


def _family_requests(cfg, seed):
    rng = np.random.default_rng(seed)
    spec = [(4, 9), (8, 3), (5, 6), (3, 8)]     # mixed lengths + budgets
    return [
        Request(prompt=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                max_new_tokens=m)
        for n, m in spec
    ]


def _timed_run(eng, reqs):
    t0 = time.perf_counter()
    eng.run(reqs)
    wall = time.perf_counter() - t0
    return sum(len(r.generated) for r in reqs) / wall


def family_rows(identity_only: bool = False):
    """One row per serving cache family.  Where a KV cache exists, the same
    request mix runs through a paged engine whose pool is SMALLER than
    slots x max_len; outputs must be bit-identical to the contiguous
    layout (greedy, same weights — any divergence is a layout bug).

    ``identity_only`` (the CI gate) skips warm-up waves and throughput
    accounting: identity needs exactly one run per layout."""
    rows = []
    summary = {}
    for arch, has_kv in FAMILY_ARCHS:
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        extras = _family_extras(cfg)

        def engine(c, **kw):
            return ServeEngine(c, params, batch_slots=FAMILY_SLOTS,
                               max_len=FAMILY_MAX_LEN, chunk_size=4,
                               extras=extras, **kw)

        eng = engine(cfg)
        row = {"name": f"serve/family_{arch}"}
        reqs = _family_requests(cfg, seed=1)
        if identity_only:
            eng.run(reqs)
        else:
            eng.run(_family_requests(cfg, seed=0))      # warm/compile
            tok_s = _timed_run(eng, reqs)
            row.update({"tok_s": tok_s, "us_per_call": 1e6 / tok_s})
        if has_kv:
            paged_cfg = dataclasses.replace(
                cfg, cache_layout="paged", kv_page_size=FAMILY_PAGE
            )
            peng = engine(paged_cfg, n_pages=FAMILY_POOL)
            preqs = _family_requests(cfg, seed=1)
            if identity_only:
                peng.run(preqs)
            else:
                peng.run(_family_requests(cfg, seed=0))  # warm/compile
                paged_tok_s = _timed_run(peng, preqs)
                row.update({
                    "paged_tok_s": paged_tok_s,
                    "paged_over_contiguous": paged_tok_s / tok_s,
                })
            mismatches = [
                (a.generated, b.generated)
                for a, b in zip(reqs, preqs) if a.generated != b.generated
            ]
            assert not mismatches, (
                f"serve bit-identity violated for {arch}: paged != "
                f"contiguous on {len(mismatches)} request(s): {mismatches[0]}"
            )
            row.update({
                "paged_pool_positions": FAMILY_POOL * FAMILY_PAGE,
                "contiguous_positions": FAMILY_SLOTS * FAMILY_MAX_LEN,
                "bit_identical": True,
            })
        rows.append(row)
        summary[arch] = {k: v for k, v in row.items() if k != "name"}
        if identity_only:
            print(f"{arch}: "
                  + ("bit-identical (paged == contiguous)" if has_kv
                     else "no KV cache (contiguous only)"))
    return rows, {"serve_families": summary}


# ---------------------------------------------------------------------------
# Chaos / lifecycle leg: preemption + fault-injection bit-identity
# ---------------------------------------------------------------------------

CHAOS_SLOTS = 2
CHAOS_MAX_LEN = 32
CHAOS_PAGE = 8
# (prompt_len, max_new_tokens) sized for page 8 / max_len 32: demands are
# 2/3/2/2 pages, so with a 4-page pool the 3-page request can only admit
# by evicting a resident — real preemption, not a simulated one.
CHAOS_SPEC = ((6, 6), (10, 8), (5, 8), (4, 6))
CHAOS_POOL = 4
# Seeded so injections actually fire within this workload's handful of
# allocs (np.random.default_rng(0) draws 0.27/0.04/0.02 early at p=0.4).
CHAOS_ALLOC_FAIL_P = 0.4
CHAOS_PREEMPT_P = 0.25
CHAOS_SEED = 0


def _chaos_requests(cfg, seed=17):
    rng = np.random.default_rng(seed)
    return [
        Request(prompt=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                max_new_tokens=m, seed=7)
        for n, m in CHAOS_SPEC
    ]


def chaos_rows(identity_only: bool = False):
    """Lifecycle robustness gate (DESIGN.md §5.5), two legs against one
    fault-free reference run:

    * pressure — a pool smaller than the workload's concurrent footprint
      forces >= 1 genuine preemption (evict, release pages, re-enqueue,
      recompute-prefill over prompt + emitted);
    * chaos — seeded alloc refusals AND forced preemptions perturb the
      schedule; the engine auto-asserts ``check_invariants()`` after
      every wave while a chaos knob is armed.

    Both must reproduce the reference streams bit-for-bit and end with
    the ENTIRE pool back on the free list (zero leaked pages) — restore
    correctness is recomputed from host-side truth, so any divergence is
    a lifecycle bug, not noise."""
    cfg = dataclasses.replace(
        get_config(SERVE_ARCH, smoke=True),
        cache_layout="paged", kv_page_size=CHAOS_PAGE,
    )
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    def run(c, n_pages=None):
        eng = ServeEngine(c, params, batch_slots=CHAOS_SLOTS,
                          max_len=CHAOS_MAX_LEN, chunk_size=4,
                          n_pages=n_pages)
        reqs = _chaos_requests(c)
        eng.run(reqs)
        return eng, reqs

    ref_eng, ref = run(cfg)                    # ample pool: no eviction
    assert ref_eng.stats["preempted"] == 0

    def check(tag, eng, reqs):
        bad = [i for i, (a, b) in enumerate(zip(reqs, ref))
               if a.generated != b.generated]
        assert not bad, (
            f"serve bit-identity violated on {tag} leg for request(s) "
            f"{bad}: fault paths changed emitted tokens"
        )
        leaked = eng.n_pages - len(eng.free_pages)
        assert leaked == 0, f"{tag} leg leaked {leaked} page(s)"
        eng.check_invariants()

    press_eng, pressed = run(cfg, n_pages=CHAOS_POOL)
    assert press_eng.stats["preempted"] >= 1, "pressure leg never evicted"
    check("pressure", press_eng, pressed)

    chaos_cfg = dataclasses.replace(
        cfg, chaos_alloc_fail_p=CHAOS_ALLOC_FAIL_P,
        chaos_preempt_p=CHAOS_PREEMPT_P, chaos_seed=CHAOS_SEED,
    )
    chaos_eng, chaotic = run(chaos_cfg, n_pages=CHAOS_POOL)
    life = chaos_eng.policy_report()["lifecycle"]
    assert life["chaos"]["injected_alloc_failures"] >= 1, "chaos never fired"
    check("chaos", chaos_eng, chaotic)

    rows = [{
        "name": "serve/chaos",
        "preempted_pressure": press_eng.stats["preempted"],
        "recompute_tokens_pressure": press_eng.stats["recompute_tokens"],
        "preempted_chaos": chaos_eng.stats["preempted"],
        "preempted_forced_chaos": chaos_eng.stats["preempted_forced"],
        "injected_alloc_failures": life["chaos"]["injected_alloc_failures"],
        "recompute_tokens_chaos": chaos_eng.stats["recompute_tokens"],
        "leaked_pages": 0,
        "goodput_under_deadline": life["goodput_under_deadline"],
        "bit_identical": True,
    }]
    if identity_only:
        print(
            "chaos: bit-identical under preemption + injected faults "
            f"(pressure preemptions={rows[0]['preempted_pressure']}, "
            f"injected alloc failures={rows[0]['injected_alloc_failures']}, "
            f"forced preemptions={rows[0]['preempted_forced_chaos']}, "
            "leaked pages=0)"
        )
    return rows, {"serve_chaos": {k: v for k, v in rows[0].items()
                                  if k != "name"}}


# ---------------------------------------------------------------------------
# Crash recovery: snapshot/journal restore identity per cache family
# ---------------------------------------------------------------------------

RECOVERY_CRASH_WAVE = 2     # late submits force wave 2, so the crash fires
RECOVERY_CORRUPT_P = 0.5


def _recovery_extras(cfg):
    """Conditioning for stateful-context families, tiled IDENTICALLY
    across slots.  The encdec/vlm stubs key their conditioning by SLOT
    (an engine fixture standing in for per-request audio/image), so a
    request restored into a different slot would be conditioned on
    different context; recovery identity is about rebuilding KV from
    host truth, not about pinning slot placement, so the recovery leg
    makes the conditioning slot-invariant."""
    if cfg.family == "encdec":
        one = np.asarray(jax.random.normal(
            jax.random.PRNGKey(4), (1, cfg.enc_seq, cfg.d_model), jnp.float32
        ))
        return {"frames": np.broadcast_to(
            one, (FAMILY_SLOTS, cfg.enc_seq, cfg.d_model)).copy()}
    if cfg.family == "vlm":
        one = np.asarray(jax.random.normal(
            jax.random.PRNGKey(3), (1, cfg.n_vis_tokens, cfg.d_model),
            jnp.float32,
        ))
        return {"vis": np.broadcast_to(
            one, (FAMILY_SLOTS, cfg.n_vis_tokens, cfg.d_model)).copy()}
    return {}


def recovery_rows(identity_only: bool = False, report_path: str | None = None):
    """Crash/restore identity gate (DESIGN.md §5.6), per cache family.

    Per leg: an uninterrupted reference run records the expected streams;
    then a journal-armed engine admits half the workload, snapshots at a
    chunk boundary, takes the second half, and dies on an injected
    ``ChaosCrash`` at a flushed chunk boundary; a FRESH engine restores
    from snapshot + journal suffix and finishes.  Results must match the
    reference stream-for-stream with zero leaked pages (free +
    quarantined partitions the pool, nothing held).  Dense adds a
    {sharing on} leg (restored residents re-attach through the trie) and
    a corruption leg (seeded device bit-flips on stamped pages must be
    detected, quarantined and recompute-healed — still bit-identical).
    """
    from repro.serve.chaos import ChaosCrash

    import json
    import os
    import tempfile

    tmpdir = tempfile.mkdtemp(prefix="serve-recovery-")
    rows = []
    for arch, has_kv in FAMILY_ARCHS:
        cfg0 = get_config(arch, smoke=True)
        params = build_model(cfg0).init(jax.random.PRNGKey(0))
        extras = _recovery_extras(cfg0)
        legs = [False, True] if (has_kv and cfg0.family in ("dense", "moe")) \
            else [False]
        for sharing in legs:
            if has_kv:
                c = dataclasses.replace(
                    cfg0, cache_layout="paged", kv_page_size=FAMILY_PAGE,
                    prefix_sharing=sharing,
                )
                kw = {"n_pages": FAMILY_POOL}
            else:
                c, kw = cfg0, {}

            def engine(cc, **ekw):
                return ServeEngine(cc, params, batch_slots=FAMILY_SLOTS,
                                   max_len=FAMILY_MAX_LEN, chunk_size=4,
                                   extras=extras, **kw, **ekw)

            tag = f"{arch}/{'shared' if sharing else 'unshared'}"
            ref_eng = engine(c)
            ref_eng.run(_family_requests(cfg0, seed=1))
            ref_out = ref_eng.results()

            jpath = os.path.join(tmpdir, f"{tag.replace('/', '-')}.jsonl")
            spath = os.path.join(tmpdir, f"{tag.replace('/', '-')}.json")
            crashed = engine(
                dataclasses.replace(
                    c, chaos_crash_after_wave=RECOVERY_CRASH_WAVE
                ),
                journal_path=jpath,
            )
            reqs = _family_requests(cfg0, seed=1)
            crashed.submit(reqs[:2])
            crashed.step()
            crashed.snapshot(spath)
            crashed.submit(reqs[2:])         # journal-only: past the snapshot
            try:
                crashed.drain()
                raise AssertionError(f"{tag}: injected crash never fired")
            except ChaosCrash as cc:
                crash_wave = cc.wave
            # The crashed engine is dead by contract; a FRESH engine
            # restores from its on-disk snapshot + journal suffix.
            eng = engine(c, journal_path=jpath)
            rep = eng.restore(spath)
            eng.drain()
            got = eng.results()
            bad = [rid for rid in ref_out if got.get(rid) != ref_out[rid]]
            assert not bad, (
                f"crash-recovery identity violated on {tag} for {bad}"
            )
            leaked = 0
            if has_kv:
                free = sorted(eng.free_pages)
                quar = sorted(eng.allocator.quarantined_pages)
                leaked = eng.n_pages - len(free) - len(quar)
                assert sorted(free + quar) == list(range(eng.n_pages)), (
                    f"{tag} leg leaked pages: free={free} quarantined={quar}"
                )
                eng.check_invariants()
            rows.append({
                "name": f"serve/recovery_{tag}",
                "crash_wave": crash_wave,
                "restored": rep["restored"],
                "replayed_events": rep["replayed_events"],
                "leaked_pages": leaked,
                "bit_identical": True,
            })
            if identity_only:
                print(f"recovery {tag}: bit-identical after crash at wave "
                      f"{crash_wave} (restored={rep['restored']}, "
                      f"replayed={rep['replayed_events']}, leaked pages=0)")

        # Corruption leg: dense paged + sharing, seeded device bit-flips.
        if arch == SERVE_ARCH:
            c = dataclasses.replace(
                cfg0, cache_layout="paged", kv_page_size=FAMILY_PAGE,
                prefix_sharing=True,
            )
            ref_eng = ServeEngine(c, params, batch_slots=FAMILY_SLOTS,
                                  max_len=FAMILY_MAX_LEN, chunk_size=4,
                                  n_pages=FAMILY_POOL)
            ref_eng.run(_family_requests(cfg0, seed=1))
            ref_out = ref_eng.results()
            crpt = dataclasses.replace(
                c, chaos_corrupt_p=RECOVERY_CORRUPT_P, chaos_seed=3
            )
            eng = ServeEngine(crpt, params, batch_slots=FAMILY_SLOTS,
                              max_len=FAMILY_MAX_LEN, chunk_size=4,
                              n_pages=FAMILY_POOL)
            eng.run(_family_requests(cfg0, seed=1))
            s = eng.stats
            assert s["injected_corruptions"] >= 1, "corruption never fired"
            assert s["corrupted_pages"] == s["injected_corruptions"], (
                "an injected corruption escaped detection"
            )
            assert eng.results() == ref_out, (
                "corruption healing changed emitted tokens"
            )
            free = sorted(eng.free_pages)
            quar = sorted(eng.allocator.quarantined_pages)
            assert sorted(free + quar) == list(range(eng.n_pages))
            eng.check_invariants()
            rows.append({
                "name": f"serve/recovery_{arch}/corruption",
                "injected_corruptions": s["injected_corruptions"],
                "corrupted_pages_detected": s["corrupted_pages"],
                "healed_requests": s["healed_requests"],
                "quarantined_pages": len(quar),
                "leaked_pages": 0,
                "bit_identical": True,
            })
            if identity_only:
                print(f"recovery {arch}/corruption: "
                      f"{s['corrupted_pages']} corruption(s) detected, "
                      f"quarantined and recompute-healed, bit-identical, "
                      "leaked pages=0")

    if report_path:
        with open(report_path, "w") as f:
            json.dump({"serve_recovery": rows}, f, indent=1)
        print(f"recovery report written to {report_path}")
    return rows, {"serve_recovery": {
        r["name"].removeprefix("serve/recovery_"): {
            k: v for k, v in r.items() if k != "name"
        } for r in rows
    }}


# ---------------------------------------------------------------------------
# Adaptive cache policy: warm retention + per-class selection (DESIGN.md §5.7)
# ---------------------------------------------------------------------------

ADAPT_PAGE = 16
ADAPT_SYS = 48          # system prompt: 3 full pages of 16
ADAPT_TAIL = 4          # per-arrival user tail
ADAPT_NEW = 8
ADAPT_JUNK = 36         # churn prompts: 2 full (never reused) pages + tail
ADAPT_JUNK_NEW = 4
ADAPT_WARM = 3          # warm budget == the system prompt's page count
# Pool sized so the mixed trace generates real contention: each junk
# request finishes first and parks its 2 never-reused pages warm, so a
# retain-always stance leaves the re-arriving system prompt only 1 of
# its 3 warm slots — junk pollution of the bounded warm tier is both
# the prefill-work cost retain-always pays and the churn signal the
# adaptive controller learns bypass from.
ADAPT_POOL = 8
ADAPT_ROUNDS = 6
ADAPT_SLOTS = 2
ADAPT_MAX_LEN = 80
# CI floor for the timed leg: warm-revived re-arrivals prefill only the
# tail (pad bucket 8 vs 64), so TTFT must improve by at least this much
# over static refcount-zero freeing.
ADAPT_TTFT_FLOOR = 1.2


def _adaptive_cfgs(identity_only: bool):
    base = get_config(SERVE_ARCH, smoke=True)
    if not identity_only:
        base = dataclasses.replace(base, **PAGED_BENCH_DIMS)
    shared = dataclasses.replace(
        base, cache_layout="paged", kv_page_size=ADAPT_PAGE,
        prefix_sharing=True,
    )
    adaptive = dataclasses.replace(
        shared, adaptive=True, warm_pages=ADAPT_WARM,
        adaptive_replan_every=1,
    )
    return base, shared, adaptive


def _mixed_trace(eng, base, pinned=None):
    """ADAPT_ROUNDS submit/drain rounds of one re-arriving system-prompt
    request plus one never-repeated junk request.  Deterministic: the
    rng draws the same workload for every engine variant."""
    if pinned is not None:
        assert eng.adaptive is not None
        eng.adaptive.pinned = pinned
    rng = np.random.default_rng(5)
    sys_p = rng.integers(0, base.vocab, size=ADAPT_SYS).astype(np.int32)
    outs = []
    for _ in range(ADAPT_ROUNDS):
        junk = Request(
            prompt=rng.integers(0, base.vocab,
                                size=ADAPT_JUNK).astype(np.int32),
            max_new_tokens=ADAPT_JUNK_NEW, seed=3,
        )
        sysr = Request(prompt=np.concatenate(
            [sys_p,
             rng.integers(0, base.vocab, size=ADAPT_TAIL).astype(np.int32)]),
            max_new_tokens=ADAPT_NEW, seed=3,
        )
        eng.submit([junk, sysr])
        eng.drain()
        outs.append((list(junk.generated), list(sysr.generated)))
    free = sorted(eng.allocator.free_pages)
    warm = sorted(eng.allocator.warm_pages)
    assert sorted(free + warm) == list(range(eng.n_pages)), (
        f"adaptive trace leaked pages: free={free} warm={warm}"
    )
    eng.check_invariants()
    return outs


def adaptive_rows(reps: int = 3, identity_only: bool = False):
    """Adaptive serve-tier cache policy vs every static stance it
    subsumes (DESIGN.md §5.7) — the serve-tier mirror of the paper's
    adaptive-matches-best-static result.

    Always asserts (the CI ``serve_adaptive`` gate), on the mixed trace
    (a re-arriving system prompt interleaved with never-repeated junk
    prompts under a pool that makes warm retention contested):

    * bit-identity — static engine, pinned retain-always, pinned bypass
      and the free-running adaptive engine all emit identical streams
      (adaptation is placement-only);
    * adaptive <= best static on prefill work: per-class replanning
      learns retain-the-system-prompt AND bypass-the-junk, which no
      single static stance can do at once (retain-always lets junk
      pollute the bounded warm tier; bypass forfeits every re-arrival);
    * the controller genuinely adapted: >= 1 replan, junk churn drove
      the aggregate "novel" class to bypass, warm revives fired.

    In full mode additionally times re-arrival TTFT against static
    freeing — warm-revived admissions prefill only the user tail — and
    enforces the >= ``ADAPT_TTFT_FLOOR``x floor."""
    from repro.serve.adaptive import CLASS_NOVEL, ServeCombo

    base, shared, adaptive = _adaptive_cfgs(identity_only)
    params = build_model(base).init(jax.random.PRNGKey(0))

    legs = {
        "static_off": (shared, None),
        "static_retain": (adaptive, ServeCombo(1.0, "lru", False)),
        "static_bypass": (adaptive, ServeCombo(1.0, "lru", True)),
        "adaptive": (adaptive, None),
    }
    engines, work = {}, {}
    ref_outs = None
    for name, (c, pinned) in legs.items():
        eng = ServeEngine(c, params, batch_slots=ADAPT_SLOTS,
                          max_len=ADAPT_MAX_LEN, chunk_size=4,
                          n_pages=ADAPT_POOL)
        outs = _mixed_trace(eng, base, pinned=pinned)
        if ref_outs is None:
            ref_outs = outs
        mismatch = [i for i, (a, b) in enumerate(zip(outs, ref_outs))
                    if a != b]
        assert not mismatch, (
            f"adaptive bit-identity violated on {name} leg: cache policy "
            f"changed emitted tokens in round(s) {mismatch}"
        )
        engines[name], work[name] = eng, eng.stats["prefill_work_tokens"]

    eng_a = engines["adaptive"]
    best_static = min(work[k] for k in legs if k != "adaptive")
    assert work["adaptive"] <= best_static, (
        f"adaptive ({work['adaptive']} prefill-work tokens) lost to the "
        f"best static policy ({best_static}): "
        f"{ {k: v for k, v in work.items()} }"
    )
    assert work["adaptive"] < work["static_off"], (
        "warm retention saved no prefill work on the re-arrival trace"
    )
    assert eng_a.stats["replans"] >= 1
    assert eng_a.stats["warm_hits"] >= 1, "no re-arrival ever revived"
    combos = eng_a.policy_report()["adaptive"]["combos"]
    novel = combos.get(CLASS_NOVEL)
    assert novel is not None and novel[2] is True, (
        f"junk churn failed to teach the novel class bypass: {combos}"
    )

    if identity_only:
        print(
            "adaptive: bit-identical across static/pinned/adaptive legs; "
            f"prefill work {work['adaptive']} <= best static {best_static} "
            f"(off={work['static_off']}, retain={work['static_retain']}, "
            f"bypass={work['static_bypass']}); "
            f"replans={eng_a.stats['replans']}, "
            f"warm hits={eng_a.stats['warm_hits']}, leaked pages=0"
        )
        return [], {}

    # -- timed: re-arrival TTFT, warm revive vs static freeing -------------
    # One engine per stance, primed once per rep with the system prompt;
    # the timed re-arrival then prefills pad-8 (tail only, warm revive)
    # vs pad-64 (full prompt, static).  Rep -1 is an untimed warm-up so
    # both pad signatures compile outside the clock.
    ttft = {}
    for name, c in (("static", shared), ("adaptive", adaptive)):
        eng = ServeEngine(c, params, batch_slots=ADAPT_SLOTS,
                          max_len=ADAPT_MAX_LEN, chunk_size=4,
                          n_pages=ADAPT_POOL)
        rng = np.random.default_rng(7)
        sys_p = rng.integers(0, base.vocab, size=ADAPT_SYS).astype(np.int32)
        best = None
        for rep in range(-1, max(1, reps)):
            for timed in (False, True):         # primer arrival, re-arrival
                r = Request(prompt=np.concatenate(
                    [sys_p, rng.integers(0, base.vocab,
                                         size=ADAPT_TAIL).astype(np.int32)]),
                    max_new_tokens=ADAPT_NEW, seed=3)
                eng.submit([r])
                eng.drain()
                if rep >= 0 and timed:
                    best = (r.ttft_s if best is None
                            else min(best, r.ttft_s))
        ttft[name] = best
    ttft_cut = ttft["static"] / ttft["adaptive"]
    assert ttft_cut >= ADAPT_TTFT_FLOOR, (
        f"warm re-arrival TTFT cut {ttft_cut:.2f}x dropped below the "
        f"{ADAPT_TTFT_FLOOR}x floor (static {ttft['static']:.6f}s, "
        f"adaptive {ttft['adaptive']:.6f}s)"
    )

    row = {
        "name": "serve/adaptive_policy",
        "ttft_s": ttft["adaptive"],
        "static_ttft_s": ttft["static"],
        "ttft_cut_x": ttft_cut,
        "prefill_work_tokens": work["adaptive"],
        "best_static_work_tokens": best_static,
        "static_off_work_tokens": work["static_off"],
        "static_retain_work_tokens": work["static_retain"],
        "static_bypass_work_tokens": work["static_bypass"],
        "warm_hits": eng_a.stats["warm_hits"],
        "warm_tokens_saved": eng_a.stats["warm_tokens_saved"],
        "replans": eng_a.stats["replans"],
        "bit_identical": True,
    }
    summary = {"serve_adaptive": {k: v for k, v in row.items()
                                  if k != "name"}}
    return [row], summary


# ---------------------------------------------------------------------------
# Decode-kernel legs: paged-vs-gather identity matrix + context scaling
# ---------------------------------------------------------------------------

DK_ARCHS = ("qwen2.5-32b", "zamba2-2.7b")
DK_SYS = 2 * FAMILY_PAGE        # 16-token shared system prompt = 2 pages
# Demands run to 4 pages/request at page 8; 2 slots -> up to 8 concurrent
# pages against a 6-page pool, so the matrix exercises real eviction under
# both kernels (the schedules must still match token-for-token).
DK_POOL = 6
DK_SPEC = ((3, 6), (6, 8), (4, 6), (5, 7))

# Context-scaling leg: resident context per slot at the decode steps we
# time.  max_len covers the largest context; the pool is ample (scaling,
# not pressure, is the subject here).
DK_CONTEXTS = (16, 32, 64)
DK_CTX_PAGE = 16
DK_CTX_MAX_LEN = 80
DK_CTX_NEW = 4


def _dk_requests(cfg, seed=5):
    """Shared system prompt + per-request tails, so the sharing=on cell of
    the matrix actually attaches shared pages under the paged kernel."""
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(0, cfg.vocab, size=DK_SYS).astype(np.int32)
    return [
        Request(prompt=np.concatenate(
                    [sys_p, rng.integers(0, cfg.vocab, size=n).astype(np.int32)]),
                max_new_tokens=m)
        for n, m in DK_SPEC
    ]


def decode_kernel_rows(identity_only: bool = False):
    """Paged-kernel identity gate: ``pallas_paged`` (the kernel
    dereferencing the page table in place) must reproduce
    ``pallas_gather`` (gather_pages + the dense split-KV kernel — the
    reference semantics for the clamp-to-page-0-then-mask contract)
    bit-for-bit across {qwen dense-GQA, zamba2 hybrid} x {prefix sharing
    on/off} x {chaos off/on}, with zero leaked pages on both engines.

    zamba2 silently disables prefix sharing (hybrid SSM state can't
    share); that cell still runs — the gate is that the kernels agree
    under whatever the engine actually does."""
    rows = []
    summary = {}
    for arch in DK_ARCHS:
        base = dataclasses.replace(
            get_config(arch, smoke=True),
            cache_layout="paged", kv_page_size=FAMILY_PAGE,
        )
        params = build_model(base).init(jax.random.PRNGKey(0))
        for sharing in (False, True):
            for chaos in (False, True):
                cfg = dataclasses.replace(base, prefix_sharing=sharing)
                if chaos:
                    cfg = dataclasses.replace(
                        cfg, chaos_alloc_fail_p=CHAOS_ALLOC_FAIL_P,
                        chaos_preempt_p=CHAOS_PREEMPT_P,
                        chaos_seed=CHAOS_SEED,
                    )

                def run(kernel, c=cfg):
                    eng = ServeEngine(
                        dataclasses.replace(c, decode_kernel=kernel),
                        params, batch_slots=FAMILY_SLOTS,
                        max_len=FAMILY_MAX_LEN, chunk_size=4,
                        n_pages=DK_POOL,
                    )
                    reqs = _dk_requests(c)
                    eng.run(reqs)
                    leaked = eng.n_pages - len(eng.free_pages)
                    assert leaked == 0, (
                        f"{kernel} leaked {leaked} page(s) on {arch} "
                        f"sharing={sharing} chaos={chaos}"
                    )
                    eng.check_invariants()
                    return eng, reqs

                geng, gref = run("pallas_gather")
                peng, pref = run("pallas_paged")
                bad = [i for i, (a, b) in enumerate(zip(gref, pref))
                       if a.generated != b.generated]
                assert not bad, (
                    f"decode-kernel bit-identity violated on {arch} "
                    f"sharing={sharing} chaos={chaos}: paged != gather "
                    f"on request(s) {bad}"
                )
                if chaos:
                    life = peng.policy_report()["lifecycle"]
                    fired = (life["chaos"]["injected_alloc_failures"]
                             + peng.stats["preempted_forced"])
                    assert fired >= 1, (
                        f"chaos never fired on {arch} sharing={sharing}"
                    )
                report = peng.policy_report()["decode_attention"]
                tag = f"{arch}/share{int(sharing)}/chaos{int(chaos)}"
                row = {
                    "name": f"serve/decode_kernel_{tag}",
                    "bit_identical": True,
                    "leaked_pages": 0,
                    "planned_splits": report["planned_splits"],
                    "kernel_bkv": report["kernel_bkv"],
                    "prefix_hits": peng.stats["prefix_hits"],
                    "preempted": peng.stats["preempted"],
                }
                if chaos:
                    row["injected_alloc_failures"] = (
                        life["chaos"]["injected_alloc_failures"])
                    row["preempted_forced"] = peng.stats["preempted_forced"]
                rows.append(row)
                summary[tag] = {k: v for k, v in row.items()
                                if k != "name"}
        if identity_only:
            print(f"decode_kernel {arch}: bit-identical "
                  "(paged == gather) across sharing x chaos")
    return rows, {"serve_decode_kernel": summary}


def _ctx_requests(cfg, context, seed=9):
    rng = np.random.default_rng(seed)
    return [
        Request(prompt=rng.integers(0, cfg.vocab,
                                    size=context - DK_CTX_NEW).astype(np.int32),
                max_new_tokens=DK_CTX_NEW)
        for _ in range(FAMILY_SLOTS)
    ]


def decode_context_rows(identity_only: bool = False):
    """Throughput vs resident-context length, xla vs the paged kernel.

    CPU wall clocks (kernels in interpret mode) anchor relative cost
    only; the acceptance gate is the v5e HBM roofline story: the xla
    path streams the resident KV three times per decode step (read pool,
    write the gathered dense copy, read it back in ``_sdpa``) where the
    paged kernel reads each mapped page exactly once, so the modeled
    advantage must GROW with resident context — asserted, alongside
    paged-vs-gather bit-identity at every context length."""
    from repro import hw

    base = dataclasses.replace(
        get_config(SERVE_ARCH, smoke=True),
        cache_layout="paged", kv_page_size=DK_CTX_PAGE,
    )
    params = build_model(base).init(jax.random.PRNGKey(0))
    pool = FAMILY_SLOTS * (DK_CTX_MAX_LEN // DK_CTX_PAGE)
    rows, advantages = [], []
    for t in DK_CONTEXTS:

        def run(kernel, timed):
            eng = ServeEngine(
                dataclasses.replace(base, decode_kernel=kernel),
                params, batch_slots=FAMILY_SLOTS, max_len=DK_CTX_MAX_LEN,
                chunk_size=16, n_pages=pool,
            )
            reqs = _ctx_requests(base, t)
            tok_s = None
            if timed:
                eng.run(_ctx_requests(base, t))     # warm/compile
                tok_s = _timed_run(eng, reqs)
            else:
                eng.run(reqs)
            return eng, reqs, tok_s

        _, gref, _ = run("pallas_gather", timed=False)
        peng, pref, paged_tok_s = run("pallas_paged", timed=not identity_only)
        bad = [i for i, (a, b) in enumerate(zip(gref, pref))
               if a.generated != b.generated]
        assert not bad, (
            f"decode-kernel bit-identity violated at context {t}: "
            f"paged != gather on request(s) {bad}"
        )

        # v5e roofline per decode step per slot: the KV stream is
        # 2*t*hkv*dh*4 bytes (K and V, fp32); xla pays it 3x (pool read,
        # dense write, _sdpa read), paged pays it once.  q/out bytes are
        # shared by both paths.
        kv_bytes = 2 * t * base.n_kv_heads * base.head_dim * 4
        fixed = 2 * base.n_heads * base.head_dim * 4
        xla_us = hw.hbm_time(3 * kv_bytes + fixed) * 1e6
        paged_us = hw.hbm_time(kv_bytes + fixed) * 1e6
        advantage = xla_us / paged_us
        advantages.append(advantage)
        row = {
            "name": f"serve/decode_context_t{t}",
            "resident_context": t,
            "modeled_xla_us": xla_us,
            "modeled_paged_us": paged_us,
            "modeled_advantage": advantage,
            "planned_splits":
                peng.policy_report()["decode_attention"]["planned_splits"],
            "bit_identical": True,
        }
        if not identity_only:
            _, _, xla_tok_s = run("xla", timed=True)
            row.update({
                "paged_tok_s": paged_tok_s,
                "xla_tok_s": xla_tok_s,
                "paged_over_xla_wall": paged_tok_s / xla_tok_s,
            })
        rows.append(row)
    assert all(a2 > a1 for a1, a2 in zip(advantages, advantages[1:])), (
        f"paged advantage must grow with resident context: {advantages}"
    )
    if identity_only:
        print("decode_context: bit-identical (paged == gather) at contexts "
              f"{DK_CONTEXTS}; modeled advantage grows "
              f"{advantages[0]:.2f}x -> {advantages[-1]:.2f}x")
    summary = {f"t{t}": {k: v for k, v in r.items() if k != "name"}
               for t, r in zip(DK_CONTEXTS, rows)}
    return rows, {"serve_decode_context": summary}


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--identity-only", action="store_true",
                    help="run only the bit-identity checks — paged vs "
                         "contiguous, speculative vs plain (greedy + "
                         "seeded sampling) with the spec acceptance floor, "
                         "shared-prefix vs unshared with the effective-"
                         "capacity floor, and the chaos leg (preemption + "
                         "seeded fault injection must not change a token "
                         "and must leak zero pages), the crash-"
                         "recovery leg (every family crashes mid-flight "
                         "and restores bit-identically from snapshot + "
                         "journal), and the decode-kernel legs (paged "
                         "kernel bit-identical to the gather path "
                         "across families x sharing x chaos, modeled "
                         "advantage grows with context) (CI gate); "
                         "nonzero exit on any violation")
    ap.add_argument("--recovery-report", metavar="PATH", default=None,
                    help="write the crash-recovery rows (per-family "
                         "crash/restore + corruption-healing results) as "
                         "JSON to PATH (the CI artifact)")
    args = ap.parse_args()
    if args.identity_only:
        family_rows(identity_only=True)
        paged_rows(reps=1, warm=False)
        spec_rows(identity_only=True)
        prefix_rows(identity_only=True)
        chaos_rows(identity_only=True)
        recovery_rows(identity_only=True, report_path=args.recovery_report)
        adaptive_rows(identity_only=True)
        decode_kernel_rows(identity_only=True)
        decode_context_rows(identity_only=True)
        print("serve bit-identity: PASS")
    else:
        rows, summary = serve_rows()
        prows, psummary = paged_rows()
        frows, fsummary = family_rows()
        srows, ssummary = spec_rows()
        xrows, xsummary = prefix_rows()
        crows, csummary = chaos_rows()
        rrows, rsummary = recovery_rows(report_path=args.recovery_report)
        arows, asummary = adaptive_rows()
        krows, ksummary = decode_kernel_rows()
        trows, tsummary = decode_context_rows()
        for r in (rows + prows + frows + srows + xrows + crows + rrows
                  + arows + krows + trows):
            print(r)
        print(json.dumps(
            {**summary, **psummary, **fsummary, **ssummary, **xsummary,
             **csummary, **rsummary, **asummary, **ksummary, **tsummary},
            indent=1,
        ))
