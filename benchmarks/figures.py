"""One benchmark per paper table/figure (Figs 4-13), evaluated with the
calibrated gem5-APU chip model + measured CPU wall time for the runnable
reduced configs.

Each figure function takes a *backend* that answers policy-cost queries:

* :class:`FastBackend` (default) — the batched, memoized pipeline: one
  vectorized lattice sweep per unique op (``core.sweep``) serves every
  (mode, AB, rinse) query, and plans/costs hit the shared
  :class:`~repro.core.planner.PlanCache`.
* :class:`SeedBackend` — the original per-query pure-Python walk (greedy
  adaptive, no caching), kept as the baseline ``benchmarks.run`` times the
  fast path against (``seed_sweep_wall_s`` / ``sweep_speedup``).

Each function returns CSV-ready rows; ``benchmarks.run`` prints them.
"""
from __future__ import annotations

import time

import jax

from repro import hw
from repro.core import allocator, cost_model
from repro.core.characterize import classify_workload, op_table
from repro.core.planner import PlanCache, Planner
from repro.core.policy import StaticMode, static_assignment
from repro.core.sweep import SweepTable
from repro.workloads.suite import SUITE

GPU = hw.PAPER_GPU
STATIC = (StaticMode.UNCACHED, StaticMode.CACHER, StaticMode.CACHERW)


class SeedBackend:
    """Seed-path evaluation: per-query python walk, greedy adaptive, cold."""

    name = "seed"

    def __init__(self, chip: hw.Chip = GPU):
        self.chip = chip
        self._sites: dict = {}   # pre-PR engine: policy-per-site table

    def workload_cost(self, ops, **kw):
        return cost_model.workload_cost(
            ops, chip=self.chip, memoize=False, search="greedy", **kw
        )

    def op_cost(self, op, **kw):
        return cost_model.op_cost(op, chip=self.chip, **kw)

    def plan_op(self, op, assignment, **kw):
        return allocator.plan_op(op, assignment, chip=self.chip, **kw)

    def launch_plan(self, op):
        """Per-launch adaptive planning, seed-engine style: the site table
        caches *policies* (as the pre-PR predictor did), but prediction,
        allocation and costing still re-run on every launch."""
        from repro.core.predictor import SiteKey

        a = {}
        seed = None
        for o in op.operands:
            key = SiteKey.from_profile(op, o)
            pol = self._sites.get(key)
            if pol is None:
                if seed is None:
                    seed = cost_model.adaptive_assignment(op, self.chip)
                pol = seed[o.name]
                self._sites[key] = pol
            a[o.name] = pol
        plan = allocator.plan_op(op, a, chip=self.chip)
        bd = cost_model.op_cost(
            op, assignment=plan.assignment, chip=self.chip, launches=1
        )
        return plan, bd

    def classify(self, ops):
        return classify_workload(ops, chip=self.chip, memoize=False)

    def stats(self):
        return {}


class FastBackend:
    """Batched + memoized evaluation over a shared sweep table/plan cache."""

    name = "fast"

    def __init__(self, chip: hw.Chip = GPU, plan_cache: PlanCache | None = None):
        self.chip = chip
        self.plan_cache = plan_cache or PlanCache()
        self.table = SweepTable(chip=chip)
        self.planner = Planner(chip=chip, cache=self.plan_cache,
                               table=self.table)
        # Pre-warm: one vectorized sweep over every unique suite op.
        self.table.add([op for w in SUITE.values() for op in w.ops])

    def workload_cost(self, ops, **kw):
        return self.table.workload_cost(ops, **kw)

    def op_cost(self, op, mode=None, assignment=None, allocation_bypass=True,
                rinse=True, launches=1):
        return self.table.op_cost(
            op, mode=mode, assignment=assignment,
            allocation_bypass=allocation_bypass, rinse=rinse,
            launches=launches,
        )

    def plan_op(self, op, assignment, allocation_bypass=True, rinse=True):
        return self.planner.plan(
            op, assignment, allocation_bypass=allocation_bypass, rinse=rinse
        )

    def launch_plan(self, op):
        """Per-launch adaptive planning: one PlanCache lookup when warm."""
        return self.planner.launch_plan(op)

    def classify(self, ops):
        return classify_workload(
            ops, chip=self.chip,
            cost_fn=lambda ops_, mode: self.table.workload_cost(
                ops_, mode=mode, launches_per_op=0
            ),
        )

    def stats(self):
        s = self.planner.stats()
        s["sweep_table"] = self.table.stats()
        return s


def _default_backend() -> FastBackend:
    global _BACKEND
    try:
        return _BACKEND
    except NameError:
        _BACKEND = FastBackend()
        return _BACKEND


def fig4_5_characterization(backend=None):
    """GVOPS / memory-requests-per-second analogue: per-workload compute and
    memory demand under CacheR (paper Figs 4-5)."""
    be = backend or _default_backend()
    rows = []
    for name, w in SUITE.items():
        c = be.workload_cost(w.ops, mode=StaticMode.CACHER, launches_per_op=0)
        flops = sum(op.flops for op in w.ops)
        rows.append({
            "name": f"fig4_5/{name}",
            "gflops_per_s": flops / max(c.t_total, 1e-12) / 1e9,
            "gmem_reqs_per_s": c.hbm_bytes / 64 / max(c.t_total, 1e-12) / 1e9,
            "class": be.classify(w.ops).value,
        })
    return rows


def fig6_7_policy_sweep(backend=None):
    """Execution time + DRAM traffic per static policy, normalized to
    Uncached (paper Figs 6-7)."""
    be = backend or _default_backend()
    rows = []
    for name, w in SUITE.items():
        base = be.workload_cost(w.ops, mode=StaticMode.UNCACHED,
                                launches_per_op=1)
        for mode in STATIC:
            c = be.workload_cost(w.ops, mode=mode, launches_per_op=1)
            rows.append({
                "name": f"fig6_7/{name}/{mode.value}",
                "norm_time": c.t_total / max(base.t_total, 1e-30),
                "norm_dram_traffic": c.hbm_bytes / max(base.hbm_bytes, 1e-30),
            })
    return rows


def fig8_stalls(backend=None):
    """Cache-stall proxy per policy (paper Fig 8): modeled stall fraction
    plus allocator shrink events (blocking baseline)."""
    be = backend or _default_backend()
    rows = []
    for name, w in SUITE.items():
        for mode in (StaticMode.CACHER, StaticMode.CACHERW):
            stall = 0.0
            shrinks = 0
            for op in w.ops:
                c = be.op_cost(op, mode=mode, allocation_bypass=False,
                               rinse=False)
                stall = max(stall, c.stall_frac)
                shrinks += be.plan_op(op, static_assignment(op, mode),
                                      allocation_bypass=False).shrink_events
            rows.append({
                "name": f"fig8/{name}/{mode.value}",
                "stall_frac": stall,
                "shrink_events": shrinks,
            })
    return rows


def fig9_13_row_locality(backend=None):
    """HBM write-burst contiguity (DRAM row-hit analogue) per policy, and
    with rinsing enabled (paper Figs 9, 13)."""
    be = backend or _default_backend()
    rows = []
    for name, w in SUITE.items():
        for label, mode, ab, rinse in (
            ("uncached", StaticMode.UNCACHED, False, False),
            ("cacherw", StaticMode.CACHERW, False, False),
            ("cacherw_AB", StaticMode.CACHERW, True, False),
            ("cacherw_AB_CR", StaticMode.CACHERW, True, True),
        ):
            c = be.workload_cost(w.ops, mode=mode,
                                 allocation_bypass=ab, rinse=rinse,
                                 launches_per_op=0)
            rows.append({
                "name": f"fig9_13/{name}/{label}",
                "write_contiguity": c.write_contiguity,
            })
    return rows


def fig10_12_optimizations(backend=None):
    """The paper's headline (Figs 10-12): AB, +CR, +PCby vs best/worst
    static policy.  norm_time < ~1.0 means the adaptive stack matched or
    beat the best static configuration."""
    be = backend or _default_backend()
    rows = []
    for name, w in SUITE.items():
        stat = {
            m: be.workload_cost(w.ops, mode=m, launches_per_op=1)
            for m in STATIC
        }
        best = min(stat.values(), key=lambda c: c.t_total)
        worst = max(stat.values(), key=lambda c: c.t_total)
        variants = {
            "cacherw_AB": dict(mode=StaticMode.CACHERW,
                               allocation_bypass=True, rinse=False),
            "cacherw_AB_CR": dict(mode=StaticMode.CACHERW,
                                  allocation_bypass=True, rinse=True),
            "adaptive_PCby": dict(mode=StaticMode.ADAPTIVE),
        }
        for label, kw in variants.items():
            c = be.workload_cost(w.ops, launches_per_op=1, **kw)
            rows.append({
                "name": f"fig10_12/{name}/{label}",
                "norm_time_vs_best_static": c.t_total / max(best.t_total, 1e-30),
                "norm_time_vs_worst_static": c.t_total / max(worst.t_total, 1e-30),
                "dram_traffic_vs_best": c.hbm_bytes / max(best.hbm_bytes, 1e-30),
            })
    return rows


# Training iterations replayed by the launch-planning benchmark: Table 2's
# launch counts are per iteration, and the planning engine runs at steady
# state across iterations (where memoization pays), so a few iterations are
# the representative load.
REPLAY_ITERATIONS = 3


def replay_launch_planning(backend=None, iterations=REPLAY_ITERATIONS):
    """Per-launch planning replay over Table 2's kernel-launch counts.

    The adaptive engine plans at *every* kernel launch; the RNN suites
    launch one cell kernel 150-363x per training iteration and the
    composed model 130x.  The seed path re-runs characterize -> predict ->
    allocate -> cost from scratch per launch; the memoized pipeline plans
    each distinct op once and hits the PlanCache for the rest — this is
    the hot planning loop the serve-time engine runs."""
    be = backend or _default_backend()
    rows = []
    launch_plan = be.launch_plan
    for name, w in SUITE.items():
        total = 0.0
        ops, n_ops = w.ops, len(w.ops)
        n_launches = w.launches * iterations
        for i in range(n_launches):
            total += launch_plan(ops[i % n_ops])[1].t_total
        rows.append({
            "name": f"replay/{name}",
            "modeled_us": total / n_launches * 1e6,
            "launches": w.launches,
            "iterations": iterations,
        })
    return rows


def wall_time_small():
    """Measured CPU wall time for the runnable reduced workloads (sanity
    anchor for the model: relative op costs, not absolute TPU numbers)."""
    rows = []
    for name, w in SUITE.items():
        if w.runnable is None:
            continue
        # Each workload is a distinct callable; re-jitting per item is
        # the point, and compile cost is excluded by the warmup call.
        fn = jax.jit(w.runnable)  # repro-lint: disable=R002 -- per-workload callable, compile excluded via warmup
        key = jax.random.PRNGKey(0)
        fn(key).block_until_ready()           # compile
        t0 = time.perf_counter()
        n = 5
        for _ in range(n):
            fn(key).block_until_ready()
        dt = (time.perf_counter() - t0) / n
        rows.append({"name": f"wall/{name}", "us_per_call": dt * 1e6})
    return rows


def characterization_table(backend=None):
    rows = []
    for name, w in SUITE.items():
        for r in op_table(w.ops)[:1]:
            rows.append({"name": f"ops/{name}", **{
                k: v for k, v in r.items() if k != "name"
            }})
    return rows


ANALYTIC_FIGURES = (
    fig4_5_characterization,
    fig6_7_policy_sweep,
    fig8_stalls,
    fig9_13_row_locality,
    fig10_12_optimizations,
    replay_launch_planning,
    characterization_table,
)


def analytic_rows(backend) -> list[dict]:
    """Every analytic (modeled, non-measured) figure through one backend."""
    rows = []
    for fn in ANALYTIC_FIGURES:
        rows.extend(fn(backend))
    return rows
