"""One benchmark per paper table/figure (Figs 4-13), evaluated with the
calibrated gem5-APU chip model + measured CPU wall time for the runnable
reduced configs.

Each function returns CSV-ready rows; ``benchmarks.run`` prints them.
"""
from __future__ import annotations

import time

import jax

from repro import hw
from repro.core.characterize import classify_workload, op_table
from repro.core.cost_model import op_cost, workload_cost
from repro.core.policy import StaticMode
from repro.workloads.suite import SUITE

GPU = hw.PAPER_GPU
STATIC = (StaticMode.UNCACHED, StaticMode.CACHER, StaticMode.CACHERW)


def fig4_5_characterization():
    """GVOPS / memory-requests-per-second analogue: per-workload compute and
    memory demand under CacheR (paper Figs 4-5)."""
    rows = []
    for name, w in SUITE.items():
        c = workload_cost(w.ops, mode=StaticMode.CACHER, chip=GPU,
                          launches_per_op=0)
        flops = sum(op.flops for op in w.ops)
        rows.append({
            "name": f"fig4_5/{name}",
            "gflops_per_s": flops / max(c.t_total, 1e-12) / 1e9,
            "gmem_reqs_per_s": c.hbm_bytes / 64 / max(c.t_total, 1e-12) / 1e9,
            "class": classify_workload(w.ops, chip=GPU).value,
        })
    return rows


def fig6_7_policy_sweep():
    """Execution time + DRAM traffic per static policy, normalized to
    Uncached (paper Figs 6-7)."""
    rows = []
    for name, w in SUITE.items():
        base = workload_cost(w.ops, mode=StaticMode.UNCACHED, chip=GPU,
                             launches_per_op=1)
        for mode in STATIC:
            c = workload_cost(w.ops, mode=mode, chip=GPU, launches_per_op=1)
            rows.append({
                "name": f"fig6_7/{name}/{mode.value}",
                "norm_time": c.t_total / max(base.t_total, 1e-30),
                "norm_dram_traffic": c.hbm_bytes / max(base.hbm_bytes, 1e-30),
            })
    return rows


def fig8_stalls():
    """Cache-stall proxy per policy (paper Fig 8): modeled stall fraction
    plus allocator shrink events (blocking baseline)."""
    from repro.core.allocator import plan_op
    from repro.core.policy import static_assignment

    rows = []
    for name, w in SUITE.items():
        for mode in (StaticMode.CACHER, StaticMode.CACHERW):
            stall = 0.0
            shrinks = 0
            for op in w.ops:
                c = op_cost(op, mode=mode, chip=GPU, allocation_bypass=False,
                            rinse=False)
                stall = max(stall, c.stall_frac)
                shrinks += plan_op(op, static_assignment(op, mode), chip=GPU,
                                   allocation_bypass=False).shrink_events
            rows.append({
                "name": f"fig8/{name}/{mode.value}",
                "stall_frac": stall,
                "shrink_events": shrinks,
            })
    return rows


def fig9_13_row_locality():
    """HBM write-burst contiguity (DRAM row-hit analogue) per policy, and
    with rinsing enabled (paper Figs 9, 13)."""
    rows = []
    for name, w in SUITE.items():
        for label, mode, ab, rinse in (
            ("uncached", StaticMode.UNCACHED, False, False),
            ("cacherw", StaticMode.CACHERW, False, False),
            ("cacherw_AB", StaticMode.CACHERW, True, False),
            ("cacherw_AB_CR", StaticMode.CACHERW, True, True),
        ):
            c = workload_cost(w.ops, mode=mode, chip=GPU,
                              allocation_bypass=ab, rinse=rinse,
                              launches_per_op=0)
            rows.append({
                "name": f"fig9_13/{name}/{label}",
                "write_contiguity": c.write_contiguity,
            })
    return rows


def fig10_12_optimizations():
    """The paper's headline (Figs 10-12): AB, +CR, +PCby vs best/worst
    static policy.  norm_time < ~1.0 means the adaptive stack matched or
    beat the best static configuration."""
    rows = []
    for name, w in SUITE.items():
        stat = {
            m: workload_cost(w.ops, mode=m, chip=GPU, launches_per_op=1)
            for m in STATIC
        }
        best = min(stat.values(), key=lambda c: c.t_total)
        worst = max(stat.values(), key=lambda c: c.t_total)
        variants = {
            "cacherw_AB": dict(mode=StaticMode.CACHERW,
                               allocation_bypass=True, rinse=False),
            "cacherw_AB_CR": dict(mode=StaticMode.CACHERW,
                                  allocation_bypass=True, rinse=True),
            "adaptive_PCby": dict(mode=StaticMode.ADAPTIVE),
        }
        for label, kw in variants.items():
            c = workload_cost(w.ops, chip=GPU, launches_per_op=1, **kw)
            rows.append({
                "name": f"fig10_12/{name}/{label}",
                "norm_time_vs_best_static": c.t_total / max(best.t_total, 1e-30),
                "norm_time_vs_worst_static": c.t_total / max(worst.t_total, 1e-30),
                "dram_traffic_vs_best": c.hbm_bytes / max(best.hbm_bytes, 1e-30),
            })
    return rows


def wall_time_small():
    """Measured CPU wall time for the runnable reduced workloads (sanity
    anchor for the model: relative op costs, not absolute TPU numbers)."""
    rows = []
    for name, w in SUITE.items():
        if w.runnable is None:
            continue
        fn = jax.jit(w.runnable)
        key = jax.random.PRNGKey(0)
        fn(key).block_until_ready()           # compile
        t0 = time.perf_counter()
        n = 5
        for _ in range(n):
            fn(key).block_until_ready()
        dt = (time.perf_counter() - t0) / n
        rows.append({"name": f"wall/{name}", "us_per_call": dt * 1e6})
    return rows


def characterization_table():
    rows = []
    for name, w in SUITE.items():
        for r in op_table(w.ops)[:1]:
            rows.append({"name": f"ops/{name}", **{
                k: v for k, v in r.items() if k != "name"
            }})
    return rows
