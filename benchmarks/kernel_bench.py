"""Kernel microbenchmarks: CPU wall time of the jitted XLA-path ops and the
modeled v5e time per policy (the TPU target numbers come from the roofline
model; CPU wall time anchors relative costs only).

Modeled queries route through the memoized planner (``plan_cache``), so the
per-shape policy ablation shares plans with the engine's own planning."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import StaticMode, make_engine
from repro.core.characterize import attention_op, matmul_op


def _time(fn, *args, n=5):
    y = fn(*args)
    jax.tree_util.tree_map(lambda a: a.block_until_ready(), y)
    t0 = time.perf_counter()
    for _ in range(n):
        y = fn(*args)
        jax.tree_util.tree_map(lambda a: a.block_until_ready(), y)
    return (time.perf_counter() - t0) / n


def matmul_policy_ablation(plan_cache=None):
    """Modeled v5e time for a training GEMM under each policy + the
    engine's plan (paper technique applied to the TPU kernel)."""
    rows = []
    eng = make_engine(plan_cache=plan_cache)
    for (m, k, n) in [(4096, 4096, 4096), (8192, 8192, 1024),
                      (512, 8192, 51200)]:
        op = matmul_op(m, k, n, dtype="bf16")
        for mode in (StaticMode.UNCACHED, StaticMode.CACHER,
                     StaticMode.CACHERW):
            c = eng.planner.cost(op, mode=mode)
            rows.append({
                "name": f"kern_mm/{m}x{k}x{n}/{mode.value}",
                "modeled_us": c.t_total * 1e6,
                "hbm_mb": c.hbm_bytes / 1e6,
            })
        plan = eng.plan_op(op)
        c = eng.cost(op, plan)
        rows.append({
            "name": f"kern_mm/{m}x{k}x{n}/engine",
            "modeled_us": c.t_total * 1e6,
            "hbm_mb": c.hbm_bytes / 1e6,
            "vmem_mb": plan.vmem_bytes / 1e6,
        })
    return rows


def attention_policy_ablation(plan_cache=None):
    rows = []
    eng = make_engine(plan_cache=plan_cache)
    for (b, hq, hkv, s, d) in [(8, 32, 4, 4096, 128), (1, 32, 8, 32768, 128)]:
        op = attention_op(b, hq, hkv, s, s, d)
        plan = eng.plan_op(op)
        for mode in (StaticMode.UNCACHED, StaticMode.CACHERW):
            c = eng.planner.cost(op, mode=mode)
            rows.append({
                "name": f"kern_attn/b{b}h{hq}s{s}/{mode.value}",
                "modeled_us": c.t_total * 1e6,
                "hbm_mb": c.hbm_bytes / 1e6,
            })
        c = eng.cost(op, plan)
        rows.append({
            "name": f"kern_attn/b{b}h{hq}s{s}/engine",
            "modeled_us": c.t_total * 1e6,
            "hbm_mb": c.hbm_bytes / 1e6,
            "blocks": str(plan.block),
        })
    return rows


def decode_attention_ablation(contexts=(256, 512, 1024), page=16):
    """Paged decode attention across resident-context lengths: xla
    ``_sdpa`` over the gathered view vs gather + dense split-KV kernel vs
    the paged kernel reading the pool in place.

    Wall times (CPU, kernels in interpret mode) anchor relative cost only;
    the modeled column is the v5e HBM roofline story and the acceptance
    gate: the gather path pays the full resident-context KV stream three
    times per step (read pool, write dense copy, read dense copy in the
    kernel) where the paged path reads each mapped page exactly once — so
    the modeled advantage must GROW with resident context (asserted), and
    paged-vs-gather bit-identity is asserted on every shape.
    """
    import numpy as np

    from repro import hw
    from repro.kernels.decode_attention import ops
    from repro.models import common as cm

    b, hq, hkv, d = 2, 8, 2, 64

    def xla_path(q, kp, vp, pg, ln):
        kd = cm.gather_pages(kp, pg)
        vd = cm.gather_pages(vp, pg)
        return cm._sdpa(q[:, None], kd, vd, causal=True, q_offset=ln - 1,
                        kv_len=ln)[:, 0]

    xla_jit = jax.jit(xla_path)

    rows, advantages, identity_pairs = [], [], []
    for t in contexts:
        P = t // page
        n_pages = b * P
        ks = jax.random.split(jax.random.PRNGKey(t), 4)
        q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
        k_pool = jax.random.normal(ks[1], (n_pages, page, hkv, d),
                                   jnp.float32)
        v_pool = jax.random.normal(ks[2], (n_pages, page, hkv, d),
                                   jnp.float32)
        perm = jax.random.permutation(ks[3], n_pages)[: b * P]
        pages = perm.reshape(b, P).astype(jnp.int32)
        lengths = jnp.asarray([t, t - page // 2], jnp.int32)
        splits = ops.plan_splits(t, page)

        def gather_kernel(q, kp, vp, pg, ln, s=splits):
            kd = jnp.swapaxes(cm.gather_pages(kp, pg), 1, 2)
            vd = jnp.swapaxes(cm.gather_pages(vp, pg), 1, 2)
            return ops.decode_attention(q, kd, vd, ln, bkv=page, splits=s)

        def paged_kernel(q, kp, vp, pg, ln, s=splits):
            return ops.paged_decode_attention(q, kp, vp, pg, ln, splits=s)

        args = (q, k_pool, v_pool, pages, lengths)
        fns = {"xla_sdpa": xla_jit, "gather_kernel": gather_kernel,
               "paged_kernel": paged_kernel}
        wall = {name: _time(fn, *args, n=3) * 1e6
                for name, fn in fns.items()}
        identity_pairs.append(
            (t, fns["paged_kernel"](*args), fns["gather_kernel"](*args))
        )

        # v5e roofline, per decode step: the KV stream is t*hkv*d*2 bytes
        # per side; gather reads the pool, writes the dense copy, and the
        # kernel reads the copy back — 3 passes.  Paged reads the pool
        # once.  Fixed per-step bytes (q, output, partials) are shared.
        kv_bytes = 2 * b * t * hkv * d * 4            # K and V, fp32
        fixed = (2 * b * hq * d * 4                   # q in, out
                 + 3 * b * hq * splits * (d + 2) * 4)  # (acc, m, l) partials
        gather_us = hw.hbm_time(3 * kv_bytes + fixed) * 1e6
        paged_us = hw.hbm_time(kv_bytes + fixed) * 1e6
        advantage = gather_us / paged_us
        advantages.append(advantage)
        rows.append({
            "name": f"kern_decode/t{t}",
            "us_per_call": wall["paged_kernel"],
            "xla_us": wall["xla_sdpa"],
            "gather_kernel_us": wall["gather_kernel"],
            "modeled_gather_us": gather_us,
            "modeled_paged_us": paged_us,
            "modeled_advantage": advantage,
            "gather_copy_mb_per_step": kv_bytes / 1e6,
            "splits": splits,
        })
    # The in-place page dereference must change nothing vs the gather
    # contract (clamp-to-page-0-then-mask) — the CI identity gate.  One
    # batched device_get for every context's pair.
    for t, paged_out, gather_out in jax.device_get(identity_pairs):
        assert np.array_equal(paged_out, gather_out), (
            f"paged kernel != gather path at t={t}"
        )
    assert all(a2 > a1 for a1, a2 in zip(advantages, advantages[1:])), (
        f"paged advantage must grow with resident context: {advantages}"
    )
    return rows


def xla_wall_times():
    """Wall time of the pure-XLA model ops on CPU (small shapes)."""
    rows = []
    from repro.models import common as cm

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 64, 8, 64), jnp.float32)
    k = jax.random.normal(key, (2, 512, 2, 64), jnp.float32)
    v = jax.random.normal(key, (2, 512, 2, 64), jnp.float32)

    naive = jax.jit(lambda q, k, v: cm._sdpa_naive(q, k, v, True, 0))
    chunk = jax.jit(lambda q, k, v: cm._sdpa_chunked(q, k, v, True, 0,
                                                     chunk=128))
    rows.append({"name": "xla/sdpa_naive",
                 "us_per_call": _time(naive, q, k, v) * 1e6})
    rows.append({"name": "xla/sdpa_chunked",
                 "us_per_call": _time(chunk, q, k, v) * 1e6})
    return rows
