"""Kernel microbenchmarks: CPU wall time of the jitted XLA-path ops and the
modeled v5e time per policy (the TPU target numbers come from the roofline
model; CPU wall time anchors relative costs only).

Modeled queries route through the memoized planner (``plan_cache``), so the
per-shape policy ablation shares plans with the engine's own planning."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import StaticMode, make_engine
from repro.core.characterize import attention_op, matmul_op


def _time(fn, *args, n=5):
    y = fn(*args)
    jax.tree_util.tree_map(lambda a: a.block_until_ready(), y)
    t0 = time.perf_counter()
    for _ in range(n):
        y = fn(*args)
        jax.tree_util.tree_map(lambda a: a.block_until_ready(), y)
    return (time.perf_counter() - t0) / n


def matmul_policy_ablation(plan_cache=None):
    """Modeled v5e time for a training GEMM under each policy + the
    engine's plan (paper technique applied to the TPU kernel)."""
    rows = []
    eng = make_engine(plan_cache=plan_cache)
    for (m, k, n) in [(4096, 4096, 4096), (8192, 8192, 1024),
                      (512, 8192, 51200)]:
        op = matmul_op(m, k, n, dtype="bf16")
        for mode in (StaticMode.UNCACHED, StaticMode.CACHER,
                     StaticMode.CACHERW):
            c = eng.planner.cost(op, mode=mode)
            rows.append({
                "name": f"kern_mm/{m}x{k}x{n}/{mode.value}",
                "modeled_us": c.t_total * 1e6,
                "hbm_mb": c.hbm_bytes / 1e6,
            })
        plan = eng.plan_op(op)
        c = eng.cost(op, plan)
        rows.append({
            "name": f"kern_mm/{m}x{k}x{n}/engine",
            "modeled_us": c.t_total * 1e6,
            "hbm_mb": c.hbm_bytes / 1e6,
            "vmem_mb": plan.vmem_bytes / 1e6,
        })
    return rows


def attention_policy_ablation(plan_cache=None):
    rows = []
    eng = make_engine(plan_cache=plan_cache)
    for (b, hq, hkv, s, d) in [(8, 32, 4, 4096, 128), (1, 32, 8, 32768, 128)]:
        op = attention_op(b, hq, hkv, s, s, d)
        plan = eng.plan_op(op)
        for mode in (StaticMode.UNCACHED, StaticMode.CACHERW):
            c = eng.planner.cost(op, mode=mode)
            rows.append({
                "name": f"kern_attn/b{b}h{hq}s{s}/{mode.value}",
                "modeled_us": c.t_total * 1e6,
                "hbm_mb": c.hbm_bytes / 1e6,
            })
        c = eng.cost(op, plan)
        rows.append({
            "name": f"kern_attn/b{b}h{hq}s{s}/engine",
            "modeled_us": c.t_total * 1e6,
            "hbm_mb": c.hbm_bytes / 1e6,
            "blocks": str(plan.block),
        })
    return rows


def xla_wall_times():
    """Wall time of the pure-XLA model ops on CPU (small shapes)."""
    rows = []
    from repro.models import common as cm

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 64, 8, 64), jnp.float32)
    k = jax.random.normal(key, (2, 512, 2, 64), jnp.float32)
    v = jax.random.normal(key, (2, 512, 2, 64), jnp.float32)

    naive = jax.jit(lambda q, k, v: cm._sdpa_naive(q, k, v, True, 0))
    chunk = jax.jit(lambda q, k, v: cm._sdpa_chunked(q, k, v, True, 0,
                                                     chunk=128))
    rows.append({"name": "xla/sdpa_naive",
                 "us_per_call": _time(naive, q, k, v) * 1e6})
    rows.append({"name": "xla/sdpa_chunked",
                 "us_per_call": _time(chunk, q, k, v) * 1e6})
    return rows
