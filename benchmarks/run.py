"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is wall time
where measured, modeled microseconds where analytical; ``derived`` packs the
figure-specific metrics.

``--json out.json`` additionally emits a machine-readable record:

* ``rows``                 — every CSV row as a dict
* ``sweep_wall_s``         — wall time of the full analytic policy sweep
                             (17 workloads x modes x AB/rinse ablations +
                             kernel ablations) on the batched/memoized path
* ``seed_sweep_wall_s``    — the same queries through the seed per-query
                             pure-Python path (``--no-compare-seed`` skips)
* ``sweep_speedup``        — seed / fast
* ``plan_cache_hit_rate``  + full ``plan_cache`` / ``sweep_table`` counters
* ``serve_tok_s`` / ``serve_ttft_s`` / ``serve_queue_wait_s`` /
  ``host_syncs_per_token`` / ``seed_tok_s`` / ``serve_speedup`` — the
  device-resident chunked serve loop vs the seed per-token dispatch loop
  (``benchmarks.serve_bench``)
* ``serve_families`` — per-cache-family serve rows with paged-vs-
  contiguous bit-identity asserted where a KV cache exists
* ``serve_spec`` — speculative decode on the repeat-heavy smoke workload:
  acceptance rate, tokens per verify round, spec/non-spec throughput
  ratio, spec-vs-plain bit-identity asserted (greedy + seeded sampling)
* ``serve_prefix`` — prefix sharing on the many-slots-one-system-prompt
  workload: effective-capacity multiple (>= 2x asserted), suffix-only
  TTFT cut vs unshared paged, shared-vs-unshared bit-identity asserted
* ``serve_chaos`` — lifecycle robustness: forced preemptions under an
  undersized pool and a seeded fault-injected run, both asserted
  bit-identical to the fault-free run with zero leaked pages
* ``serve_decode_kernel`` — paged decode-attention kernel vs the gather
  path, asserted bit-identical across {qwen, zamba2} x {prefix sharing
  on/off} x {chaos off/on} with zero leaked pages
* ``serve_decode_context`` — tok/s vs resident-context length (xla vs
  paged kernel) with the v5e roofline-modeled advantage asserted to
  grow with context; ``kern_decode/*`` rows add the kernel-level
  ablation (xla vs gather+kernel vs paged)
* ``lint`` — the ``repro.lint`` static-analysis pass over src/,
  benchmarks/ and examples/ against the committed baseline:
  ``rules_run``, ``findings``, ``baseline_suppressed``, ``wall_s``

so BENCH_*.json files can track the planning-pipeline and serving perf
trajectories across PRs.  ``--analytic-only`` skips the measured (jit
wall-time) benchmarks including the serve loop — useful for CI smoke runs.
"""
from __future__ import annotations

import argparse
import json
import time


def _emit(rows, out):
    for r in rows:
        r = dict(r)
        name = r.pop("name")
        us = r.pop("us_per_call", r.pop("modeled_us", ""))
        derived = json.dumps(r, sort_keys=True) if r else ""
        print(f"{name},{us},{derived}")
        out.append({"name": name, "us_per_call": us, **r})


def _kernel_rows(plan_cache):
    from benchmarks import kernel_bench

    rows = list(kernel_bench.matmul_policy_ablation(plan_cache=plan_cache))
    rows.extend(kernel_bench.attention_policy_ablation(plan_cache=plan_cache))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write machine-readable results to PATH")
    ap.add_argument("--analytic-only", action="store_true",
                    help="skip measured (jit wall-time) benchmarks")
    ap.add_argument("--no-compare-seed", action="store_true",
                    help="skip timing the seed (unbatched) sweep path")
    ap.add_argument("--no-serve", action="store_true",
                    help="skip the serve-loop benchmark")
    ap.add_argument("--serve-chunk", type=int, default=16,
                    help="decode chunk size for the serve benchmark")
    ap.add_argument("--reps", type=int, default=5,
                    help="repetitions per timed sweep (best-of, noise guard)")
    args = ap.parse_args(argv)

    from benchmarks import figures, kernel_bench
    from repro.core.planner import PlanCache

    rows: list[dict] = []
    print("name,us_per_call,derived")

    # One-time numpy/einsum dispatch warmup so neither timed pass pays it.
    from repro.core.characterize import matmul_op
    from repro.core.sweep import sweep_ops

    sweep_ops([matmul_op(128, 128, 128)])

    # -- analytic sweep: batched + memoized path (timed, cold each rep) -----
    sweep_wall_s = None
    for _ in range(max(1, args.reps)):
        plan_cache = PlanCache()
        t0 = time.perf_counter()
        backend = figures.FastBackend(plan_cache=plan_cache)
        fast_rows = figures.analytic_rows(backend)
        dt = time.perf_counter() - t0
        sweep_wall_s = dt if sweep_wall_s is None else min(sweep_wall_s, dt)
    _emit(fast_rows, rows)

    t0 = time.perf_counter()
    _emit(_kernel_rows(plan_cache), rows)
    kernel_wall_s = time.perf_counter() - t0

    # -- the same queries through the seed path (timed, rows discarded) -----
    seed_sweep_wall_s = None
    if not args.no_compare_seed:
        for _ in range(max(1, args.reps)):
            t0 = time.perf_counter()
            figures.analytic_rows(figures.SeedBackend())
            dt = time.perf_counter() - t0
            seed_sweep_wall_s = (
                dt if seed_sweep_wall_s is None else min(seed_sweep_wall_s, dt)
            )

    # -- measured wall-time benchmarks --------------------------------------
    serve_summary = {}
    if not args.analytic_only:
        if not args.no_serve:
            from benchmarks import serve_bench

            serve_rows, serve_summary = serve_bench.serve_rows(
                chunk_size=args.serve_chunk, reps=max(1, args.reps)
            )
            _emit(serve_rows, rows)
            # Paged pool at 2.67x effective capacity (mixed long/short) +
            # cache-family breadth, asserting paged-vs-contiguous
            # bit-identity where a KV cache exists (AssertionError fails
            # the run — the CI serve-identity gate).
            paged_rows, paged_summary = serve_bench.paged_rows(
                chunk_size=args.serve_chunk, reps=max(1, args.reps)
            )
            _emit(paged_rows, rows)
            family_rows, family_summary = serve_bench.family_rows()
            _emit(family_rows, rows)
            # Speculative decode on the repeat-heavy workload: asserts
            # spec-vs-plain bit-identity (greedy + seeded sampling) and
            # the acceptance floor, reports the throughput ratio.
            spec_rows, spec_summary = serve_bench.spec_rows(
                reps=max(1, args.reps)
            )
            _emit(spec_rows, rows)
            # Prefix sharing on the shared-system-prompt workload:
            # asserts shared-vs-unshared bit-identity and the >= 2x
            # effective-capacity floor, reports the suffix-only TTFT cut.
            prefix_rows, prefix_summary = serve_bench.prefix_rows(
                reps=max(1, args.reps)
            )
            _emit(prefix_rows, rows)
            # Chaos/lifecycle: preemption + seeded fault injection must
            # stay bit-identical to the fault-free run and leak no pages.
            chaos_rows, chaos_summary = serve_bench.chaos_rows()
            _emit(chaos_rows, rows)
            # Crash recovery: every cache family crashes mid-flight and
            # restores bit-identically from snapshot + journal; the
            # corruption leg must detect, quarantine and heal.
            recovery_rows, recovery_summary = serve_bench.recovery_rows()
            _emit(recovery_rows, rows)
            # Adaptive cache policy: static/pinned/adaptive legs must be
            # bit-identical; adaptive must match the best static stance
            # on prefill work and clear the warm re-arrival TTFT floor.
            adaptive_rows, adaptive_summary = serve_bench.adaptive_rows(
                reps=max(1, args.reps)
            )
            _emit(adaptive_rows, rows)
            # Paged decode-attention kernel: paged-vs-gather bit-identity
            # across families x sharing x chaos, plus tok/s vs resident
            # context with the modeled advantage asserted to grow.
            dk_rows, dk_summary = serve_bench.decode_kernel_rows()
            _emit(dk_rows, rows)
            ctx_rows, ctx_summary = serve_bench.decode_context_rows()
            _emit(ctx_rows, rows)
            serve_summary = {**serve_summary, **paged_summary,
                             **family_summary, **spec_summary,
                             **prefix_summary, **chaos_summary,
                             **recovery_summary, **adaptive_summary,
                             **dk_summary, **ctx_summary}
        _emit(figures.wall_time_small(), rows)
        _emit(kernel_bench.xla_wall_times(), rows)
        # Decode-attention kernel ablation: xla vs gather+kernel vs paged
        # across resident-context lengths; asserts paged-vs-gather
        # bit-identity per shape and growing modeled advantage.
        _emit(kernel_bench.decode_attention_ablation(), rows)

    # -- static-analysis pass (perf/determinism invariants) ------------------
    import os

    from repro.lint import load_baseline, run_lint

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    baseline_path = os.path.join(repo_root, "lint_baseline.json")
    lint_result = run_lint(
        [os.path.join(repo_root, d)
         for d in ("src", "benchmarks", "examples")],
        baseline=(load_baseline(baseline_path)
                  if os.path.isfile(baseline_path) else None),
        root=repo_root,
    )
    lint_summary = {
        "rules_run": lint_result.rules_run,
        "findings": [f.to_dict() for f in lint_result.findings],
        "baseline_suppressed": lint_result.baseline_suppressed,
        "wall_s": lint_result.wall_s,
    }

    stats = backend.stats()
    summary = {
        "sweep_wall_s": sweep_wall_s,
        "kernel_wall_s": kernel_wall_s,
        "seed_sweep_wall_s": seed_sweep_wall_s,
        "sweep_speedup": (
            seed_sweep_wall_s / sweep_wall_s if seed_sweep_wall_s else None
        ),
        "plan_cache_hit_rate": stats["hit_rate"],
        "lint": lint_summary,
        **serve_summary,
        "plan_cache": {k: v for k, v in stats.items() if k != "sweep_table"},
        "sweep_table": stats["sweep_table"],
    }
    print(f"sweep_wall_s,{sweep_wall_s * 1e6:.1f},"
          + json.dumps({k: v for k, v in summary.items()
                        if k not in ("plan_cache", "sweep_table", "lint")}))
    print(f"lint,{lint_summary['wall_s'] * 1e6:.1f},"
          + json.dumps({"findings": len(lint_summary["findings"]),
                        "baseline_suppressed":
                            lint_summary["baseline_suppressed"],
                        "rules": len(lint_summary["rules_run"])}))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, **summary}, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
