"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is wall time
where measured, modeled microseconds where analytical; ``derived`` packs the
figure-specific metrics.
"""
from __future__ import annotations

import json


def _emit(rows):
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", r.pop("modeled_us", ""))
        derived = json.dumps(r, sort_keys=True) if r else ""
        print(f"{name},{us},{derived}")


def main() -> None:
    from benchmarks import figures, kernel_bench

    print("name,us_per_call,derived")
    _emit(figures.fig4_5_characterization())
    _emit(figures.fig6_7_policy_sweep())
    _emit(figures.fig8_stalls())
    _emit(figures.fig9_13_row_locality())
    _emit(figures.fig10_12_optimizations())
    _emit(figures.wall_time_small())
    _emit(figures.characterization_table())
    _emit(kernel_bench.matmul_policy_ablation())
    _emit(kernel_bench.attention_policy_ablation())
    _emit(kernel_bench.xla_wall_times())


if __name__ == "__main__":
    main()
