"""Reproduce the paper's characterization interactively: classify all 17 MI
workloads, sweep the static policies, and show the adaptive stack matching
the best static choice per workload (Figs 6/7/10).

Run:  PYTHONPATH=src python examples/policy_explorer.py [--chip tpu-v5e]
"""
import argparse

from repro import hw
from repro.core.characterize import classify_workload
from repro.core.cost_model import workload_cost
from repro.core.policy import StaticMode
from repro.workloads.suite import SUITE


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chip", choices=["gem5-apu", "tpu-v5e"],
                    default="gem5-apu")
    args = ap.parse_args()
    chip = hw.PAPER_GPU if args.chip == "gem5-apu" else hw.V5E

    print(f"chip: {chip.name}  peak={chip.peak_flops_bf16/1e12:.1f}TF "
          f"bw={chip.hbm_bw/1e9:.0f}GB/s residency={chip.vmem_bytes>>20}MB\n")
    hdr = (f"{'workload':10s} {'class':22s} "
           f"{'unc(ms)':>9s} {'cacheR':>9s} {'cacheRW':>9s} "
           f"{'adaptive':>9s} {'traffic cut':>11s}")
    print(hdr)
    print("-" * len(hdr))
    wins = 0
    for name, w in SUITE.items():
        cls = classify_workload(w.ops, chip=chip)
        t = {m: workload_cost(w.ops, mode=m, chip=chip, launches_per_op=1)
             for m in StaticMode}
        best = min(t[m].t_total for m in
                   (StaticMode.UNCACHED, StaticMode.CACHER,
                    StaticMode.CACHERW))
        cut = 1 - (t[StaticMode.CACHERW].hbm_bytes
                   / max(t[StaticMode.UNCACHED].hbm_bytes, 1e-30))
        ok = t[StaticMode.ADAPTIVE].t_total <= best * 1.05
        wins += ok
        print(f"{name:10s} {cls.value:22s} "
              f"{t[StaticMode.UNCACHED].t_total*1e3:9.3f} "
              f"{t[StaticMode.CACHER].t_total*1e3:9.3f} "
              f"{t[StaticMode.CACHERW].t_total*1e3:9.3f} "
              f"{t[StaticMode.ADAPTIVE].t_total*1e3:9.3f} "
              f"{cut*100:10.0f}% {'✓' if ok else '✗'}")
    print(f"\nadaptive matches best static on {wins}/{len(SUITE)} workloads "
          f"(paper §VII: 'matches or exceeds ... for nearly all workloads')")


if __name__ == "__main__":
    main()
