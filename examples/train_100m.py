"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
CPU, with the full production stack — policy-engine remat, WSD schedule,
async checkpointing, preemption-safe fault-tolerant loop, deterministic
resume.

Run:  PYTHONPATH=src python examples/train_100m.py --steps 200
Kill it mid-run (Ctrl-C) and re-run: it resumes exactly.
"""
import argparse
import dataclasses

import jax

from repro.configs.base import ModelConfig
from repro.core import make_engine
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.train import loop as train_loop
from repro.train import optimizer as opt
from repro.train.step import TrainConfig, init_train_state, make_train_step
from repro.utils import tree_param_count

CFG_100M = ModelConfig(
    arch="repro-100m", family="dense", n_layers=8, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=2048, vocab=32000, head_dim=64,
    vocab_pad_multiple=256, dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_100m")
    args = ap.parse_args()

    cfg = CFG_100M
    model = build_model(cfg)
    engine = make_engine()
    # Trainer-level policy decision: activation residency from HBM budget.
    act_bytes = args.batch * args.seq * cfg.d_model * 4 * 8
    remat = engine.remat_policy(act_bytes, cfg.n_layers)
    print(f"policy engine chose remat={remat.value}")

    tcfg = TrainConfig(
        adamw=opt.AdamWConfig(
            lr=3e-4, warmup_steps=20, total_steps=args.steps, schedule="wsd"
        ),
        remat=remat,
        batch_axes=(),
    )
    train_step, _ = make_train_step(cfg, tcfg)
    train_step = jax.jit(train_step, donate_argnums=(0,))
    state = init_train_state(model, jax.random.PRNGKey(0))
    print(f"params: {tree_param_count(state['params'])/1e6:.1f}M")

    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq, seed=1234)
    lcfg = train_loop.LoopConfig(
        total_steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir,
        log_every=10,
    )

    def on_step(step, metrics):
        if step % lcfg.log_every == 0:
            print(f"step {step}: loss={float(metrics['loss']):.4f}")

    state, report = train_loop.run(train_step, state, data, lcfg,
                                   on_step=on_step)
    print(f"done at step {report.final_step}; "
          f"resumed_from={report.resumed_from} "
          f"preempted={report.preempted} "
          f"stragglers={report.straggler_steps}")
    print(f"loss: {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
