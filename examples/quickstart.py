"""Quickstart: the paper's adaptive memory-policy engine in 60 seconds.

1. Characterize ops analytically (reuse, windows, intensity).
2. Let the engine plan VMEM policies (PCby + allocation bypass + rinse).
3. Train a tiny model a few steps with the policy-driven train step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import StaticMode, make_engine
from repro.core.characterize import (
    attention_op,
    classify_workload,
    elementwise_op,
    matmul_op,
)
from repro.core.cost_model import workload_cost
from repro.data.pipeline import SyntheticLM
from repro.models import get_config
from repro.train import optimizer as opt
from repro.train.step import TrainConfig, init_train_state, make_train_step


def main():
    engine = make_engine()  # TPU v5e target, adaptive mode

    print("=== per-op policy plans (the paper's technique) ===")
    ops = [
        matmul_op(4096, 4096, 4096, name="train GEMM"),
        attention_op(8, 32, 4, 4096, 4096, 128, name="GQA attention"),
        elementwise_op(1 << 28, name="activation (no reuse)"),
    ]
    for op in ops:
        plan = engine.plan_op(op)
        cost = engine.cost(op, plan)
        print(f"{op.name:24s} class={classify_workload([op]).value:22s} "
              f"policies={{ {', '.join(f'{k}:{v.value}' for k, v in plan.assignment.items())} }} "
              f"blocks={plan.block} modeled={cost.t_total*1e6:.0f}us")

    print("\n=== adaptive vs static (modeled, v5e) ===")
    for mode in StaticMode:
        t = workload_cost(ops, mode=mode).t_total
        print(f"{mode.value:10s} {t*1e3:8.3f} ms")

    print("\n=== train a smoke model 5 steps ===")
    cfg = get_config("yi-9b", smoke=True)
    tcfg = TrainConfig(adamw=opt.AdamWConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=100))
    train_step, model = make_train_step(cfg, tcfg)
    train_step = jax.jit(train_step, donate_argnums=(0,))
    state = init_train_state(model, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, batch=4, seq=32, seed=0)
    for step in range(5):
        state, metrics = train_step(state, data(step))
        # One batched transfer instead of two scalar pulls; the demo
        # prints per-step metrics by design, so the per-step sync stays.
        loss, lr = jax.device_get((metrics["loss"], metrics["lr"]))  # repro-lint: disable=R001 -- demo prints per-step metrics
        print(f"step {step}: loss={float(loss):.4f} lr={float(lr):.2e}")


if __name__ == "__main__":
    main()
