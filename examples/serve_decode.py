"""Serve a small model with batched requests through the device-resident
continuous-batching engine, with the engine's KV policy decisions printed.

The first run through the engine pays jit compilation for the prefill and
the chunked decode loop; timing that run reports compile time, not serving
throughput.  We warm up first, then time a fresh request wave on the same
(already-compiled) engine and report both TTFT and steady-state tok/s.

``--paged`` serves the same wave through the paged KV pool (half the
contiguous reservation) and checks the outputs are identical.

``--spec`` serves the same wave through the speculative engine (n-gram
draft + chunked verification), checks the outputs are identical, and
prints the draft acceptance rate.  On the tiny smoke model per-eval
compute is negligible, so the interesting numbers here are acceptance and
tokens/round — the throughput win shows up at serving-scale dims
(``benchmarks.serve_bench.spec_rows``).

``--shared-prefix`` serves a many-slots-one-system-prompt wave through
the paged pool with prefix sharing on and off: attached requests ride the
resident system-prompt pages (refcounted; prefilled once) and the shared
engine holds far fewer pages at its peak, with identical outputs.

``--deadline-s S`` attaches a per-request deadline to a long-budget wave:
requests that blow it are expired mid-stream (partial tokens kept, slot
and pages freed) and counted against goodput-under-deadline.

``--cancel`` cancels one resident request mid-stream after the first
decode chunk: the engine retires it at the next chunk boundary, keeps the
tokens already emitted, and the rest of the wave is unaffected.

``--crash`` demonstrates crash-safe serving (DESIGN.md §5.6): a
journal-armed engine snapshots mid-wave and then dies on an injected
``ChaosCrash``; a FRESH engine restores from the snapshot + journal
suffix, finishes the wave, and the streams are bit-identical to an
uninterrupted run with zero leaked pages.

Run:  PYTHONPATH=src python examples/serve_decode.py
          [--paged] [--spec] [--shared-prefix] [--deadline-s S] [--cancel]
          [--crash]
"""
import dataclasses
import os
import sys
import tempfile
import time

import jax
import numpy as np

from repro.core import make_engine
from repro.models import build_model, get_config
from repro.serve.engine import Request, ServeEngine


def make_requests(cfg, rng, n_tokens=12):
    return [
        Request(prompt=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                max_new_tokens=n_tokens)
        for n in (5, 8, 3, 6, 9, 4)
    ]


def main():
    cfg = get_config("qwen2.5-32b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    engine = make_engine()
    kv_bytes = 2 * cfg.n_kv_heads * cfg.head_dim_ * 64 * 2
    print(f"KV policy for {kv_bytes}B/layer cache:",
          engine.kv_policy(kv_bytes).value)

    serve = ServeEngine(cfg, params, batch_slots=4, max_len=64, chunk_size=8)
    rng = np.random.default_rng(0)

    # Warm-up: compiles prefill + chunked decode (not timed).
    t0 = time.perf_counter()
    serve.run(make_requests(cfg, rng))
    print(f"warm-up (includes jit compile): {time.perf_counter() - t0:.2f}s")

    # Timed: steady-state serving on the compiled engine.
    reqs = make_requests(cfg, rng)
    base_stats = dict(serve.stats)
    t0 = time.perf_counter()
    serve.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in reqs)
    ttft = np.mean([r.ttft_s for r in reqs])
    syncs = serve.stats["host_syncs"] - base_stats["host_syncs"]
    print(f"generated {total} tokens across {len(reqs)} requests in {dt:.3f}s")
    print(f"steady-state: {total / dt:.0f} tok/s, mean TTFT {ttft * 1e3:.1f}ms, "
          f"{syncs} host syncs ({syncs / total:.3f}/token)")
    for i, r in enumerate(reqs):
        print(f"req{i}: prompt[{len(r.prompt)}] -> {r.generated}")

    if "--paged" in sys.argv:
        # Same wave through the paged pool at half the contiguous
        # reservation (4 slots x 64 = 256 positions -> 8 pages x 16 = 128).
        paged_cfg = dataclasses.replace(
            cfg, cache_layout="paged", kv_page_size=16
        )
        pserve = ServeEngine(paged_cfg, params, batch_slots=4, max_len=64,
                             chunk_size=8, n_pages=8)
        prng = np.random.default_rng(0)       # replays the contiguous waves
        pserve.run(make_requests(cfg, prng))  # warm-up (same first wave)
        preqs = make_requests(cfg, prng)      # same prompts as timed `reqs`
        t0 = time.perf_counter()
        pserve.run(preqs)
        dt = time.perf_counter() - t0
        ptotal = sum(len(r.generated) for r in preqs)
        print(f"paged pool (128/256 positions): {ptotal / dt:.0f} tok/s")
        assert all(a.generated == b.generated for a, b in zip(reqs, preqs))
        print("paged == contiguous: True")

    if "--shared-prefix" in sys.argv:
        # Many slots, one system prompt: the prefix-hit workload.  With
        # sharing, the 32-token system prompt (2 pages of 16) prefills
        # once; every later admission attaches to its resident pages and
        # prefills only the few-token user tail.
        rng2 = np.random.default_rng(1)
        sys_p = rng2.integers(0, cfg.vocab, size=32).astype(np.int32)

        def sys_requests():
            r = np.random.default_rng(2)
            return [
                Request(prompt=np.concatenate(
                    [sys_p, r.integers(0, cfg.vocab, size=n).astype(np.int32)]),
                    max_new_tokens=12)
                for n in (5, 8, 3, 6, 9, 4)
            ]

        paged_cfg = dataclasses.replace(
            cfg, cache_layout="paged", kv_page_size=16
        )
        outs = {}
        for name, c in (
            ("unshared", paged_cfg),
            ("shared", dataclasses.replace(paged_cfg, prefix_sharing=True)),
        ):
            xeng = ServeEngine(c, params, batch_slots=4, max_len=64,
                               chunk_size=8)
            xreqs = sys_requests()
            xeng.run(xreqs)
            outs[name] = [r.generated for r in xreqs]
            stats = xeng.serve_stats()
            print(f"{name}: peak {xeng.stats['peak_pages_held']}/"
                  f"{xeng.n_pages} pages held, "
                  f"{stats['prefix_hits']} prefix hits "
                  f"({stats['prefix_tokens_shared']} prompt tokens attached "
                  "from resident pages)")
        assert outs["shared"] == outs["unshared"]
        print("shared prefix == unshared: True")

    if "--spec" in sys.argv:
        # Same wave through the speculative path: n-gram drafts verified in
        # chunks of spec_k + 1, rejected suffixes rolled back per slot.
        spec_cfg = dataclasses.replace(cfg, spec_k=4, spec_ngram=3)
        sserve = ServeEngine(spec_cfg, params, batch_slots=4, max_len=64,
                             chunk_size=10)
        srng = np.random.default_rng(0)       # replays the contiguous waves
        sserve.run(make_requests(cfg, srng))  # warm-up (same first wave)
        sreqs = make_requests(cfg, srng)      # same prompts as timed `reqs`
        t0 = time.perf_counter()
        sserve.run(sreqs)
        dt = time.perf_counter() - t0
        stotal = sum(len(r.generated) for r in sreqs)
        stats = sserve.serve_stats()
        print(f"speculative (k=4): {stotal / dt:.0f} tok/s, "
              f"acceptance {stats['spec_acceptance_rate']:.2f}, "
              f"{stats['spec_tokens_per_round']:.2f} tokens/round")
        assert all(a.generated == b.generated for a, b in zip(reqs, sreqs))
        print("speculative == plain: True")

    deadline = None
    if "--deadline-s" in sys.argv:
        deadline = float(sys.argv[sys.argv.index("--deadline-s") + 1])
    if deadline is not None or "--cancel" in sys.argv:
        # Lifecycle demo (DESIGN.md §5.5): submit/step/cancel/drain by
        # hand instead of run(), since cancellation is a mid-stream act.
        lrng = np.random.default_rng(5)
        lreqs = [
            Request(prompt=lrng.integers(0, cfg.vocab, size=n)
                    .astype(np.int32),
                    max_new_tokens=24, deadline_s=deadline, id=f"demo-{i}")
            for i, n in enumerate((5, 8, 3, 6))
        ]
        leng = ServeEngine(cfg, params, batch_slots=2, max_len=64,
                           chunk_size=4)
        leng.submit(lreqs)
        leng.step()                        # admit first wave + one chunk
        if "--cancel" in sys.argv:
            victim = next(r for r in lreqs if r.status == "resident")
            assert leng.cancel(victim.id)
            print(f"cancel({victim.id}) requested mid-stream "
                  f"({len(victim.generated)} tokens emitted so far)")
        leng.drain()
        print("lifecycle:", {r.id: f"{r.status}[{len(r.generated)}]"
                             for r in lreqs})
        print(f"cancelled={leng.stats['cancelled']} "
              f"expired={leng.stats['expired']} "
              "goodput_under_deadline="
              f"{leng.serve_stats()['goodput_under_deadline']:.2f}")

    if "--crash" in sys.argv:
        # Crash-safe serving demo (DESIGN.md §5.6).  Reference: the same
        # wave served uninterrupted through a paged engine.
        from repro.serve.chaos import ChaosCrash

        paged_cfg = dataclasses.replace(
            cfg, cache_layout="paged", kv_page_size=16
        )
        crng = np.random.default_rng(0)
        ref_eng = ServeEngine(paged_cfg, params, batch_slots=4, max_len=64,
                              chunk_size=8, n_pages=8)
        ref_eng.run(make_requests(cfg, crng))
        ref_out = ref_eng.results()

        tmp = tempfile.mkdtemp(prefix="serve-crash-demo-")
        jpath = os.path.join(tmp, "requests.jsonl")
        spath = os.path.join(tmp, "engine.json")
        crash_cfg = dataclasses.replace(paged_cfg, chaos_crash_after_wave=2)
        doomed = ServeEngine(crash_cfg, params, batch_slots=4, max_len=64,
                             chunk_size=8, n_pages=8, journal_path=jpath)
        crng = np.random.default_rng(0)
        wave = make_requests(cfg, crng)
        doomed.submit(wave[:4])
        doomed.step()
        info = doomed.snapshot(spath)
        print(f"snapshot: {info['requests']} request records "
              f"({info['in_flight']} in flight) -> {spath}")
        doomed.submit(wave[4:])            # journaled past the snapshot
        try:
            doomed.drain()
        except ChaosCrash as c:
            print(f"injected crash after admission wave {c.wave} "
                  "(journal flushed at the chunk boundary)")

        fresh = ServeEngine(paged_cfg, params, batch_slots=4, max_len=64,
                            chunk_size=8, n_pages=8, journal_path=jpath)
        rep = fresh.restore(spath)
        print(f"restore: {rep['restored']} re-queued, "
              f"{rep['replayed_events']} journal events replayed, "
              f"{rep['terminal']} already terminal")
        fresh.drain()
        assert fresh.results() == ref_out
        assert sorted(fresh.free_pages) == list(range(fresh.n_pages))
        print("recovered == uninterrupted: True (zero leaked pages)")


if __name__ == "__main__":
    main()
