"""Serve a small model with batched requests: prefill + continuous decode,
with the engine's KV policy decisions printed.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.core import make_engine
from repro.models import build_model, get_config
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("qwen2.5-32b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    engine = make_engine()
    kv_bytes = 2 * cfg.n_kv_heads * cfg.head_dim_ * 64 * 2
    print(f"KV policy for {kv_bytes}B/layer cache:",
          engine.kv_policy(kv_bytes).value)

    serve = ServeEngine(cfg, params, batch_slots=4, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                max_new_tokens=12)
        for n in (5, 8, 3, 6)
    ]
    t0 = time.perf_counter()
    serve.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in reqs)
    print(f"generated {total} tokens across {len(reqs)} requests "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s on CPU)")
    for i, r in enumerate(reqs):
        print(f"req{i}: prompt[{len(r.prompt)}] -> {r.generated}")


if __name__ == "__main__":
    main()
