"""Assemble the final EXPERIMENTS.md §Dry-run/§Roofline/§Perf from artifacts.

Run: PYTHONPATH=src python scripts/finalize_experiments.py
Appends/refreshes the dry-run sections after the reproduction section.
"""
import io
import sys

sys.path.insert(0, "src")

from repro.launch import report  # noqa: E402

MARK = "\n## §Dry-run"


def main():
    rows = report.load("artifacts/dryrun")
    buf = io.StringIO()
    n_single = len([r for r in rows if "pod" not in r["mesh"] and not r["_tag"]])
    n_multi = len([r for r in rows if "pod" in r["mesh"] and not r["_tag"]])
    buf.write(MARK + f" — {n_single} single-pod (16x16) + {n_multi} multi-pod "
              "(2x16x16) cells\n\n")
    buf.write(
        "Every cell is `jax.jit(step).lower(ShapeDtypeStructs).compile()` "
        "against the production mesh; `memory_analysis()` (fits column), "
        "`cost_analysis()` and the parsed collective schedule are recorded "
        "per cell in `artifacts/dryrun/*.json`.  Train cells report the "
        "auto-fit baseline config (knobs column); multi-pod cells are "
        "compile+memory proofs (roofline is single-pod per the brief).  "
        "The 8 nominal long_500k cells for pure full-attention archs are "
        "principled skips (DESIGN.md §5).\n\n"
    )
    buf.write(report.dryrun_table(rows))
    buf.write("\n\n## §Roofline (single-pod, TPU v5e constants)\n\n")
    buf.write(
        "Terms: t_compute = HLO_FLOPs/chip / 197e12; t_memory = "
        "HLO_bytes/chip / 819e9; t_collective = moved_bytes (ring factors "
        "applied per kind) / (4 x 50e9).  HLO FLOPs/bytes are "
        "trip-count-corrected by two-point extrapolation over unrolled "
        "reduced-depth lowers (XLA counts while bodies once).  "
        "MODEL_FLOPS = 6*N*D (train) / 2*N*D (serve), N = active params.  "
        "useful = MODEL_FLOPS / HLO_FLOPs; roofline frac = useful model "
        "FLOP throughput vs peak given the dominant bound.\n\n"
    )
    buf.write(report.roofline_table(rows, "single"))
    frac, coll = report.worst_cells(rows)
    buf.write("\n\nWorst roofline fractions: "
              + ", ".join(f"{r['arch']}x{r['shape']}" for r in frac))
    buf.write("\nMost collective-bound: "
              + ", ".join(f"{r['arch']}x{r['shape']}" for r in coll))
    buf.write("\n")

    with open("EXPERIMENTS.md") as f:
        txt = f.read()
    if MARK in txt:
        txt = txt[: txt.index(MARK)]
    with open("EXPERIMENTS.md", "w") as f:
        f.write(txt + buf.getvalue())
    print("EXPERIMENTS.md updated:",
          f"{n_single} single + {n_multi} multi cells")


if __name__ == "__main__":
    main()
