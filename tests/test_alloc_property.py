"""Property tests for the paged free-list allocator (DESIGN.md §5.2).

`serve.engine.PageAllocator` backs paged-KV admission: requests are
admitted only while their worst-case page count fits the free list, and
`_finish` returns pages.  Random alloc/free/finish interleavings must
never double-allocate a page, never leak one (free + held is always a
partition of the pool), and never over-commit (alloc yields None instead
of dipping below zero free pages) — the "admission never exceeds free
pages" gate.

Skips gracefully when hypothesis is absent (see requirements-dev.txt).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve.engine import PageAllocator  # noqa: E402

# An op is ("alloc", n_pages) or ("free", fraction-of-held-to-release);
# frees release a prefix of the live allocations (requests finishing).
_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(min_value=0, max_value=12)),
        st.tuples(st.just("free"), st.floats(min_value=0.0, max_value=1.0)),
    ),
    max_size=60,
)


def _check_partition(alloc: PageAllocator, live: list[list[int]]):
    free = alloc.free_pages
    held = [p for ids in live for p in ids]
    # No double allocation, inside or across requests.
    assert len(held) == len(set(held)), "page handed out twice"
    assert not set(free) & set(held), "page simultaneously free and held"
    # No leak: free + held is exactly the pool.
    assert sorted(free + held) == list(range(alloc.n_pages))
    assert alloc.held_pages == set(held)


@settings(max_examples=200, deadline=None)
@given(n_pages=st.integers(min_value=0, max_value=16), ops=_OPS)
def test_alloc_free_sequences_preserve_pool(n_pages, ops):
    alloc = PageAllocator(n_pages)
    live: list[list[int]] = []
    for op, arg in ops:
        if op == "alloc":
            before = alloc.free_count()
            ids = alloc.alloc(arg)
            if arg > before:
                # Admission gate: over-commit must refuse, not over-draw.
                assert ids is None
                assert alloc.free_count() == before
            else:
                assert ids is not None and len(ids) == arg
                live.append(ids)
        else:
            n_release = round(arg * len(live))
            for ids in live[:n_release]:
                alloc.free(ids)
            live = live[n_release:]
        _check_partition(alloc, live)
    # Draining everything restores the full pool.
    for ids in live:
        alloc.free(ids)
    _check_partition(alloc, [])
    assert sorted(alloc.free_pages) == list(range(n_pages))


@settings(max_examples=100, deadline=None)
@given(ops=_OPS)
def test_alloc_never_exceeds_free_pages(ops):
    """Re-admission pressure: total held can never exceed the pool, no
    matter the interleaving (the free list is the only admission token)."""
    alloc = PageAllocator(8)
    live: list[list[int]] = []
    for op, arg in ops:
        if op == "alloc":
            ids = alloc.alloc(arg)
            if ids is not None:
                live.append(ids)
        elif live:
            alloc.free(live.pop())
        assert sum(len(x) for x in live) + alloc.free_count() == 8
        assert sum(len(x) for x in live) <= 8


def test_free_rejects_unheld_pages():
    alloc = PageAllocator(4)
    ids = alloc.alloc(2)
    with pytest.raises(AssertionError, match="not held"):
        alloc.free([p for p in range(4) if p not in ids][:1])
    with pytest.raises(AssertionError, match="duplicate"):
        alloc.free([ids[0], ids[0]])   # same page twice in one call
    alloc.free(ids)
    with pytest.raises(AssertionError, match="not held"):
        alloc.free(ids)   # double free


def test_alloc_negative_rejected():
    with pytest.raises(ValueError):
        PageAllocator(4).alloc(-1)
