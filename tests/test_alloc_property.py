"""Property tests for the refcounted paged free-list allocator
(DESIGN.md §5.2, refcounts §5.4).

`serve.engine.PageAllocator` backs paged-KV admission: requests are
admitted only while their worst-case page count fits the free list, and
`_finish` drops references (`release`); prefix sharing adds references
(`share`) so a page frees only at refcount zero.  Random
alloc/share/release interleavings — driven by the hypothesis state
machine below — must never double-allocate a page, never free one while
references remain, conserve refcounts, never leak (free + held is always
a partition of the pool), and never over-commit (alloc yields None,
atomically, instead of dipping below zero free pages) — the "admission
never exceeds free pages" gate, with or without sharing.

KV integrity (DESIGN.md §5.6) adds ``quarantine``: a free page leaves
service immediately; a held page is doomed and diverts to quarantine at
its LAST release (never the free list).  The machines interleave
quarantines with everything else, so the partition invariant becomes
free + held + quarantined == pool with doomed ⊆ held throughout.

CI runs these under the derandomized ``ci`` hypothesis profile
(tests/conftest.py); skips gracefully when hypothesis is absent (see
requirements-dev.txt).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402
from hypothesis.stateful import (  # noqa: E402
    RuleBasedStateMachine,
    invariant,
    rule,
)

from repro.serve.engine import PageAllocator  # noqa: E402

# An op is ("alloc", n_pages) or ("free", fraction-of-held-to-release);
# frees release a prefix of the live allocations (requests finishing).
_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(min_value=0, max_value=12)),
        st.tuples(st.just("free"), st.floats(min_value=0.0, max_value=1.0)),
    ),
    max_size=60,
)


def _check_partition(alloc: PageAllocator, live: list[list[int]]):
    free = alloc.free_pages
    held = [p for ids in live for p in ids]
    # No double allocation, inside or across requests.
    assert len(held) == len(set(held)), "page handed out twice"
    assert not set(free) & set(held), "page simultaneously free and held"
    # No leak: free + held is exactly the pool.
    assert sorted(free + held) == list(range(alloc.n_pages))
    assert alloc.held_pages == set(held)


@settings(max_examples=200, deadline=None)
@given(n_pages=st.integers(min_value=0, max_value=16), ops=_OPS)
def test_alloc_free_sequences_preserve_pool(n_pages, ops):
    alloc = PageAllocator(n_pages)
    live: list[list[int]] = []
    for op, arg in ops:
        if op == "alloc":
            before = alloc.free_count()
            ids = alloc.alloc(arg)
            if arg > before:
                # Admission gate: over-commit must refuse, not over-draw.
                assert ids is None
                assert alloc.free_count() == before
            else:
                assert ids is not None and len(ids) == arg
                live.append(ids)
        else:
            n_release = round(arg * len(live))
            for ids in live[:n_release]:
                alloc.free(ids)
            live = live[n_release:]
        _check_partition(alloc, live)
    # Draining everything restores the full pool.
    for ids in live:
        alloc.free(ids)
    _check_partition(alloc, [])
    assert sorted(alloc.free_pages) == list(range(n_pages))


@settings(max_examples=100, deadline=None)
@given(ops=_OPS)
def test_alloc_never_exceeds_free_pages(ops):
    """Re-admission pressure: total held can never exceed the pool, no
    matter the interleaving (the free list is the only admission token)."""
    alloc = PageAllocator(8)
    live: list[list[int]] = []
    for op, arg in ops:
        if op == "alloc":
            ids = alloc.alloc(arg)
            if ids is not None:
                live.append(ids)
        elif live:
            alloc.free(live.pop())
        assert sum(len(x) for x in live) + alloc.free_count() == 8
        assert sum(len(x) for x in live) <= 8


# ---------------------------------------------------------------------------
# Refcounted sharing: hypothesis state machine (DESIGN.md §5.4)
# ---------------------------------------------------------------------------

_POOL = 12


class RefcountedAllocatorMachine(RuleBasedStateMachine):
    """Random alloc/share/release interleavings against a pure-Python
    refcount mirror.  ``handles`` holds one entry per outstanding
    reference-set (an allocation, or a sharer's alias of one); releasing
    a handle drops exactly one reference per page.

    Invariants checked after every step:

    * no page is freed while references remain (held ∩ free == ∅),
    * refcounts match the mirror exactly (conservation across
      share/release interleavings),
    * held + free is a partition of the pool (no leak, no double-alloc),
    * held never exceeds the pool even under sharing (alloc never
      over-commits, and a failed alloc changes nothing).
    """

    def __init__(self):
        super().__init__()
        self.alloc = PageAllocator(_POOL)
        self.mirror: dict[int, int] = {}     # page -> expected refcount
        self.handles: list[list[int]] = []
        self.quarantined: set[int] = set()   # out of service now
        self.doomed: set[int] = set()        # held; diverts at last release

    @rule(n=st.integers(min_value=0, max_value=_POOL + 2))
    def do_alloc(self, n):
        before_free = self.alloc.free_count()
        before_refs = self.alloc.total_refs()
        ids = self.alloc.alloc(n)
        if n > before_free:
            # Atomic failure: nothing popped, nothing referenced.
            assert ids is None
            assert self.alloc.free_count() == before_free
            assert self.alloc.total_refs() == before_refs
        else:
            assert len(ids) == n == len(set(ids))
            for i in ids:
                assert i not in self.mirror, "page handed out twice"
                self.mirror[i] = 1
            self.handles.append(list(ids))

    @rule(data=st.data())
    def do_share(self, data):
        if not self.handles:
            return
        ids = self.handles[
            data.draw(st.integers(0, len(self.handles) - 1), label="handle")
        ]
        self.alloc.share(ids)
        for i in ids:
            self.mirror[i] += 1
        self.handles.append(list(ids))

    @rule(data=st.data())
    def do_release(self, data):
        if not self.handles:
            return
        ids = self.handles.pop(
            data.draw(st.integers(0, len(self.handles) - 1), label="handle")
        )
        expect_freed = sorted(i for i in ids if self.mirror[i] == 1)
        freed = self.alloc.release(ids)
        assert sorted(freed) == expect_freed, "freed despite live refs"
        for i in ids:
            self.mirror[i] -= 1
            if not self.mirror[i]:
                del self.mirror[i]
        for i in freed:
            # A doomed page's last release diverts it to quarantine; the
            # caller still sees it in `freed` (drives trie eviction).
            if i in self.doomed:
                self.doomed.discard(i)
                self.quarantined.add(i)

    @rule(data=st.data())
    def do_quarantine(self, data):
        page = data.draw(st.integers(0, _POOL - 1), label="page")
        expect = page not in self.quarantined and page not in self.doomed
        assert self.alloc.quarantine(page) is expect   # idempotent
        if not expect:
            return
        if page in self.mirror:
            self.doomed.add(page)        # held: leaves service at release
        else:
            self.quarantined.add(page)   # free: leaves service now

    @invariant()
    def refcounts_conserved(self):
        held = self.alloc.held_pages
        free = self.alloc.free_pages
        assert held == set(self.mirror)
        for i, refs in self.mirror.items():
            assert self.alloc.ref_count(i) == refs
        assert not held & set(free), "page simultaneously free and held"
        assert self.alloc.quarantined_pages == self.quarantined
        assert self.alloc.doomed_pages == self.doomed
        assert self.doomed <= held, "doomed page is not held"
        assert not self.quarantined & (held | set(free))
        assert sorted(list(free) + list(held) + sorted(self.quarantined)) \
            == list(range(_POOL)), (
            "free + held + quarantined is not a partition of the pool"
        )
        assert self.alloc.usable_pages() == (
            _POOL - len(self.quarantined) - len(self.doomed)
        )
        assert len(held) <= _POOL


RefcountedAllocatorMachine.TestCase.settings = settings(
    max_examples=60, stateful_step_count=50, deadline=None
)
TestRefcountedAllocator = RefcountedAllocatorMachine.TestCase


class ChaosAllocatorMachine(RefcountedAllocatorMachine):
    """The same alloc/share/release interleavings against the fault-
    injecting `serve.chaos.ChaosAllocator`: injected refusals must be
    exactly as atomic as genuine over-commits (nothing popped, nothing
    referenced) and every refcount/partition invariant must survive the
    interleaving of injected and genuine failures."""

    def __init__(self):
        super().__init__()
        from repro.serve.chaos import ChaosAllocator
        self.alloc = ChaosAllocator(_POOL, fail_p=0.35, seed=11,
                                    share_fail_p=0.35)

    @rule(n=st.integers(min_value=0, max_value=_POOL + 2))
    def do_alloc(self, n):
        before_free = self.alloc.free_count()
        before_refs = self.alloc.total_refs()
        ids = self.alloc.alloc(n)
        if ids is None:
            # Genuine over-commit or injected refusal — either way the
            # failure is atomic and the two are distinguishable only via
            # last_injected (the engine can't tell, by design).
            assert n > before_free or self.alloc.last_injected
            assert self.alloc.free_count() == before_free
            assert self.alloc.total_refs() == before_refs
        else:
            assert not self.alloc.last_injected
            assert len(ids) == n == len(set(ids))
            for i in ids:
                assert i not in self.mirror, "page handed out twice"
                self.mirror[i] = 1
            self.handles.append(list(ids))

    @rule(data=st.data())
    def do_share(self, data):
        if not self.handles:
            return
        ids = self.handles[
            data.draw(st.integers(0, len(self.handles) - 1), label="handle")
        ]
        before_refs = self.alloc.total_refs()
        if self.alloc.share(ids):
            assert not self.alloc.last_injected
            for i in ids:
                self.mirror[i] += 1
            self.handles.append(list(ids))
        else:
            # Injected refusal (only possible on a non-empty share): as
            # atomic as a genuine alloc failure — no refcount perturbed.
            assert ids and self.alloc.last_injected
            assert self.alloc.total_refs() == before_refs


ChaosAllocatorMachine.TestCase.settings = settings(
    max_examples=40, stateful_step_count=50, deadline=None
)
TestChaosAllocator = ChaosAllocatorMachine.TestCase


@settings(max_examples=100, deadline=None)
@given(extra=st.integers(min_value=1, max_value=8),
       held_n=st.integers(min_value=0, max_value=8))
def test_failed_alloc_is_atomic_and_leaks_nothing(extra, held_n):
    """The partial-failure path: an alloc exceeding the free count must
    refuse WITHOUT popping any page or taking any reference.  The guard
    predates refcounting but was untested; this pins the atomicity (a
    naive pop-then-check rewrite would leak the popped prefix on
    failure) and that sharing does not perturb the gating."""
    alloc = PageAllocator(8)
    held = alloc.alloc(held_n)
    shared = held[: held_n // 2]
    if shared:
        alloc.share(shared)               # sharing must not change gating
    free_before = alloc.free_pages
    refs_before = {i: alloc.ref_count(i) for i in alloc.held_pages}
    ids = alloc.alloc(len(free_before) + extra)
    assert ids is None
    assert alloc.free_pages == free_before
    assert {i: alloc.ref_count(i) for i in alloc.held_pages} == refs_before


def test_share_release_refcount_lifecycle():
    """A shared page survives its first release and frees on the last."""
    alloc = PageAllocator(4)
    ids = alloc.alloc(2)
    alloc.share(ids)
    alloc.share([ids[0]])
    assert alloc.ref_count(ids[0]) == 3 and alloc.ref_count(ids[1]) == 2
    assert alloc.release(ids) == []              # refs remain: nothing freed
    assert alloc.release(ids) == [ids[1]]        # ids[0] still shared once
    assert alloc.ref_count(ids[0]) == 1
    assert alloc.release([ids[0]]) == [ids[0]]
    assert sorted(alloc.free_pages) == list(range(4))
    with pytest.raises(AssertionError, match="not held"):
        alloc.share(ids)                          # sharing freed pages


def test_free_rejects_unheld_pages():
    alloc = PageAllocator(4)
    ids = alloc.alloc(2)
    with pytest.raises(AssertionError, match="not held"):
        alloc.free([p for p in range(4) if p not in ids][:1])
    with pytest.raises(AssertionError, match="duplicate"):
        alloc.free([ids[0], ids[0]])   # same page twice in one call
    alloc.free(ids)
    with pytest.raises(AssertionError, match="not held"):
        alloc.free(ids)   # double free


def test_alloc_negative_rejected():
    with pytest.raises(ValueError):
        PageAllocator(4).alloc(-1)


# ---------------------------------------------------------------------------
# Warm retention tier: hypothesis state machine (DESIGN.md §5.7)
# ---------------------------------------------------------------------------

_WARM_BUDGET = 3


class WarmTierAllocatorMachine(RuleBasedStateMachine):
    """Random alloc/share/release/retain/revive/reclaim/quarantine
    interleavings against pure-Python mirrors.  The adaptive warm tier
    must compose with refcounting without weakening any §5.2/§5.4
    guarantee:

    * a warm page is never double-allocated — ``alloc`` can't see it
      (it left the free list) and ``revive`` moves it to refcount 1
      exactly once,
    * the warm set never exceeds ``warm_budget`` (``retain`` refuses,
      atomically, at the cap),
    * ``reclaim`` restores refcount conservation: reclaimed pages are
      ordinary free pages again and free + held + warm + quarantined
      stays a partition of the pool throughout.
    """

    def __init__(self):
        super().__init__()
        self.alloc = PageAllocator(_POOL, warm_budget=_WARM_BUDGET)
        self.mirror: dict[int, int] = {}     # page -> expected refcount
        self.handles: list[list[int]] = []
        self.warm: set[int] = set()
        self.quarantined: set[int] = set()
        self.doomed: set[int] = set()

    @rule(n=st.integers(min_value=0, max_value=_POOL + 2))
    def do_alloc(self, n):
        before_free = self.alloc.free_count()
        ids = self.alloc.alloc(n)
        if n > before_free:
            assert ids is None
            assert self.alloc.free_count() == before_free
        else:
            assert len(ids) == n == len(set(ids))
            for i in ids:
                assert i not in self.mirror, "page handed out twice"
                assert i not in self.warm, "warm page handed out by alloc"
                self.mirror[i] = 1
            self.handles.append(list(ids))

    @rule(data=st.data())
    def do_share(self, data):
        if not self.handles:
            return
        ids = self.handles[
            data.draw(st.integers(0, len(self.handles) - 1), label="handle")
        ]
        self.alloc.share(ids)
        for i in ids:
            self.mirror[i] += 1
        self.handles.append(list(ids))

    @rule(data=st.data())
    def do_release(self, data):
        if not self.handles:
            return
        ids = self.handles.pop(
            data.draw(st.integers(0, len(self.handles) - 1), label="handle")
        )
        expect_freed = sorted(i for i in ids if self.mirror[i] == 1)
        freed = self.alloc.release(ids)
        assert sorted(freed) == expect_freed, "freed despite live refs"
        for i in ids:
            self.mirror[i] -= 1
            if not self.mirror[i]:
                del self.mirror[i]
        for i in freed:
            if i in self.doomed:
                self.doomed.discard(i)
                self.quarantined.add(i)

    @rule(page=st.integers(min_value=0, max_value=_POOL - 1))
    def do_retain(self, page):
        free_before = sorted(self.alloc.free_pages)
        expect = (len(self.warm) < _WARM_BUDGET
                  and self.alloc.is_free(page))
        assert self.alloc.retain(page) is expect
        if expect:
            self.warm.add(page)
        else:
            # Refusal is atomic: a full budget / non-free page moves
            # nothing.
            assert sorted(self.alloc.free_pages) == free_before

    @rule(data=st.data())
    def do_reclaim(self, data):
        if not self.warm:
            return
        ids = data.draw(
            st.lists(st.sampled_from(sorted(self.warm)), unique=True),
            label="reclaim",
        )
        assert sorted(self.alloc.reclaim(ids)) == sorted(ids)
        self.warm -= set(ids)

    @rule(data=st.data())
    def do_revive(self, data):
        if not self.warm:
            return
        ids = data.draw(
            st.lists(st.sampled_from(sorted(self.warm)), unique=True),
            label="revive",
        )
        assert self.alloc.revive(ids) is True
        for i in ids:
            assert i not in self.mirror, "revived page was already held"
            self.mirror[i] = 1
        self.warm -= set(ids)
        if ids:
            self.handles.append(list(ids))

    @rule(page=st.integers(min_value=0, max_value=_POOL - 1))
    def do_quarantine(self, page):
        expect = page not in self.quarantined and page not in self.doomed
        assert self.alloc.quarantine(page) is expect
        if not expect:
            return
        if page in self.mirror:
            self.doomed.add(page)
        else:
            # Free AND warm pages leave service immediately.
            self.warm.discard(page)
            self.quarantined.add(page)

    @invariant()
    def warm_tier_conserved(self):
        held = self.alloc.held_pages
        free = self.alloc.free_pages
        warm = self.alloc.warm_pages
        assert held == set(self.mirror)
        for i, refs in self.mirror.items():
            assert self.alloc.ref_count(i) == refs
        assert warm == self.warm
        assert len(warm) <= _WARM_BUDGET, "warm budget exceeded"
        assert not warm & held, "page simultaneously warm and held"
        assert not warm & set(free), "page simultaneously warm and free"
        assert not warm & self.quarantined
        assert self.alloc.quarantined_pages == self.quarantined
        assert self.alloc.doomed_pages == self.doomed
        assert sorted(list(free) + list(held) + sorted(warm)
                      + sorted(self.quarantined)) == list(range(_POOL)), (
            "free + held + warm + quarantined is not a partition of the pool"
        )


WarmTierAllocatorMachine.TestCase.settings = settings(
    max_examples=60, stateful_step_count=50, deadline=None
)
TestWarmTierAllocator = WarmTierAllocatorMachine.TestCase


def test_warm_retain_refuses_at_budget_and_non_free():
    """retain() is atomic: it refuses pages that aren't free (held,
    quarantined, already warm) and refuses everything past the budget;
    reclaim/revive assert on non-warm ids rather than guessing."""
    alloc = PageAllocator(6, warm_budget=2)
    ids = alloc.alloc(2)
    assert not alloc.retain(ids[0])            # held, not free
    alloc.free(ids)
    assert alloc.retain(ids[0])
    assert not alloc.retain(ids[0])            # already warm, not free
    assert alloc.retain(ids[1])
    spare = alloc.alloc(1)
    alloc.free(spare)
    assert not alloc.retain(spare[0])          # budget full
    assert alloc.warm_count() == 2
    with pytest.raises(AssertionError, match="not warm"):
        alloc.reclaim(spare)
    with pytest.raises(AssertionError, match="not warm"):
        alloc.revive(spare)
    with pytest.raises(ValueError):
        alloc.retain(99)
    # Revive hands the pages back at refcount 1; the pool stays whole.
    assert alloc.revive(ids)
    assert all(alloc.ref_count(i) == 1 for i in ids)
    alloc.free(ids)
    assert sorted(alloc.free_pages) == list(range(6))


def test_quarantine_lifecycle():
    """Quarantine semantics (DESIGN.md §5.6): free pages leave service
    immediately, held pages are doomed and divert at their LAST release
    (still reported in `freed` so the engine's trie/stamp cleanup runs),
    and a quarantined page never re-enters the free list."""
    alloc = PageAllocator(4)
    ids = alloc.alloc(2)
    free_page = next(p for p in range(4) if p not in ids)
    assert alloc.quarantine(free_page)
    assert alloc.quarantined_pages == {free_page}
    assert not alloc.quarantine(free_page)           # idempotent: False
    assert alloc.usable_pages() == 3

    assert alloc.quarantine(ids[0])                  # held -> doomed
    assert alloc.doomed_pages == {ids[0]}
    assert ids[0] in alloc.held_pages                # still held for now
    assert alloc.usable_pages() == 2
    assert not alloc.quarantine(ids[0])              # already doomed

    alloc.share(ids)
    assert alloc.release(ids) == []                  # refs remain
    freed = alloc.release(ids)                       # last ref drops
    assert sorted(freed) == sorted(ids)              # caller sees both
    assert ids[0] in alloc.quarantined_pages         # ...but one diverted
    assert not alloc.doomed_pages
    assert ids[1] in alloc.free_pages
    assert ids[0] not in alloc.free_pages
    assert alloc.usable_pages() == 2

    with pytest.raises(ValueError):
        alloc.quarantine(99)
    # Quarantined pages are unreachable: the pool can still hand out
    # exactly the usable remainder and no more.
    got = alloc.alloc(2)
    assert got is not None and not (set(got) & alloc.quarantined_pages)
    assert alloc.alloc(1) is None
