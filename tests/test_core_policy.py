"""Policy-engine unit + hypothesis property tests: the paper's invariants.

Requires the optional ``hypothesis`` dev dependency (requirements-dev.txt);
the module skips gracefully when it is absent.  The deterministic planner /
sweep invariants live in ``test_planner_sweep.py`` and always run.
"""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro import hw
from repro.core import Policy, StaticMode, WorkloadClass, make_engine
from repro.core.allocator import mxu_efficiency, plan_op
from repro.core.characterize import (
    attention_op,
    classify_workload,
    elementwise_op,
    matmul_op,
    rowwise_op,
    window_op,
)
from repro.core.cost_model import (
    adaptive_assignment,
    op_cost,
    workload_cost,
)
from repro.core.policy import static_assignment
from repro.core.predictor import PolicyPredictor, SiteKey


# ---------------------------------------------------------------------------
# Allocation-Bypass (allocator) properties
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(
    m=st.integers(1, 16384), k=st.integers(1, 16384), n=st.integers(1, 16384),
    mode=st.sampled_from([StaticMode.UNCACHED, StaticMode.CACHER,
                          StaticMode.CACHERW]),
    ab=st.booleans(),
)
def test_allocator_never_exceeds_budget(m, k, n, mode, ab):
    op = matmul_op(m, k, n)
    plan = plan_op(op, static_assignment(op, mode), chip=hw.V5E,
                   allocation_bypass=ab)
    assert plan.vmem_bytes <= hw.V5E.vmem_budget
    # MXU-aligned (or dim-limited) block shapes.
    for dim, b in plan.block.items():
        assert b >= 1


@settings(max_examples=30, deadline=None)
@given(m=st.integers(256, 8192), k=st.integers(256, 8192),
       n=st.integers(256, 8192))
def test_allocation_bypass_demotes_instead_of_shrinking(m, k, n):
    """With AB, residency pressure resolves by demotion (bypass), keeping
    MXU-efficient tiles; without, tiles shrink (stall events)."""
    op = matmul_op(m, k, n)
    a = static_assignment(op, StaticMode.CACHERW)
    with_ab = plan_op(op, a, allocation_bypass=True)
    without = plan_op(op, a, allocation_bypass=False)
    assert with_ab.shrink_events == 0 or not with_ab.demotions
    assert mxu_efficiency(with_ab) >= mxu_efficiency(without) - 1e-9


def test_blocking_baseline_records_stalls():
    # Force residency whose reuse band (bk x N) far exceeds VMEM.
    op = matmul_op(1024, 8192, 2_000_000)
    a = static_assignment(op, StaticMode.CACHERW)
    plan = plan_op(op, a, allocation_bypass=False)
    assert plan.shrink_events > 0
    plan_ab = plan_op(op, a, allocation_bypass=True)
    assert plan_ab.shrink_events == 0
    assert plan_ab.demotions


# ---------------------------------------------------------------------------
# Cost model properties
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(elems=st.integers(1 << 10, 1 << 28))
def test_elementwise_caching_never_helps(elems):
    """Zero-reuse ops: Uncached is always <= cached times (paper's
    throughput-sensitive finding)."""
    op = elementwise_op(elems, dtype="f32")
    unc = op_cost(op, mode=StaticMode.UNCACHED, chip=hw.PAPER_GPU,
                  allocation_bypass=False, rinse=False)
    crw = op_cost(op, mode=StaticMode.CACHERW, chip=hw.PAPER_GPU,
                  allocation_bypass=False, rinse=False)
    assert unc.t_total <= crw.t_total + 1e-12


@settings(max_examples=60, deadline=None)
@given(rows=st.integers(1, 4096), row_len=st.integers(2, 8192),
       passes=st.integers(2, 5))
def test_realizable_reuse_reduces_traffic(rows, row_len, passes):
    op = rowwise_op(rows, row_len, passes=passes, dtype="f32")
    unc = op_cost(op, mode=StaticMode.UNCACHED, chip=hw.PAPER_GPU)
    cr = op_cost(op, mode=StaticMode.CACHER, chip=hw.PAPER_GPU)
    assert cr.hbm_bytes <= unc.hbm_bytes + 1e-9


@settings(max_examples=40, deadline=None)
@given(
    elems=st.integers(1 << 21, 1 << 26),
    window=st.integers(2, 9),
)
def test_unrealizable_reuse_gains_nothing(elems, window):
    """Reuse whose window exceeds capacity is captured at most in
    proportion to budget/window (FwLRN: window >> L2 -> ~no gain)."""
    op = window_op(elems, window, 1, reuse_distance_elems=elems // 2,
                   dtype="f32")
    unc = op_cost(op, mode=StaticMode.UNCACHED, chip=hw.PAPER_GPU)
    cr = op_cost(op, mode=StaticMode.CACHER, chip=hw.PAPER_GPU)
    x = op.operand("x")
    frac_max = min(1.0, hw.PAPER_GPU.vmem_budget / x.window_bytes)
    min_traffic = unc.read_bytes - (
        (x.touched_bytes_stream - x.unique_bytes) * frac_max
    )
    assert cr.read_bytes >= min_traffic * 0.99


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(64, 4096), k=st.integers(64, 4096), n=st.integers(64, 4096),
)
def test_adaptive_never_worse_than_best_static(m, k, n):
    """The paper's headline: AB+CR+PCby matches the best static policy."""
    ops = [matmul_op(m, k, n, dtype="f32", bm=64, bn=64, bk=64)]
    times = {
        mode: workload_cost(ops, mode=mode, chip=hw.PAPER_GPU,
                            launches_per_op=0).t_total
        for mode in StaticMode
    }
    best_static = min(
        times[m_] for m_ in (StaticMode.UNCACHED, StaticMode.CACHER,
                             StaticMode.CACHERW)
    )
    assert times[StaticMode.ADAPTIVE] <= best_static * 1.05


def test_rinse_improves_write_contiguity():
    op = matmul_op(4096, 4096, 4096, split_k=4)
    a = static_assignment(op, StaticMode.CACHERW)
    no_rinse = op_cost(op, assignment=a, rinse=False, allocation_bypass=True)
    rinse = op_cost(op, assignment=a, rinse=True, allocation_bypass=True)
    assert rinse.write_contiguity >= no_rinse.write_contiguity
    assert rinse.t_total <= no_rinse.t_total + 1e-12


# ---------------------------------------------------------------------------
# Classification
# ---------------------------------------------------------------------------

def test_paper_workload_classification():
    from repro.workloads.suite import SUITE

    mismatches = {
        name: (w.expected, classify_workload(w.ops, chip=hw.PAPER_GPU))
        for name, w in SUITE.items()
        if classify_workload(w.ops, chip=hw.PAPER_GPU) != w.expected
    }
    assert not mismatches, mismatches


def test_adaptive_matches_best_static_on_suite():
    from repro.workloads.suite import SUITE

    for name, w in SUITE.items():
        times = {
            mode: workload_cost(w.ops, mode=mode, chip=hw.PAPER_GPU,
                                launches_per_op=0).t_total
            for mode in StaticMode
        }
        best = min(times[m] for m in (StaticMode.UNCACHED, StaticMode.CACHER,
                                      StaticMode.CACHERW))
        assert times[StaticMode.ADAPTIVE] <= best * 1.05, (name, times)


def test_classification_matches_on_tpu_chip_for_elementwise():
    op = elementwise_op(1 << 28, dtype="bf16")
    assert classify_workload([op], chip=hw.V5E) in (
        WorkloadClass.THROUGHPUT_SENSITIVE, WorkloadClass.MEMORY_INSENSITIVE
    )


# ---------------------------------------------------------------------------
# Predictor (PCby analogue)
# ---------------------------------------------------------------------------

def test_predictor_seeded_from_cost_model():
    from repro.core.sweep import optimal_assignment

    p = PolicyPredictor(chip=hw.V5E)
    op = matmul_op(2048, 2048, 2048)
    a = p.predict(op)
    # Seeded from the exact lattice optimum, which keeps the greedy choice
    # on ties — and for this op the greedy walk is already optimal.
    assert a == optimal_assignment(op, hw.V5E)
    assert a == adaptive_assignment(op, hw.V5E)
    t_seed = op_cost(op, assignment=a, chip=hw.V5E, launches=0).t_total
    t_greedy = op_cost(op, assignment=adaptive_assignment(op, hw.V5E),
                       chip=hw.V5E, launches=0).t_total
    assert t_seed <= t_greedy


def test_predictor_flips_on_negative_feedback():
    p = PolicyPredictor(chip=hw.V5E)
    op = rowwise_op(512, 1024, passes=3)
    a = p.predict(op)
    assert a["x"] is Policy.RESIDENT
    for _ in range(4):
        p.update(op, a, benefit=-0.5)
    assert p.predict(op)["x"] is Policy.STREAM


def test_predictor_persistence_roundtrip(tmp_path):
    p = PolicyPredictor()
    op = matmul_op(512, 512, 512)
    p.predict(op)
    path = str(tmp_path / "policies.json")
    p.save(path)
    q = PolicyPredictor().load(path)
    assert len(q) == len(p)
    assert q.predict(op) == p.predict(op)


def test_engine_feedback_converges_to_best_static():
    """Simulated closed loop: feed modeled times back; adaptive ends at or
    below the best static cost for a mixed workload (paper Fig 10)."""
    eng = make_engine(chip="gem5-apu")
    ops = [
        elementwise_op(1 << 26, dtype="f32", name="act"),
        matmul_op(512, 4096, 4096, dtype="f32", bm=64, bn=64, bk=64),
        rowwise_op(4096, 4096, passes=3, dtype="f32"),
    ]
    for _ in range(6):
        for op in ops:
            plan = eng.plan_op(op)
            eng.feedback(op, plan, eng.cost(op, plan).t_total)
    for op in ops:
        best = min(
            workload_cost([op], mode=m, chip=hw.PAPER_GPU,
                          launches_per_op=1).t_total
            for m in (StaticMode.UNCACHED, StaticMode.CACHER,
                      StaticMode.CACHERW)
        )
        assert eng.cost(op).t_total <= best * 1.1


# ---------------------------------------------------------------------------
# SiteKey hygiene
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 8192), k=st.integers(1, 8192), n=st.integers(1, 8192))
def test_sitekey_encode_roundtrip(m, k, n):
    op = matmul_op(m, k, n)
    for o in op.operands:
        key = SiteKey.from_profile(op, o)
        assert SiteKey.decode(key.encode()) == key
