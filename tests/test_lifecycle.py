"""Request-lifecycle + chaos tests (DESIGN.md §5.5).

The tentpole claims: (1) preemption under genuine page pressure restores
evicted requests BIT-IDENTICALLY (the `(seed, token index)` sampler keys
make the recompute-prefill over prompt + emitted reproduce the stream by
construction); (2) cancellation/deadlines free slots, pages and trie refs
mid-stream with nothing leaked; (3) seeded fault injection
(`serve.chaos`) — alloc refusals and forced preemptions — perturbs the
schedule but never the outputs, with `check_invariants()` holding after
every wave (the engine asserts it automatically whenever a chaos knob is
armed).  A hypothesis state machine drives a REAL tiny engine through
random submit/cancel/step interleavings with the invariant checked after
every step.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.models import build_model, get_config
from repro.serve.engine import AdmissionReject, Request, ServeEngine


def _paged(cfg, page_size=8):
    return dataclasses.replace(
        cfg, cache_layout="paged", kv_page_size=page_size
    )


def _reqs(cfg, spec, seed=0, rng_seed=3):
    """Fresh Request objects for (prompt_len, max_new_tokens) pairs —
    identity comparisons need two independent copies of one workload."""
    rng = np.random.default_rng(rng_seed)
    return [
        Request(prompt=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                max_new_tokens=m, seed=seed)
        for n, m in spec
    ]


# (prompt_len, max_new_tokens) sized for page_size=8 / max_len=32:
# A needs 2 pages (11 positions), B needs 3 (17), C needs 2 (12).  With
# n_pages=4 B's admission is gated behind resident A and must preempt it.
_PRESSURE = [(6, 6), (10, 8), (5, 8)]


def _run_engine(cfg, params, reqs, **kw):
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32,
                      chunk_size=2, **kw)
    eng.run(reqs)
    return eng


@pytest.mark.parametrize("sampling", ["greedy", "top_p"])
@pytest.mark.parametrize("sharing", [False, True])
def test_preemption_identity_matrix(sampling, sharing):
    """Acceptance gate: an undersized pool forces >= 1 preemption, and
    every request's stream is bit-identical to the uninterrupted run —
    across {greedy, seeded top-p} x {prefix sharing on, off}."""
    cfg = get_config("yi-9b", smoke=True)
    if sampling == "top_p":
        cfg = dataclasses.replace(cfg, sampling="top_p", top_p=0.9)
    cfg = dataclasses.replace(_paged(cfg), prefix_sharing=sharing)
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    # Reference: same workload, pool big enough that nothing is evicted.
    ref = _reqs(cfg, _PRESSURE, seed=11)
    eng_ref = _run_engine(cfg, params, ref)
    assert eng_ref.stats["preempted"] == 0

    got = _reqs(cfg, _PRESSURE, seed=11)
    eng = _run_engine(cfg, params, got, n_pages=4)
    assert eng.stats["preempted"] >= 1, "scenario failed to force eviction"
    assert eng.stats["recompute_tokens"] >= 1
    for r, rr in zip(got, ref):
        assert r.done and r.status == "finished"
        assert len(r.generated) == r.max_new_tokens
        assert r.generated == rr.generated, (
            f"preempted stream diverged (preempted_n={r.preempted_n})"
        )
    # Nothing leaked: the full pool is free and state is conserved.
    assert sorted(eng.free_pages) == list(range(eng.n_pages))
    eng.check_invariants()


def test_preemption_is_bounded_and_refcount_safe():
    """Natural preemption evicts each request at most once (the
    never-preempted-victim guard), and under prefix sharing the victim's
    shared pages are only dereferenced — the sharer keeps decoding from
    intact storage."""
    cfg = dataclasses.replace(
        _paged(get_config("yi-9b", smoke=True)), prefix_sharing=True
    )
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    # A and B share a 2-page (16-token) prompt prefix and admit together;
    # C's demand (3 pages vs 1 free) then evicts B — the YOUNGEST — while
    # its shared prefix pages are still referenced by resident A.
    rng = np.random.default_rng(9)
    common = rng.integers(0, cfg.vocab, size=17).astype(np.int32)
    reqs = [
        Request(prompt=common, max_new_tokens=6, seed=1),       # 3 pages
        Request(prompt=np.concatenate(
            [common, rng.integers(0, cfg.vocab, size=3).astype(np.int32)]
        ), max_new_tokens=6, seed=2),      # 4 pages, 2 shared with A
        Request(prompt=rng.integers(0, cfg.vocab, size=10).astype(np.int32),
                max_new_tokens=8, seed=3),                      # 3 pages
    ]
    ref = [dataclasses.replace(r, generated=[]) for r in reqs]
    eng_ref = ServeEngine(cfg, params, batch_slots=3, max_len=32,
                          chunk_size=2)
    eng_ref.run(ref)
    eng = ServeEngine(cfg, params, batch_slots=3, max_len=32,
                      chunk_size=2, n_pages=6)
    eng.run(reqs)
    assert eng.stats["preempted"] >= 1
    assert all(r.preempted_n <= 1 for r in reqs)
    for r, rr in zip(reqs, ref):
        assert r.generated == rr.generated
    assert sorted(eng.free_pages) == list(range(eng.n_pages))
    eng.check_invariants()


@pytest.mark.parametrize("arch", ["yi-9b", "mamba2-1.3b"])
def test_chaos_forced_preemption_identity(arch):
    """cfg.chaos_preempt_p force-evicts residents at wave boundaries —
    including on layouts where genuine page pressure cannot arise
    (mamba2 falls back to contiguous).  Streams must stay bit-identical
    and, because a chaos knob is armed, the engine asserts
    check_invariants() after every single wave."""
    cfg = get_config(arch, smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(1))
    spec = [(5, 6), (3, 5), (7, 4), (4, 6)]
    ref = _reqs(cfg, spec, seed=5)
    _run_engine(cfg, params, ref)
    chaos_cfg = dataclasses.replace(
        cfg, chaos_preempt_p=0.5, chaos_seed=123
    )
    got = _reqs(chaos_cfg, spec, seed=5)
    eng = _run_engine(chaos_cfg, params, got)
    assert eng.stats["preempted_forced"] >= 1, "chaos never fired"
    for r, rr in zip(got, ref):
        assert r.generated == rr.generated, "forced preemption changed output"
    eng.check_invariants()


def test_chaos_alloc_failures_identity_and_zero_leaks():
    """Seeded alloc refusals are indistinguishable from pool exhaustion:
    the run must stay bit-identical to the fault-free run and end with
    the entire pool back on the free list (the CI chaos leg's gate)."""
    cfg = _paged(get_config("yi-9b", smoke=True))
    params = build_model(cfg).init(jax.random.PRNGKey(2))
    spec = [(6, 6), (9, 5), (5, 7), (4, 4)]
    ref = _reqs(cfg, spec, seed=7)
    _run_engine(cfg, params, ref, n_pages=6)
    # Seed chosen so injections actually fire within the handful of
    # allocs this workload makes (default_rng(0) draws 0.27, 0.04, 0.02
    # early — three refusals at p=0.4).
    chaos_cfg = dataclasses.replace(
        cfg, chaos_alloc_fail_p=0.4, chaos_seed=0
    )
    got = _reqs(chaos_cfg, spec, seed=7)
    eng = _run_engine(chaos_cfg, params, got, n_pages=6)
    assert eng.allocator.injected_failures >= 1, "chaos never fired"
    for r, rr in zip(got, ref):
        assert r.generated == rr.generated, "injected fault changed output"
    assert sorted(eng.free_pages) == list(range(eng.n_pages))
    eng.check_invariants()


def test_chaos_allocator_seeded_and_atomic():
    """ChaosAllocator unit behavior (hypothesis-free so it always runs;
    the interleaving machine lives in test_alloc_property): identical
    seeds reproduce the exact injection pattern, an injected refusal
    changes no allocator state, and alloc(0) — the fully-shared-prefix
    no-op — is never injected."""
    from repro.serve.chaos import ChaosAllocator

    def pattern(seed):
        alloc = ChaosAllocator(8, fail_p=0.5, seed=seed)
        out = []
        for _ in range(12):
            free_before = alloc.free_pages
            refs_before = {p: alloc.ref_count(p) for p in alloc.held_pages}
            ids = alloc.alloc(1)
            out.append(ids is None)
            if ids is None:
                assert alloc.last_injected    # pool never genuinely empty
                assert alloc.free_pages == free_before
                assert {p: alloc.ref_count(p)
                        for p in alloc.held_pages} == refs_before
            else:
                alloc.release(ids)
        return out

    assert pattern(3) == pattern(3)          # reproducible from the seed
    assert any(pattern(3)) and not all(pattern(3))
    assert pattern(3) != pattern(4)          # and actually seed-dependent

    alloc = ChaosAllocator(4, fail_p=1.0 - 1e-12, seed=0)
    for _ in range(32):
        assert alloc.alloc(0) == []          # never injected for n == 0
        assert not alloc.last_injected
    assert alloc.injected_failures == 0


def test_cancel_queued_and_resident():
    """cancel() retires a queued request before it ever runs and a
    resident one mid-stream (slot + pages free, partial tokens kept);
    unknown or already-terminal ids return False instead of raising."""
    cfg = _paged(get_config("yi-9b", smoke=True))
    params = build_model(cfg).init(jax.random.PRNGKey(3))
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32, chunk_size=2)
    rng = np.random.default_rng(1)
    mk = lambda rid: Request(  # noqa: E731
        prompt=rng.integers(0, cfg.vocab, size=4).astype(np.int32),
        max_new_tokens=12, id=rid,
    )
    resident, queued = mk("res"), mk("qd")
    eng.submit([resident, queued])
    assert eng.step()                       # admits "res", decodes a chunk
    assert resident.status == "resident" and len(resident.generated) >= 1
    assert eng.cancel("qd") and eng.cancel("res")
    assert not eng.cancel("no-such-id")
    eng.drain()
    assert queued.done and queued.status == "cancelled"
    assert queued.generated == []           # never admitted
    assert resident.done and resident.status == "cancelled"
    assert 1 <= len(resident.generated) < resident.max_new_tokens
    assert not eng.cancel("res")            # terminal: idempotent False
    assert eng.stats["cancelled"] == 2
    assert sorted(eng.free_pages) == list(range(eng.n_pages))
    eng.check_invariants()


def test_deadline_and_queue_wait_expiry():
    """deadline_s expires a resident mid-stream (partial tokens kept) and
    max_queue_wait_s expires a stale queued request; both count against
    goodput-under-deadline in serve_stats()/policy_report()."""
    import time

    cfg = _paged(get_config("yi-9b", smoke=True))
    params = build_model(cfg).init(jax.random.PRNGKey(4))
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32, chunk_size=2)
    rng = np.random.default_rng(2)
    p = lambda: rng.integers(0, cfg.vocab, size=4).astype(np.int32)  # noqa: E731
    slow = Request(prompt=p(), max_new_tokens=24, deadline_s=0.05, id="slow")
    stale = Request(prompt=p(), max_new_tokens=4, max_queue_wait_s=1e-6,
                    id="stale")
    ok = Request(prompt=p(), max_new_tokens=2, deadline_s=60.0, id="ok")
    eng.submit([slow, stale, ok])
    assert eng.step()                       # "slow" resident, decoding
    time.sleep(0.06)                        # blow slow's deadline
    eng.drain()
    assert slow.status == "expired" and 1 <= len(slow.generated) < 24
    assert stale.status == "expired" and stale.generated == []
    assert ok.status == "finished" and len(ok.generated) == 2
    assert eng.stats["expired"] == 2
    st = eng.serve_stats()
    # Deadlined population is {slow, ok} ("stale" carried only a queue-
    # wait bound, no deadline_s): 1 of 2 met.
    assert st["goodput_under_deadline"] == pytest.approx(0.5)
    assert sorted(eng.free_pages) == list(range(eng.n_pages))
    eng.check_invariants()


def test_bounded_queue_backpressure():
    """max_queue rejects the whole over-quota batch with reason
    "queue_full" BEFORE enqueuing anything, and the engine stays usable."""
    cfg = get_config("yi-9b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(5))
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32,
                      chunk_size=2, max_queue=2)
    rng = np.random.default_rng(3)
    mk = lambda: Request(  # noqa: E731
        prompt=rng.integers(0, cfg.vocab, size=4).astype(np.int32),
        max_new_tokens=2,
    )
    with pytest.raises(AdmissionReject, match="max_queue") as ei:
        eng.submit([mk(), mk(), mk()])
    assert ei.value.reason == "queue_full"
    assert len(eng.queue) == 0              # nothing half-submitted
    assert eng.stats["rejected"] == 3
    batch = [mk(), mk()]
    eng.submit(batch)                       # at quota: accepted
    eng.drain()
    assert all(r.status == "finished" for r in batch)


def test_submit_rejects_impossible_page_demand():
    """Satellite regression: a request whose worst-case page demand
    exceeds the ENTIRE pool used to enqueue and then wedge the FIFO
    head-of-line gate forever; it must be rejected at submit."""
    cfg = _paged(get_config("yi-9b", smoke=True))
    params = build_model(cfg).init(jax.random.PRNGKey(6))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32,
                      chunk_size=2, n_pages=2)     # pool: 16 positions
    rng = np.random.default_rng(4)
    impossible = Request(
        prompt=rng.integers(0, cfg.vocab, size=10).astype(np.int32),
        max_new_tokens=8,                          # 17 positions -> 3 pages
    )
    fine = Request(
        prompt=rng.integers(0, cfg.vocab, size=6).astype(np.int32),
        max_new_tokens=4,
    )
    with pytest.raises(AdmissionReject, match="could never be admitted") as ei:
        eng.submit([fine, impossible])
    assert ei.value.reason == "pool_too_small"
    assert len(eng.queue) == 0              # batch validation is atomic
    eng.run([fine])                          # engine unharmed
    assert fine.status == "finished"


def test_duplicate_id_rejected():
    cfg = get_config("yi-9b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(7))
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32)
    prompt = np.arange(4, dtype=np.int32) % cfg.vocab
    eng.run([Request(prompt=prompt, max_new_tokens=2, id="dup")])
    with pytest.raises(AdmissionReject) as ei:
        eng.submit([Request(prompt=prompt, max_new_tokens=2, id="dup")])
    assert ei.value.reason == "duplicate_id"


def test_policy_report_schema_stable():
    """Benches and CI parse policy_report()/serve_stats(); pin the full
    key sets (including the §5.5 lifecycle section) so they can't drift
    silently."""
    cfg = dataclasses.replace(
        _paged(get_config("yi-9b", smoke=True)),
        prefix_sharing=True, spec_k=2,
    )
    params = build_model(cfg).init(jax.random.PRNGKey(8))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    report = eng.policy_report()
    assert set(report) == {
        "kv_bytes_per_layer", "kv_residency", "cache_layout", "sampling",
        "plan_cache", "speculative", "paged_kv", "prefix_sharing",
        "adaptive", "lifecycle", "integrity", "decode_attention",
    }
    assert report["adaptive"] == {"enabled": False}   # static engine
    assert set(report["lifecycle"]) == {
        "preemption_enabled", "max_queue", "preempted", "preempted_forced",
        "recompute_tokens", "cancelled", "expired", "rejected",
        "goodput_under_deadline", "chaos",
    }
    assert set(report["lifecycle"]["chaos"]) == {
        "alloc_fail_p", "preempt_p", "share_fail_p", "corrupt_p",
        "crash_after_wave", "seed", "injected_alloc_failures",
        "injected_share_failures", "injected_corruptions",
    }
    assert set(report["integrity"]) == {
        "enabled", "strict_invariants", "journal", "stamped_pages",
        "quarantined_pages", "corrupted_pages", "healed_requests",
        "snapshots", "restores",
    }
    stats = eng.serve_stats()
    assert {
        "preempted", "preempted_forced", "recompute_tokens", "cancelled",
        "expired", "rejected", "deadline_total", "deadline_met",
        "goodput_under_deadline", "invariant_checks", "integrity_sweeps",
        "corrupted_pages", "healed_requests", "snapshots", "restores",
    } <= set(stats)
    assert stats["goodput_under_deadline"] == 1.0    # vacuous: no SLOs yet


def test_chaos_share_failures_identity_and_zero_leaks():
    """Satellite: seeded SHARE refusals (the alloc-own-then-share
    admission ordering's second failure point) roll back the head's
    fresh allocation atomically — no refcount perturbed — and the run
    stays bit-identical to the fault-free run with zero leaked pages."""
    cfg = dataclasses.replace(
        _paged(get_config("yi-9b", smoke=True)), prefix_sharing=True
    )
    params = build_model(cfg).init(jax.random.PRNGKey(9))
    rng = np.random.default_rng(17)
    common = rng.integers(0, cfg.vocab, size=17).astype(np.int32)
    spec_reqs = lambda: [  # noqa: E731
        Request(prompt=common.copy(), max_new_tokens=5, seed=1),
        Request(prompt=np.concatenate(
            [common, rng.integers(0, cfg.vocab, size=2).astype(np.int32)]
        ), max_new_tokens=5, seed=2),       # attaches to A's prefix pages
        Request(prompt=common.copy(), max_new_tokens=4, seed=3),
    ]
    rng = np.random.default_rng(17)         # same prompts both runs
    ref = spec_reqs()
    rng = np.random.default_rng(17)
    got = spec_reqs()
    _run_engine(cfg, params, ref)
    chaos_cfg = dataclasses.replace(cfg, chaos_share_fail_p=0.6,
                                    chaos_seed=1)
    eng = _run_engine(chaos_cfg, params, got)
    assert eng.allocator.injected_share_failures >= 1, "chaos never fired"
    for r, rr in zip(got, ref):
        assert r.generated == rr.generated, "share refusal changed output"
    assert sorted(eng.free_pages) == list(range(eng.n_pages))
    eng.check_invariants()


def test_chaos_share_refusal_is_atomic():
    """ChaosAllocator.share unit: an injected refusal returns False
    having touched NO refcount, share([]) is never injected, and the
    injection pattern is reproducible from the seed."""
    from repro.serve.chaos import ChaosAllocator

    def pattern(seed):
        alloc = ChaosAllocator(8, fail_p=0.0, seed=seed, share_fail_p=0.5)
        base = alloc.alloc(3)
        out = []
        for _ in range(12):
            refs_before = {p: alloc.ref_count(p) for p in alloc.held_pages}
            ok = alloc.share(base)
            out.append(ok)
            if not ok:
                assert alloc.last_injected
                assert {p: alloc.ref_count(p)
                        for p in alloc.held_pages} == refs_before
            else:
                alloc.release(base)
        return out

    assert pattern(5) == pattern(5)
    assert any(pattern(5)) and not all(pattern(5))
    assert pattern(5) != pattern(6)

    alloc = ChaosAllocator(4, fail_p=0.0, seed=0, share_fail_p=1.0 - 1e-12)
    for _ in range(16):
        assert alloc.share([]) is True       # no-op: never injected
        assert not alloc.last_injected
    assert alloc.injected_share_failures == 0


def test_strict_invariants_runs_without_chaos(monkeypatch):
    """Satellite: cfg.strict_invariants (or the REPRO_STRICT_INVARIANTS
    env var CI sets) arms the per-wave check_invariants() sweep with no
    chaos knob on; without either, no per-wave check runs."""
    cfg = _paged(get_config("yi-9b", smoke=True))
    params = build_model(cfg).init(jax.random.PRNGKey(10))
    reqs = lambda: _reqs(cfg, [(5, 4), (4, 3)], seed=2)  # noqa: E731

    monkeypatch.delenv("REPRO_STRICT_INVARIANTS", raising=False)
    eng = _run_engine(cfg, params, reqs())
    assert eng.stats["invariant_checks"] == 0

    strict = dataclasses.replace(cfg, strict_invariants=True)
    eng = _run_engine(strict, params, reqs())
    assert eng.stats["invariant_checks"] >= 1

    monkeypatch.setenv("REPRO_STRICT_INVARIANTS", "1")
    eng = _run_engine(cfg, params, reqs())
    assert eng.stats["invariant_checks"] >= 1
    monkeypatch.setenv("REPRO_STRICT_INVARIANTS", "0")   # "0" disarms
    eng = _run_engine(cfg, params, reqs())
    assert eng.stats["invariant_checks"] == 0
