"""Crash-safe serving tests (DESIGN.md §5.6).

The tentpole claims: (1) ``snapshot()`` serializes only host-side truth
and ``restore()`` rebuilds all device KV bit-identically via the same
recompute-prefill path preemption uses, so a run killed mid-wave and
restored into a FRESH engine finishes with exactly the streams of an
uninterrupted run; (2) the append-only fsync'd request journal makes
recovery possible with no snapshot at all — replaying submits + terminal
events past the last flushed chunk boundary; (3) a corrupted/mismatched
snapshot is rejected with a typed ``SnapshotError`` BEFORE any live
state is discarded; (4) quarantined pages stay quarantined across
restore; (5) ``drain()``'s watchdog converts a zero-progress livelock
into a typed ``NoProgressError`` instead of a silent spin.

All engines here share one params tree (one compile per dispatch shape);
workload copies are regenerated per run so identity comparisons are
between independent Request objects.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.models import build_model, get_config
from repro.serve.chaos import ChaosCrash
from repro.serve.engine import NoProgressError, Request, ServeEngine
from repro.serve.snapshot import (
    RequestJournal,
    SnapshotError,
    load_snapshot,
    write_snapshot,
)

_PRESSURE = [(6, 6), (10, 8), (5, 8)]


def _cfg(**kw):
    base = dataclasses.replace(
        get_config("yi-9b", smoke=True), cache_layout="paged",
        kv_page_size=8,
    )
    return dataclasses.replace(base, **kw) if kw else base


@pytest.fixture(scope="module")
def params():
    return build_model(_cfg()).init(jax.random.PRNGKey(0))


def _reqs(cfg, spec=_PRESSURE, seed=0, rng_seed=3):
    rng = np.random.default_rng(rng_seed)
    return [
        Request(prompt=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                max_new_tokens=m, seed=seed)
        for n, m in spec
    ]


def _engine(cfg, params, **kw):
    return ServeEngine(cfg, params, batch_slots=2, max_len=32,
                       chunk_size=2, **kw)


def _reference(cfg, params, **req_kw):
    ref = _reqs(cfg, **req_kw)
    eng = _engine(cfg, params)
    eng.run(ref)
    return {r.id: list(r.generated) for r in ref}


def _assert_zero_leaks(eng):
    free = sorted(eng.free_pages)
    quar = sorted(eng.allocator.quarantined_pages)
    assert sorted(free + quar) == list(range(eng.n_pages)), (free, quar)
    eng.check_invariants()


@pytest.mark.parametrize("sharing", [False, True])
def test_snapshot_restore_mid_wave_identity(sharing, params, tmp_path):
    """Tentpole gate: snapshot taken mid-stream (some requests resident,
    some queued, some finished), restored into a FRESH engine — which
    finishes with streams bit-identical to the uninterrupted run and
    zero leaked pages.  With sharing on, restored residents re-attach
    through the trie like any recompute admission."""
    cfg = _cfg(prefix_sharing=sharing)
    ref_out = _reference(cfg, params, seed=5)

    got = _reqs(cfg, seed=5)
    e1 = _engine(cfg, params)
    e1.submit(got)
    for _ in range(3):                          # mid-wave: in-flight state
        e1.step()
    spath = str(tmp_path / "mid.json")
    info = e1.snapshot(spath)
    assert info["requests"] == len(got)
    assert info["in_flight"] >= 1, "snapshot point was not mid-stream"

    e2 = _engine(cfg, params)
    r = e2.restore(spath)
    assert r["restored"] == info["in_flight"]
    e2.drain()
    assert e2.results() == ref_out
    _assert_zero_leaks(e2)
    # The crashed original is dead by contract; never drained.


def test_journal_replay_after_injected_kill(params, tmp_path):
    """chaos_crash_after_wave kills the engine at a flushed chunk
    boundary; a fresh engine recovers from the journal ALONE (no
    snapshot was ever taken) and finishes bit-identically."""
    cfg = _cfg()
    ref_out = _reference(cfg, params, seed=7)

    jpath = str(tmp_path / "j.jsonl")
    crash = dataclasses.replace(cfg, chaos_crash_after_wave=1)
    e1 = _engine(crash, params, journal_path=jpath)
    e1.submit(_reqs(cfg, seed=7))
    with pytest.raises(ChaosCrash) as ei:
        e1.drain()
    assert ei.value.wave >= 1

    e2 = _engine(cfg, params, journal_path=jpath)
    rep = e2.restore()                           # journal-only recovery
    assert rep["replayed_events"] >= len(_PRESSURE)
    e2.drain()
    assert e2.results() == ref_out
    _assert_zero_leaks(e2)


def test_snapshot_plus_journal_suffix(params, tmp_path):
    """Snapshot at wave 1, crash later: restore loads the snapshot and
    replays only the journal suffix past its recorded offset —
    terminal events re-retire finished requests with their exact
    streams, never re-running them."""
    cfg = _cfg()
    ref_out = _reference(cfg, params, seed=9)

    jpath = str(tmp_path / "j.jsonl")
    spath = str(tmp_path / "s.json")
    crash = dataclasses.replace(cfg, chaos_crash_after_wave=2)
    e1 = _engine(crash, params, journal_path=jpath)
    e1.submit(_reqs(cfg, seed=9))
    e1.step()
    e1.snapshot(spath)
    with pytest.raises(ChaosCrash):
        e1.drain()

    e2 = _engine(cfg, params, journal_path=jpath)
    rep = e2.restore(spath)
    e2.drain()
    assert e2.results() == ref_out
    assert rep["restored"] >= 1
    _assert_zero_leaks(e2)


def test_terminal_results_survive_restore(params, tmp_path):
    """Requests that finished BEFORE the snapshot come back as terminal
    records — status and streams intact, never re-admitted."""
    cfg = _cfg()
    got = _reqs(cfg, spec=[(6, 4)], seed=3)
    e1 = _engine(cfg, params)
    e1.run(got)
    spath = str(tmp_path / "done.json")
    e1.snapshot(spath)

    e2 = _engine(cfg, params)
    rep = e2.restore(spath)
    assert rep == {"restored": 0, "replayed_events": 0, "terminal": 1}
    r = e2.request(got[0].id)
    assert r.done and r.status == "finished"
    assert r.generated == got[0].generated
    assert not e2.step()                        # nothing left to run


def test_corrupted_snapshot_rejected_with_typed_error(params, tmp_path):
    """Every tampering mode maps to its SnapshotError.reason, and a
    rejected restore leaves the live engine fully intact."""
    cfg = _cfg()
    e1 = _engine(cfg, params)
    e1.run(_reqs(cfg, spec=[(6, 4)], seed=1))
    spath = str(tmp_path / "s.json")
    e1.snapshot(spath)

    def tampered(mutate, name):
        doc = json.load(open(spath))
        mutate(doc)
        p = str(tmp_path / name)
        json.dump(doc, open(p, "w"))
        return p

    cases = [
        ("checksum", tampered(
            lambda d: d["payload"]["counters"].__setitem__(
                "next_id", d["payload"]["counters"]["next_id"] + 1),
            "flip.json")),
        ("bad_magic", tampered(
            lambda d: d.__setitem__("magic", "nope"), "magic.json")),
        ("version", tampered(
            lambda d: d.__setitem__("version", 999), "ver.json")),
        ("unreadable", str(tmp_path / "absent.json")),
    ]
    trunc = str(tmp_path / "trunc.json")
    open(trunc, "w").write(open(spath).read()[:40])
    cases.append(("unreadable", trunc))

    victim = _engine(cfg, params)
    victim.submit(_reqs(cfg, spec=[(6, 4)], seed=2))
    victim.step()
    before = victim.results()
    for reason, path in cases:
        with pytest.raises(SnapshotError) as ei:
            victim.restore(path)
        assert ei.value.reason == reason, (reason, ei.value.reason)
    assert victim.results() == before           # live state untouched
    victim.drain()                              # still fully operational
    assert all(r.done for r in victim._by_id.values())


def test_restore_rejects_config_and_geometry_mismatch(params, tmp_path):
    cfg = _cfg()
    e1 = _engine(cfg, params)
    e1.run(_reqs(cfg, spec=[(6, 4)], seed=1))
    spath = str(tmp_path / "s.json")
    e1.snapshot(spath)

    other = _engine(dataclasses.replace(cfg, sampling="top_p", top_p=0.9),
                    params)
    with pytest.raises(SnapshotError) as ei:
        other.restore(spath)
    assert ei.value.reason == "config_mismatch"

    # Chaos/strict knobs are excluded from the fingerprint: recovery
    # legitimately runs with the crash injection OFF that the dead run
    # had on.
    relaxed = _engine(
        dataclasses.replace(cfg, chaos_crash_after_wave=7,
                            strict_invariants=True), params)
    relaxed.restore(spath)                       # accepted

    small = ServeEngine(cfg, params, batch_slots=2, max_len=32,
                        chunk_size=2, n_pages=4)
    with pytest.raises(SnapshotError) as ei:
        small.restore(spath)
    assert ei.value.reason == "geometry_mismatch"

    with pytest.raises(SnapshotError) as ei:
        _engine(cfg, params).restore()
    assert ei.value.reason == "no_source"


def test_inconsistent_snapshot_audit(params, tmp_path):
    """A snapshot whose refcounts disagree with its page tables is
    internally inconsistent — rejected by the pre-restore audit even
    though its checksum is valid (it was WRITTEN corrupt, not torn)."""
    cfg = _cfg()
    e1 = _engine(cfg, params)
    e1.submit(_reqs(cfg, seed=4))
    e1.step()
    spath = str(tmp_path / "s.json")
    e1.snapshot(spath)
    payload = load_snapshot(spath)
    assert payload["allocator"]["refcounts"], "no held pages to corrupt"
    k = next(iter(payload["allocator"]["refcounts"]))
    payload["allocator"]["refcounts"][k] += 1
    write_snapshot(spath, payload)               # re-checksummed

    with pytest.raises(SnapshotError) as ei:
        _engine(cfg, params).restore(spath)
    assert ei.value.reason == "inconsistent"


def test_quarantine_persists_across_restore(params, tmp_path):
    """Pages quarantined by integrity healing never silently return to
    service: restore re-quarantines them in the fresh allocator."""
    cfg = _cfg()
    e1 = _engine(cfg, params)
    e1.run(_reqs(cfg, spec=[(6, 4)], seed=1))
    for p in (2, 5):
        assert e1.allocator.quarantine(p)
    spath = str(tmp_path / "q.json")
    e1.snapshot(spath)

    e2 = _engine(cfg, params)
    e2.restore(spath)
    assert sorted(e2.allocator.quarantined_pages) == [2, 5]
    assert e2.allocator.usable_pages() == e2.n_pages - 2
    e2.check_invariants()


def test_journal_skips_torn_trailing_line(tmp_path):
    """A partial trailing line (the write a crash interrupted) is
    skipped, not an error; everything before it replays intact."""
    jpath = str(tmp_path / "j.jsonl")
    j = RequestJournal(jpath)
    j.append({"ev": "submit", "id": "a"})
    j.append({"ev": "terminal", "id": "a", "status": "finished",
              "generated": [1, 2]})
    off = j.offset()
    j.close()
    with open(jpath, "a") as f:
        f.write('{"ev": "submit", "id": "b", "pro')    # torn mid-record
    evs = list(RequestJournal.replay(jpath))
    assert [e["id"] for e in evs] == ["a", "a"]
    assert list(RequestJournal.replay(jpath, offset=off)) == []
    with pytest.raises(SnapshotError) as ei:
        list(RequestJournal.replay(str(tmp_path / "absent.jsonl")))
    assert ei.value.reason == "unreadable"


def test_drain_watchdog_raises_no_progress(params):
    """Satellite: a pool where every page is quarantined can never admit
    the queued request — drain() must raise NoProgressError after the
    configured number of zero-progress steps instead of spinning."""
    cfg = _cfg()
    eng = _engine(cfg, params, no_progress_limit=4)
    eng.submit(_reqs(cfg, spec=[(6, 4)], seed=1))
    for p in list(eng.allocator.free_pages):
        eng.allocator.quarantine(p)
    with pytest.raises(NoProgressError) as ei:
        eng.drain()
    msg = str(ei.value)
    assert "no progress" in msg and "usable_pages" in msg
    # The engine is still inspectable after the typed failure.
    assert eng.allocator.usable_pages() == 0


def test_counters_and_adaptive_state_survive_restore(params, tmp_path):
    """Satellite (stats/snapshot bugfix): a restored engine must carry
    the crashed engine's policy-relevant counters AND the adaptive
    controller's learned class state forward — any counter-driven
    decision would otherwise diverge after crash-recovery.  Warm pages
    themselves are volatile (device KV died with the process); only
    knowledge survives.  The recovered run still finishes bit-identical
    to the uninterrupted one."""
    cfg = _cfg(prefix_sharing=True, adaptive=True, warm_pages=2,
               adaptive_replan_every=1)
    ref_out = _reference(cfg, params, seed=5)

    got = _reqs(cfg, seed=5)
    e1 = _engine(cfg, params)
    e1.submit(got)
    for _ in range(3):                          # mid-stream snapshot point
        e1.step()
    pre = dict(e1.stats)
    pre_adaptive = e1.adaptive.snapshot_state()
    assert pre["admission_waves"] >= 1
    spath = str(tmp_path / "adaptive.json")
    info = e1.snapshot(spath)
    assert info["in_flight"] >= 1

    e2 = _engine(cfg, params)
    e2.restore(spath)
    # Counter continuity: every policy-relevant counter resumes where
    # the snapshot left it, nothing restarts from zero.
    for key in ("admission_waves", "prefill_tokens", "decode_tokens",
                "admitted_fresh", "readmitted", "prefill_work_tokens",
                "prefix_hits", "prefix_hits_fresh", "warm_retained",
                "warm_hits", "warm_reclaimed", "replans", "preempted"):
        assert e2.stats[key] == pre[key], (
            f"counter {key!r} did not survive restore"
        )
    # Learned adaptive state (classes, combos, wave clock) round-trips;
    # page-level recency starts cold by design.
    assert e2.adaptive.snapshot_state() == pre_adaptive
    assert e2.adaptive.wave == e1.adaptive.wave
    assert e2.allocator.warm_count() == 0, "warm pages must not survive"

    e2.drain()
    assert e2.results() == ref_out
    free = sorted(e2.allocator.free_pages)
    warm = sorted(e2.allocator.warm_pages)
    assert sorted(free + warm) == list(range(e2.n_pages))
    e2.check_invariants()


def test_adaptive_crash_recovery_identity(params, tmp_path):
    """Injected kill mid-run with the adaptive tier live: journal replay
    into a fresh adaptive engine reproduces the uninterrupted streams,
    and a static engine can restore the adaptive engine's snapshot (the
    adaptive knobs are fingerprint-exempt — placement-only)."""
    acfg = _cfg(prefix_sharing=True, adaptive=True, warm_pages=2,
                adaptive_replan_every=1)
    ref_out = _reference(acfg, params, seed=7)

    jpath = str(tmp_path / "aj.jsonl")
    crash = dataclasses.replace(acfg, chaos_crash_after_wave=1)
    e1 = _engine(crash, params, journal_path=jpath)
    e1.submit(_reqs(acfg, seed=7))
    with pytest.raises(ChaosCrash):
        e1.drain()

    e2 = _engine(acfg, params, journal_path=jpath)
    e2.restore()                                 # journal-only recovery
    e2.drain()
    assert e2.results() == ref_out

    # Cross-restore: static engine <- adaptive snapshot (and the stream
    # identity gate still holds — adaptation never moved a token).
    spath = str(tmp_path / "cross.json")
    e2.snapshot(spath)
    e3 = _engine(_cfg(prefix_sharing=True), params)
    e3.restore(spath)
    assert e3.adaptive is None
    assert e3.results() == ref_out
    e3.check_invariants()
