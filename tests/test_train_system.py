"""Training-substrate system tests: loop, checkpointing, fault tolerance,
optimizer, data determinism."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.remat import RematPolicy
from repro.data.pipeline import MemmapLM, Prefetcher, SyntheticLM
from repro.models import build_model, get_config
from repro.train import checkpoint as ckpt
from repro.train import loop as train_loop
from repro.train import optimizer as opt
from repro.train.step import TrainConfig, init_train_state, make_train_step


@pytest.fixture()
def tiny():
    cfg = get_config("yi-9b", smoke=True)
    tcfg = TrainConfig(
        adamw=opt.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50),
        remat=RematPolicy.SAVE_DOTS,
    )
    train_step, model = make_train_step(cfg, tcfg)
    train_step = jax.jit(train_step, donate_argnums=(0,))
    state = init_train_state(model, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, batch=4, seq=16, seed=7)
    return cfg, train_step, state, data


def test_loss_decreases(tiny):
    cfg, train_step, state, data = tiny
    losses = []
    for step in range(12):
        state, metrics = train_step(state, data(step % 2))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


def test_microbatch_equivalence():
    """mb=4 grad accumulation == mb=1 on the same batch (same update)."""
    cfg = get_config("yi-9b", smoke=True)
    data = SyntheticLM(cfg, batch=4, seq=16, seed=3)
    batch = data(0)
    outs = []
    for mb in (1, 4):
        tcfg = TrainConfig(
            adamw=opt.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10),
            microbatch=mb, batch_axes=(),
        )
        train_step, model = make_train_step(cfg, tcfg)
        state = init_train_state(model, jax.random.PRNGKey(0))
        new_state, _ = jax.jit(train_step)(state, batch)
        outs.append(new_state["params"])
    flat1 = jax.tree_util.tree_leaves(outs[0])
    flat4 = jax.tree_util.tree_leaves(outs[1])
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-3, atol=2e-3,
        )


def test_schedules():
    c = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        schedule="wsd", decay_frac=0.2, min_lr_frac=0.1)
    lr5 = float(opt.schedule_lr(c, jnp.asarray(5)))
    lr50 = float(opt.schedule_lr(c, jnp.asarray(50)))
    lr99 = float(opt.schedule_lr(c, jnp.asarray(99)))
    assert lr5 == pytest.approx(0.5, rel=1e-3)      # warmup
    assert lr50 == pytest.approx(1.0, rel=1e-3)     # stable
    assert 0.09 < lr99 < 0.25                       # decaying
    c2 = opt.AdamWConfig(lr=1.0, warmup_steps=0, total_steps=100,
                         schedule="cosine", min_lr_frac=0.1)
    assert float(opt.schedule_lr(c2, jnp.asarray(100))) == pytest.approx(
        0.1, rel=1e-2
    )


def test_checkpoint_roundtrip_and_atomicity(tmp_path, tiny):
    cfg, train_step, state, data = tiny
    d = str(tmp_path / "ck")
    ckpt.save(state, d, step=10)
    # a stale tmp dir (simulated crash) must be ignored
    os.makedirs(os.path.join(d, "step_00000020.tmp"))
    assert ckpt.latest_step(d) == 10
    restored, step = ckpt.restore(d, template=state)
    assert step == 10
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention(tmp_path, tiny):
    _, _, state, _ = tiny
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        ckpt.save(state, d, step=s, keep=2)
    assert ckpt.latest_step(d) == 4
    names = sorted(os.listdir(d))
    assert names == ["step_00000003", "step_00000004"]


def test_loop_resume_determinism(tmp_path):
    """Train 6 steps straight vs 3 + crash + resume 3: identical params."""
    cfg = get_config("yi-9b", smoke=True)
    tcfg = TrainConfig(adamw=opt.AdamWConfig(lr=1e-3, warmup_steps=0,
                                             total_steps=100))
    train_step, model = make_train_step(cfg, tcfg)
    train_step = jax.jit(train_step)
    data = SyntheticLM(cfg, batch=2, seq=16, seed=11)

    def fresh():
        return init_train_state(model, jax.random.PRNGKey(0))

    straight, _ = train_loop.run(
        train_step, fresh(), data,
        train_loop.LoopConfig(total_steps=6, ckpt_every=100,
                              ckpt_dir=str(tmp_path / "a"),
                              handle_signals=False),
    )
    # interrupted run: 3 steps, checkpoint, then resume to 6
    st1, rep1 = train_loop.run(
        train_step, fresh(), data,
        train_loop.LoopConfig(total_steps=3, ckpt_every=3,
                              ckpt_dir=str(tmp_path / "b"),
                              handle_signals=False),
    )
    st2, rep2 = train_loop.run(
        train_step, fresh(), data,
        train_loop.LoopConfig(total_steps=6, ckpt_every=3,
                              ckpt_dir=str(tmp_path / "b"),
                              handle_signals=False),
    )
    assert rep2.resumed_from == 3
    for a, b in zip(jax.tree_util.tree_leaves(straight["params"]),
                    jax.tree_util.tree_leaves(st2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_loop_preemption_writes_final_checkpoint(tmp_path):
    cfg = get_config("yi-9b", smoke=True)
    tcfg = TrainConfig(adamw=opt.AdamWConfig(warmup_steps=0, total_steps=100))
    train_step, model = make_train_step(cfg, tcfg)
    train_step = jax.jit(train_step)
    state = init_train_state(model, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, batch=2, seq=16, seed=1)

    fired = {"done": False}

    def on_step(step, metrics):
        if step == 2 and not fired["done"]:
            fired["done"] = True
            os.kill(os.getpid(), signal.SIGTERM)

    _, report = train_loop.run(
        train_step, state, data,
        train_loop.LoopConfig(total_steps=50, ckpt_every=100,
                              ckpt_dir=str(tmp_path / "c")),
        on_step=on_step,
    )
    assert report.preempted
    assert ckpt.latest_step(str(tmp_path / "c")) == report.final_step


def test_nan_fuse(tmp_path):
    def bad_step(state, batch):
        return state, {"loss": jnp.float32(jnp.nan)}

    with pytest.raises(FloatingPointError):
        train_loop.run(
            bad_step, {}, lambda s: {},
            train_loop.LoopConfig(total_steps=3, ckpt_dir=str(tmp_path / "n"),
                                  handle_signals=False),
        )


def test_straggler_watchdog(tmp_path):
    import time

    calls = []

    def slow_step(state, batch):
        if len(calls) == 3:
            time.sleep(0.25)
        return state, {"loss": jnp.float32(1.0)}

    def on_straggler(step, ratio):
        calls.append((step, ratio))

    state, report = train_loop.run(
        slow_step, {}, lambda s: calls.append("d") or {},
        train_loop.LoopConfig(total_steps=6, ckpt_every=100,
                              ckpt_dir=str(tmp_path / "ck"),
                              straggler_factor=3.0, handle_signals=False),
        on_straggler=on_straggler,
    )
    del state
    assert report.straggler_steps, report.step_times
    ratios = [c[1] for c in calls if isinstance(c, tuple)]
    assert ratios and ratios[0] > 3.0  # flagged ratio


def test_data_determinism_and_memmap(tmp_path):
    cfg = get_config("yi-9b", smoke=True)
    a = SyntheticLM(cfg, 4, 16, seed=5)(3)
    b = SyntheticLM(cfg, 4, 16, seed=5)(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg, 4, 16, seed=6)(3)
    assert not np.array_equal(a["tokens"], c["tokens"])

    path = str(tmp_path / "corpus.bin")
    np.arange(10000, dtype=np.uint32).tofile(path)
    mm = MemmapLM(path, cfg, batch=2, seq=16, seed=0)
    b0, b1 = mm(0), mm(0)
    np.testing.assert_array_equal(b0["tokens"], b1["tokens"])
    assert (b0["labels"][:, :-1] == b0["tokens"][:, 1:]).all()


def test_prefetcher_orders_steps():
    cfg = get_config("yi-9b", smoke=True)
    src = SyntheticLM(cfg, 2, 8, seed=0)
    pf = Prefetcher(src, start_step=5, depth=2)
    it = iter(pf)
    steps = [next(it)[0] for _ in range(4)]
    pf.stop()
    assert steps == [5, 6, 7, 8]


def test_elastic_restore_across_shardings(tmp_path):
    """Checkpoint on one topology, restore onto a 2-device mesh layout
    (host resharding path)."""
    cfg = get_config("yi-9b", smoke=True)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    d = str(tmp_path / "el")
    ckpt.save(state, d, step=5)
    # restore with default placement (single device here) but through the
    # resharding code path
    restored, step = ckpt.restore(d, template=state, shardings=None)
    assert step == 5
    n1 = jax.tree_util.tree_leaves(state)
    n2 = jax.tree_util.tree_leaves(restored)
    for a, b in zip(n1, n2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
