"""Seedable sampler statistical tests (DESIGN.md §5.3).

The Sampler is the serving-side sampling abstraction: greedy / temperature
/ top-k / top-p with per-request keys folded from (seed, token index).
These tests pin down the statistical contracts the serve identity matrix
relies on: temperature -> 0 collapses to exact greedy, top-k never leaves
the k-largest support, top-p keeps exactly the smallest prefix whose mass
reaches p, and keys are a pure function of (seed, index) — never of slot.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.draft import ngram_propose
from repro.serve.sampling import Sampler, greedy_sample, sample_keys


def _rand_logits(key, b=8, v=64):
    return jax.random.normal(jax.random.PRNGKey(key), (b, v), jnp.float32)


def _keys(seed_lo, n, idx=0):
    return sample_keys(
        jnp.arange(seed_lo, seed_lo + n, dtype=jnp.int32),
        jnp.full((n,), idx, jnp.int32),
    )


def test_greedy_matches_argmax_and_ignores_keys():
    logits = _rand_logits(0)
    s = Sampler("greedy")
    np.testing.assert_array_equal(
        np.asarray(s(logits)), np.asarray(jnp.argmax(logits, -1))
    )
    # 3-D logits sample the last position (the engine's prefill shape).
    np.testing.assert_array_equal(
        np.asarray(s(logits[:, None, :])), np.asarray(jnp.argmax(logits, -1))
    )
    np.testing.assert_array_equal(
        np.asarray(greedy_sample(logits[:, None, :])),
        np.asarray(jnp.argmax(logits, -1)),
    )


def test_temperature_to_zero_converges_to_greedy_exactly():
    """As temperature -> 0 the scaled logit gaps dwarf the Gumbel noise:
    the sample must EQUAL argmax, not just approach it."""
    logits = _rand_logits(1, b=16, v=128)
    cold = Sampler("temperature", temperature=1e-6)
    ref = np.asarray(jnp.argmax(logits, -1))
    for trial in range(8):
        got = np.asarray(cold(logits, _keys(100 * trial, 16)))
        np.testing.assert_array_equal(got, ref)


def test_warm_temperature_actually_samples():
    """Sanity check that the statistical tests below aren't vacuous: at
    temperature 1 different keys produce different draws somewhere."""
    logits = _rand_logits(2, b=4, v=16)
    warm = Sampler("temperature", temperature=1.0)
    draws = {
        tuple(np.asarray(warm(logits, _keys(t, 4)))) for t in range(32)
    }
    assert len(draws) > 1


def test_top_k_never_leaves_top_k_support():
    logits = _rand_logits(3, b=4, v=32)
    for k in (1, 2, 5):
        s = Sampler("top_k", top_k=k, temperature=1.0)
        allowed = np.asarray(
            jnp.argsort(logits, axis=-1)[:, -k:]
        )
        for trial in range(64):
            got = np.asarray(s(logits, _keys(1000 + trial, 4)))
            for b in range(4):
                assert got[b] in allowed[b], (
                    f"top_k={k} emitted token {got[b]} outside the "
                    f"{k}-largest logits of row {b}"
                )


def test_top_k_one_is_greedy():
    logits = _rand_logits(4)
    s = Sampler("top_k", top_k=1, temperature=1.0)
    np.testing.assert_array_equal(
        np.asarray(s(logits, _keys(0, logits.shape[0]))),
        np.asarray(jnp.argmax(logits, -1)),
    )


def test_top_p_mass_bound_on_crafted_logits():
    """Crafted distribution [0.5, 0.3, 0.15, 0.05]: the kept set is the
    smallest prefix whose mass reaches top_p.  Thresholds sit away from
    the cumulative-mass boundaries (0.5, 0.8, 0.95) so float rounding in
    the softmax cannot flip the expected support."""
    probs = np.asarray([0.5, 0.3, 0.15, 0.05])
    logits = jnp.asarray(np.log(probs))[None, :].repeat(4, axis=0)

    def support(p, trials=96):
        s = Sampler("top_p", top_p=p, temperature=1.0)
        out = set()
        for t in range(trials):
            out.update(int(x) for x in np.asarray(s(logits, _keys(t, 4))))
        return out

    assert support(0.45) == {0}
    assert support(0.75) == {0, 1}
    assert support(0.9) == {0, 1, 2}
    assert support(1.0) == {0, 1, 2, 3}


def test_top_p_mass_bound_random_logits():
    """On random logits, every emitted token must belong to the smallest
    prefix (by descending probability) whose cumulative mass >= top_p."""
    logits = _rand_logits(5, b=4, v=32)
    p = 0.7
    s = Sampler("top_p", top_p=p, temperature=1.0)
    probs = np.asarray(jax.nn.softmax(logits, axis=-1))
    order = np.argsort(-probs, axis=-1)
    allowed = []
    for b in range(4):
        csum = np.cumsum(probs[b][order[b]])
        n_keep = int(np.searchsorted(csum, p)) + 1
        allowed.append(set(order[b][:n_keep].tolist()))
    for trial in range(64):
        got = np.asarray(s(logits, _keys(5000 + trial, 4)))
        for b in range(4):
            assert int(got[b]) in allowed[b]


def test_sample_keys_are_slot_independent():
    """The key for (seed, index) must not depend on the position within the
    batch vector — the property that makes re-ordered submissions
    reproduce identical streams."""
    k1 = sample_keys(jnp.asarray([5, 9], jnp.int32), jnp.asarray([3, 3]))
    k2 = sample_keys(jnp.asarray([9, 5], jnp.int32), jnp.asarray([3, 3]))
    np.testing.assert_array_equal(np.asarray(k1[0]), np.asarray(k2[1]))
    np.testing.assert_array_equal(np.asarray(k1[1]), np.asarray(k2[0]))
    # Distinct (seed, index) pairs get distinct keys.
    k3 = sample_keys(jnp.asarray([5], jnp.int32), jnp.asarray([4]))
    assert not np.array_equal(np.asarray(k1[0]), np.asarray(k3[0]))


def test_sampler_validation():
    with pytest.raises(ValueError, match="mode"):
        Sampler("beam")
    with pytest.raises(ValueError, match="top_k"):
        Sampler("top_k", top_k=0)
    with pytest.raises(ValueError, match="top_p"):
        Sampler("top_p", top_p=0.0)
    with pytest.raises(AssertionError, match="keys"):
        Sampler("temperature")(jnp.zeros((1, 4)))


def test_ngram_proposer_suffix_match_and_fallback():
    """Draft = continuation of the most recent earlier suffix occurrence;
    no occurrence (or too-short history) falls back to the last token."""
    hist = jnp.zeros((3, 12), jnp.int32)
    hist = hist.at[0, :7].set(jnp.asarray([1, 2, 3, 4, 1, 2, 3]))
    hist = hist.at[1, :4].set(jnp.asarray([9, 8, 7, 6]))
    hist = hist.at[2, :2].set(jnp.asarray([5, 5]))
    hlen = jnp.asarray([7, 4, 2], jnp.int32)
    d = np.asarray(ngram_propose(hist, hlen, ngram=3, k=4))
    # Slot 0: suffix [1,2,3] matched at p=0 -> continuation [4,1,2,3].
    np.testing.assert_array_equal(d[0], [4, 1, 2, 3])
    # Slot 1: no earlier occurrence -> repeat last token.
    np.testing.assert_array_equal(d[1], [6, 6, 6, 6])
    # Slot 2: history shorter than the ngram -> fallback.
    np.testing.assert_array_equal(d[2], [5, 5, 5, 5])


def test_ngram_proposer_prefers_most_recent_match():
    # [7,8] occurs at p=0 (-> 1) and p=3 (-> 2): the later context wins.
    hist = jnp.asarray([[7, 8, 1, 7, 8, 2, 0, 7, 8]], jnp.int32)
    d = np.asarray(ngram_propose(hist, jnp.asarray([9]), ngram=2, k=2))
    np.testing.assert_array_equal(d[0], [2, 0])
