import os

import numpy as np
import pytest

# Hypothesis profiles: CI runs the property suites (allocator refcounts,
# state machine) under the fixed, derandomized "ci" profile so failures
# reproduce exactly across runs; "dev" keeps random exploration locally.
# Per-test @settings decorators override only the fields they name, so
# derandomization applies to every suite.  Soft dependency — the property
# tests importorskip hypothesis themselves.
try:
    from hypothesis import settings

    settings.register_profile("ci", derandomize=True, deadline=None)
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:
    pass


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
