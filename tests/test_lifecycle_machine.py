"""Hypothesis state machine for the request lifecycle (DESIGN.md §5.5).

Random submit / cancel / expire / step / kill+restore interleavings
against a REAL tiny ``ServeEngine`` with chaos knobs armed — step()
internally exercises preemption, seeded alloc refusals and forced
preemptions, and the kill rule snapshots + hard-resets + restores the
engine mid-example (DESIGN.md §5.6) — asserting the full
engine/allocator/trie conservation invariant after every rule.
Separate from ``test_lifecycle`` so the deterministic lifecycle tests
still run when hypothesis is absent (this module then skips, like
``test_alloc_property``; see requirements-dev.txt).
"""
import dataclasses
import os
import tempfile

import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import settings, strategies as st  # noqa: E402
from hypothesis.stateful import (  # noqa: E402
    RuleBasedStateMachine,
    invariant,
    rule,
)

from repro.models import build_model, get_config  # noqa: E402
from repro.serve.engine import (  # noqa: E402
    AdmissionReject,
    Request,
    ServeEngine,
)

_ENGINE = None


def _shared_engine():
    """One tiny REAL engine reused across examples (compile once); every
    example starts from a drained engine — stats accumulate, but the
    invariants are state- not counter-based."""
    global _ENGINE
    if _ENGINE is None:
        cfg = dataclasses.replace(
            get_config("yi-9b", smoke=True),
            cache_layout="paged", kv_page_size=8,
            prefix_sharing=True, chaos_alloc_fail_p=0.2,
            chaos_preempt_p=0.2, chaos_seed=99,
        )
        params = build_model(cfg).init(jax.random.PRNGKey(9))
        _ENGINE = ServeEngine(cfg, params, batch_slots=2, max_len=16,
                              chunk_size=2, n_pages=3, max_queue=6)
    return _ENGINE


class LifecycleMachine(RuleBasedStateMachine):
    """Interleaves submit / cancel / expire (via deadlines) / step —
    which internally exercises preempt, chaos alloc failures and forced
    preemptions — against a real ServeEngine, asserting the full
    engine/allocator/trie conservation invariant after every rule.  The
    per-wave auto-check inside the engine (armed by the chaos knobs)
    additionally fires mid-step."""

    def __init__(self):
        super().__init__()
        self.eng = _shared_engine()
        while self.eng.step():               # drain any prior example
            pass
        self.rng = np.random.default_rng(17)
        self.inflight: list[Request] = []

    @rule(n_prompt=st.integers(min_value=1, max_value=6),
          budget=st.integers(min_value=1, max_value=6),
          deadline=st.sampled_from([None, 60.0, 1e-6]))
    def do_submit(self, n_prompt, budget, deadline):
        r = Request(
            prompt=self.rng.integers(
                0, self.eng.cfg.vocab, size=n_prompt
            ).astype(np.int32),
            max_new_tokens=budget,
            deadline_s=deadline,
            seed=int(self.rng.integers(0, 2 ** 31)),
        )
        try:
            self.eng.submit([r])
            self.inflight.append(r)
        except AdmissionReject as e:
            assert e.reason in ("queue_full", "pool_too_small", "max_len")

    @rule(data=st.data())
    def do_cancel(self, data):
        live = [r for r in self.inflight if not r.done]
        if not live:
            return
        r = live[data.draw(st.integers(0, len(live) - 1), label="victim")]
        assert self.eng.cancel(r.id)

    @rule()
    def do_step(self):
        self.eng.step()

    @rule()
    def do_kill_and_restore(self):
        """In-process kill: snapshot host truth, discard EVERY device
        buffer and host structure via restore (which hard-resets before
        re-enqueueing), and continue the example on the rebuilt state.
        restore() constructs NEW Request objects, so the machine re-syncs
        its handles by id — exactly what a recovering client does."""
        path = os.path.join(
            tempfile.gettempdir(), f"lifecycle-machine-{os.getpid()}.json"
        )
        self.eng.snapshot(path)
        self.eng.restore(path)
        self.inflight = [self.eng.request(r.id) for r in self.inflight]
        assert all(r is not None for r in self.inflight)

    @invariant()
    def conserved(self):
        self.eng.check_invariants()

    def teardown(self):
        while self.eng.step():
            pass
        self.eng.check_invariants()
        for r in self.inflight:
            assert r.done and r.status in (
                "finished", "cancelled", "expired"
            )
            if r.status == "finished":
                assert len(r.generated) == r.max_new_tokens
        assert sorted(self.eng.free_pages) == list(range(self.eng.n_pages))


LifecycleMachine.TestCase.settings = settings(
    max_examples=8, stateful_step_count=12, deadline=None,
)
TestLifecycleMachine = LifecycleMachine.TestCase
