"""repro.lint: per-rule positive/negative fixtures, suppression and
baseline round-trips, CLI exit codes, and the repo self-check.

Fixture snippets are deliberately tiny and self-contained: each one
isolates exactly the pattern a rule must (or must not) flag, so a rule
regression points at one failing fixture instead of a pile of repo
findings.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.lint import (
    all_rules,
    analyze_source,
    load_baseline,
    run_lint,
    write_baseline,
)
from repro.lint.baseline import filter_findings

REPO = Path(__file__).resolve().parents[1]


def lint(code, path="src/repro/serve/mod.py", rules=None):
    findings, _ = analyze_source(textwrap.dedent(code), path=path,
                                 rules=rules)
    return findings


def rule_ids(findings):
    return sorted({f.rule for f in findings})


# --------------------------------------------------------------------------
# Registry


def test_at_least_six_active_rules():
    ids = [r.id for r in all_rules()]
    assert len(ids) >= 6
    assert ids == sorted(ids)
    for rid in ("R001", "R002", "R003", "R004", "R005", "R006"):
        assert rid in ids


# --------------------------------------------------------------------------
# R001 host-sync-in-hot-loop


def test_r001_asarray_on_device_in_loop():
    findings = lint("""
        import jax.numpy as jnp
        import numpy as np

        def f(n):
            x = jnp.zeros((4,))
            out = []
            for _ in range(n):
                out.append(np.asarray(x))
            return out
    """)
    assert "R001" in rule_ids(findings)


def test_r001_item_in_loop():
    findings = lint("""
        import jax.numpy as jnp

        def f(flags):
            x = jnp.zeros(())
            total = 0.0
            for _ in flags:
                total += x.item()
            return total
    """)
    assert "R001" in rule_ids(findings)


def test_r001_device_get_inside_jit():
    findings = lint("""
        import jax

        @jax.jit
        def f(x):
            return jax.device_get(x)
    """)
    assert "R001" in rule_ids(findings)
    assert "inside jit-traced code" in findings[0].message


def test_r001_implicit_bool_of_device_array():
    findings = lint("""
        import jax.numpy as jnp

        def f():
            x = jnp.ones((3,))
            if x.sum() > 0:
                return 1
            return 0
    """)
    assert "R001" in rule_ids(findings)


def test_r001_negative_batched_device_get_outside_loop():
    findings = lint("""
        import jax
        import jax.numpy as jnp

        def f(n):
            xs = [jnp.zeros((4,)) for _ in range(n)]
            host = jax.device_get(xs)     # one batched transfer
            return [h.sum() for h in host]
    """)
    assert "R001" not in rule_ids(findings)


def test_r001_negative_numpy_only_loop():
    findings = lint("""
        import numpy as np

        def f(n):
            x = np.zeros((4,))
            out = []
            for _ in range(n):
                out.append(np.asarray(x))   # host->host, free
            return out
    """)
    assert "R001" not in rule_ids(findings)


# --------------------------------------------------------------------------
# R002 recompile-hazard


def test_r002_branch_on_traced_value():
    findings = lint("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    assert "R002" in rule_ids(findings)


def test_r002_traced_shape():
    findings = lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(n):
            return jnp.zeros((n,))
    """)
    assert "R002" in rule_ids(findings)


def test_r002_jit_inside_loop():
    findings = lint("""
        import jax

        def f(fns, x):
            out = []
            for _ in range(3):
                g = jax.jit(step)
                out.append(g(x))
            return out
    """)
    assert "R002" in rule_ids(findings)


def test_r002_negative_static_argnames():
    findings = lint("""
        import functools
        import jax
        import jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("n",))
        def f(x, n):
            if n > 2:
                return jnp.zeros((n,))
            return x
    """)
    assert "R002" not in rule_ids(findings)


def test_r002_negative_is_none_and_shape_derived():
    findings = lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x, lengths=None):
            b, d = x.shape
            if lengths is None:
                lengths = jnp.full((b,), d)
            return jnp.zeros((b, d)) + lengths[:, None]
    """)
    assert rule_ids(findings) == []


# --------------------------------------------------------------------------
# R003 donation-violation


def test_r003_read_after_donation():
    findings = lint("""
        import jax

        def g(a, b):
            return a + b

        gg = jax.jit(g, donate_argnums=(0,))

        def caller(x, y):
            z = gg(x, y)
            return x + z
    """)
    assert "R003" in rule_ids(findings)
    assert "donated" in findings[0].message


def test_r003_negative_rebound_target():
    findings = lint("""
        import jax

        def g(a, b):
            return a + b

        gg = jax.jit(g, donate_argnums=(0,))

        def caller(x, y):
            x = gg(x, y)
            return x + 1
    """)
    assert "R003" not in rule_ids(findings)


def test_r003_self_attribute_round_trip():
    # The engine idiom: donated self-attrs reassigned by the same
    # statement are fine; a forgotten one is not.
    findings = lint("""
        import jax

        class Eng:
            def __init__(self):
                self._step = jax.jit(self._step_fn, donate_argnums=(0, 1))

            def _step_fn(self, cache, tok):
                return cache, tok

            def good(self, tok):
                self.cache, self.tok = self._step(self.cache, self.tok)
                return self.tok

            def bad(self, tok):
                out = self._step(self.cache, self.tok)
                return self.cache
    """)
    assert [f.symbol for f in findings if f.rule == "R003"] == ["Eng.bad"]


# --------------------------------------------------------------------------
# R004 nondeterminism


def test_r004_set_iteration():
    findings = lint("""
        def dispatch(items, handle):
            for x in set(items):
                handle(x)
    """)
    assert "R004" in rule_ids(findings)


def test_r004_negative_sorted_set():
    findings = lint("""
        def dispatch(items, handle):
            for x in sorted(set(items)):
                handle(x)
            drift = sorted(k for k in set(items) | {0})
            return drift
    """)
    assert "R004" not in rule_ids(findings)


def test_r004_time_time_in_serve_tier_only():
    code = """
        import time

        def stamp():
            return time.time()
    """
    assert "R004" in rule_ids(lint(code, path="src/repro/serve/x.py"))
    assert "R004" not in rule_ids(lint(code, path="benchmarks/x.py"))


def test_r004_unseeded_rng():
    findings = lint("""
        import numpy as np

        def f():
            good = np.random.default_rng(42)
            bad = np.random.default_rng()
            return good, bad
    """)
    r4 = [f for f in findings if f.rule == "R004"]
    assert len(r4) == 1
    assert "default_rng" in r4[0].message


# --------------------------------------------------------------------------
# R005 refcount-balance


def test_r005_dropped_alloc_result():
    findings = lint("""
        def f(allocator):
            allocator.alloc(3)
    """)
    assert "R005" in rule_ids(findings)
    assert "dropped" in findings[0].message


def test_r005_unchecked_share():
    findings = lint("""
        def f(allocator, pages):
            allocator.share(pages)
    """)
    assert "R005" in rule_ids(findings)


def test_r005_branch_leak():
    findings = lint("""
        def f(allocator, flag):
            ids = allocator.alloc(2)
            if ids is None:
                return None
            if flag:
                allocator.release(ids)
            return 1
    """)
    assert "R005" in rule_ids(findings)


def test_r005_negative_balanced_paths():
    findings = lint("""
        def f(allocator, flag):
            ids = allocator.alloc(2)
            if ids is None:
                return None
            if flag:
                allocator.release(ids)
                return None
            return ids
    """)
    assert "R005" not in rule_ids(findings)


def test_r005_negative_escape_into_owned_state():
    findings = lint("""
        def f(self, allocator, slot):
            ids = allocator.alloc(2)
            if ids is None:
                return False
            self.slot_pages[slot] = ids
            return True
    """)
    assert "R005" not in rule_ids(findings)


def test_r005_negative_raising_path_exempt():
    findings = lint("""
        def f(allocator):
            ids = allocator.alloc(2)
            if ids is None:
                raise MemoryError("pool exhausted")
            return ids
    """)
    assert "R005" not in rule_ids(findings)


# --------------------------------------------------------------------------
# R006 pallas-grid-shape


PALLAS_PREAMBLE = """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from repro.kernels.common import cdiv

    def k(x_ref, o_ref):
        o_ref[...] = x_ref[...]
"""


def test_r006_index_map_arity_mismatch():
    findings = lint(PALLAS_PREAMBLE + """
        def call(x):
            return pl.pallas_call(
                k,
                grid=(4, 4),
                in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            )(x)
    """)
    r6 = [f for f in findings if f.rule == "R006"]
    assert len(r6) == 1
    assert "does not cover the grid" in r6[0].message


def test_r006_return_length_mismatch():
    findings = lint(PALLAS_PREAMBLE + """
        def call(x):
            return pl.pallas_call(
                k,
                grid=(4, 4),
                in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i,))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            )(x)
    """)
    r6 = [f for f in findings if f.rule == "R006"]
    assert len(r6) == 1
    assert "misaligned" in r6[0].message


def test_r006_grid_spec_unwrapped_scalar_prefetch():
    # grid/in_specs inside a pltpu.PrefetchScalarGridSpec are checked too:
    # scalar-prefetch refs arrive as trailing positional index-map args,
    # so arity grid_len + num_scalar_prefetch is accepted ...
    findings = lint(PALLAS_PREAMBLE + """
        from jax.experimental.pallas import tpu as pltpu

        def call(x, tbl, n):
            return pl.pallas_call(
                k,
                grid_spec=pltpu.PrefetchScalarGridSpec(
                    num_scalar_prefetch=1,
                    grid=(cdiv(n, 8),),
                    in_specs=[pl.BlockSpec(
                        (8,), lambda i, pt: (pt[i],))],
                    out_specs=pl.BlockSpec((8,), lambda i, pt: (i,)),
                ),
            )(tbl, x)
    """)
    assert "R006" not in rule_ids(findings)


def test_r006_grid_spec_bad_arity_and_floor_div_flagged():
    # ... while a map that covers neither the grid alone nor grid +
    # prefetch refs is flagged, and grid floor-div arithmetic inside the
    # grid_spec still needs exactness evidence.
    findings = lint(PALLAS_PREAMBLE + """
        from jax.experimental.pallas import tpu as pltpu

        def call(x, tbl, n):
            return pl.pallas_call(
                k,
                grid_spec=pltpu.PrefetchScalarGridSpec(
                    num_scalar_prefetch=1,
                    grid=(n // 8, 4),
                    in_specs=[pl.BlockSpec(
                        (8, 8), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec(
                        (8, 8), lambda i, j, pt: (i, j)),
                ),
            )(tbl, x)
    """)
    r6 = [f for f in findings if f.rule == "R006"]
    assert len(r6) == 2
    assert any("cdiv" in f.message for f in r6)
    assert any("does not cover the grid" in f.message for f in r6)


def test_r006_floor_div_grid_without_evidence():
    findings = lint(PALLAS_PREAMBLE + """
        def call(x, n):
            return pl.pallas_call(
                k,
                grid=(n // 8,),
                in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
                out_specs=pl.BlockSpec((8,), lambda i: (i,)),
            )(x)
    """)
    r6 = [f for f in findings if f.rule == "R006"]
    assert len(r6) == 1
    assert "cdiv" in r6[0].message


def test_r006_negative_ceil_div_idioms():
    findings = lint(PALLAS_PREAMBLE + """
        def call_cdiv(x, n):
            return pl.pallas_call(
                k,
                grid=(cdiv(n, 8),),
                in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
                out_specs=pl.BlockSpec((8,), lambda i: (i,)),
            )(x)

        def call_padded(x, n):
            n_pad = cdiv(n, 8) * 8
            grid = (n_pad // 8,)
            return pl.pallas_call(
                k,
                grid=grid,
                in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
                out_specs=pl.BlockSpec((8,), lambda i: (i,)),
            )(x)

        def call_asserted(x, n):
            assert n % 8 == 0
            return pl.pallas_call(
                k,
                grid=(n // 8,),
                in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
                out_specs=pl.BlockSpec((8,), lambda i: (i,)),
            )(x)
    """)
    assert "R006" not in rule_ids(findings)


def test_r006_keyword_defaults_excluded_from_arity():
    # The decode-attention idiom: trailing kw-defaulted lambda params
    # carry closure constants and don't consume grid axes.
    findings = lint(PALLAS_PREAMBLE + """
        def call(x, steps):
            return pl.pallas_call(
                k,
                grid=(4, 4),
                in_specs=[pl.BlockSpec(
                    (8, 8), lambda i, j, ks=steps: (i, j))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            )(x)
    """)
    assert "R006" not in rule_ids(findings)


# --------------------------------------------------------------------------
# Suppression + baseline


def test_inline_suppression_same_line():
    code = textwrap.dedent("""
        import jax.numpy as jnp
        import numpy as np

        def f(n):
            x = jnp.zeros((4,))
            out = []
            for _ in range(n):
                out.append(np.asarray(x))  # repro-lint: disable=R001 -- fixture
            return out
    """)
    findings, suppressed = analyze_source(code, path="src/repro/serve/m.py")
    assert "R001" not in rule_ids(findings)
    assert suppressed == 1


def test_inline_suppression_preceding_line():
    code = textwrap.dedent("""
        import time

        def stamp():
            # repro-lint: disable=R004 -- wall-clock timestamp is the point
            return time.time()
    """)
    findings, suppressed = analyze_source(code, path="src/repro/serve/m.py")
    assert "R004" not in rule_ids(findings)
    assert suppressed == 1


def test_suppression_is_rule_specific():
    code = textwrap.dedent("""
        import time

        def stamp():
            return time.time()  # repro-lint: disable=R001 -- wrong rule id
    """)
    findings, suppressed = analyze_source(code, path="src/repro/serve/m.py")
    assert "R004" in rule_ids(findings)
    assert suppressed == 0


def test_baseline_round_trip(tmp_path):
    bad = tmp_path / "serve"
    bad.mkdir()
    (bad / "mod.py").write_text(textwrap.dedent("""
        import time

        def stamp():
            return time.time()

        def leak(allocator):
            allocator.alloc(2)
    """))
    # The /serve/ path segment puts the fixture in the deterministic tier.
    first = run_lint([str(tmp_path)], root=str(tmp_path.parent))
    assert len(first.findings) == 2
    bl_path = tmp_path / "baseline.json"
    write_baseline(str(bl_path), first.findings, reason="fixture")
    baseline = load_baseline(str(bl_path))
    second = run_lint([str(tmp_path)], baseline=baseline,
                      root=str(tmp_path.parent))
    assert second.findings == []
    assert second.baseline_suppressed == 2
    # A NEW finding in a baselined file still fails.
    (bad / "mod.py").write_text(
        (bad / "mod.py").read_text()
        + "\ndef stamp2():\n    return time.time()\n"
    )
    third = run_lint([str(tmp_path)], baseline=baseline,
                     root=str(tmp_path.parent))
    assert len(third.findings) == 1
    assert third.findings[0].symbol == "stamp2"


def test_filter_findings_counts_within_symbol(tmp_path):
    mod = tmp_path / "serve"
    mod.mkdir()
    (mod / "m.py").write_text(textwrap.dedent("""
        import time

        def f():
            a = time.time()
            b = time.time()
            return a + b
    """))
    first = run_lint([str(tmp_path)], root=str(tmp_path.parent))
    assert len(first.findings) == 2
    baseline = {("R004", f"{tmp_path.name}/serve/m.py", "f"): 1}
    kept, suppressed = filter_findings(first.findings, baseline)
    assert suppressed == 1 and len(kept) == 1


# --------------------------------------------------------------------------
# CLI


def _run_cli(args, cwd):
    return subprocess.run(
        [sys.executable, "-m", "repro.lint", *args],
        capture_output=True, text=True, cwd=cwd,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )


def test_cli_exits_nonzero_on_seeded_violation(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent("""
        import jax.numpy as jnp
        import numpy as np

        def f(n):
            x = jnp.zeros((4,))
            return [float(np.asarray(x).sum()) or float(x[0])
                    for _ in range(n)]
    """))
    proc = _run_cli([str(bad), "--no-baseline", "--json", "-"], tmp_path)
    assert proc.returncode == 1, proc.stderr
    report = json.loads(proc.stdout)
    assert len(report["rules_run"]) >= 6
    assert report["findings"]
    assert {"rule", "path", "line", "col", "symbol", "message"} <= set(
        report["findings"][0]
    )
    assert "wall_s" in report and "baseline_suppressed" in report


def test_cli_exits_zero_on_clean_file(tmp_path):
    good = tmp_path / "good.py"
    good.write_text("def f(x):\n    return x + 1\n")
    proc = _run_cli([str(good), "--no-baseline"], tmp_path)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_list_rules(tmp_path):
    proc = _run_cli(["--list-rules"], tmp_path)
    assert proc.returncode == 0
    for rid in ("R001", "R002", "R003", "R004", "R005", "R006"):
        assert rid in proc.stdout


# --------------------------------------------------------------------------
# Repo self-check: the tree lints clean modulo the committed baseline


def test_repo_lints_clean_modulo_baseline():
    baseline_path = REPO / "lint_baseline.json"
    assert baseline_path.is_file(), "committed baseline missing"
    baseline = load_baseline(str(baseline_path))
    result = run_lint(
        [str(REPO / "src"), str(REPO / "benchmarks"), str(REPO / "examples")],
        baseline=baseline,
        root=str(REPO),
    )
    assert not result.errors, result.errors
    rendered = "\n".join(f.render() for f in result.findings)
    assert not result.findings, f"repo has lint findings:\n{rendered}"
    assert len(result.rules_run) >= 6
