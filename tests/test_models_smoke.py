"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values; prefill+decode == forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCHS, build_model, get_config


def _batch_for(cfg, b=2, s=16, key=jax.random.PRNGKey(0)):
    ks = jax.random.split(key, 3)
    toks = jax.random.randint(ks[0], (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["vis"] = jax.random.normal(
            ks[1], (b, cfg.n_vis_tokens, cfg.d_model), jnp.float32
        )
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[2], (b, cfg.enc_seq, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch_for(cfg, b, s)

    loss, metrics = model.loss(params, batch)
    assert jnp.isfinite(loss), (arch, loss)

    kwargs = {}
    if cfg.family == "vlm":
        kwargs["vis"] = batch["vis"]
    if cfg.family == "encdec":
        kwargs["frames"] = batch["frames"]
    logits, aux = model.forward(params, batch["tokens"], **kwargs)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    # one SGD-ish step: grads exist and are finite
    def scalar_loss(p):
        return model.loss(p, batch)[0]

    grads = jax.grad(scalar_loss)(params)
    gnorm = sum(
        float(jnp.sum(jnp.square(g.astype(jnp.float32))))
        for g in jax.tree_util.tree_leaves(grads)
    )
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 12
    batch = _batch_for(cfg, b, s, key=jax.random.PRNGKey(7))
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["vis"] = batch["vis"]
    if cfg.family == "encdec":
        kwargs["frames"] = batch["frames"]

    cache = model.init_cache(
        params, batch=b, max_len=s + 4,
        **({"vis": batch.get("vis")} if cfg.family == "vlm" else {}),
        **({"frames": batch.get("frames")} if cfg.family == "encdec" else {}),
    )
    _, cache = model.prefill(params, cache, batch["tokens"][:, : s - 1])
    step_logits, cache = model.decode_step(
        params, cache, batch["tokens"][:, s - 1: s]
    )
    full_logits, _ = model.forward(params, batch["tokens"], **kwargs)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        rtol=3e-2, atol=3e-2,
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_counts_sane(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    assert 1e8 < n < 2e11, (arch, n)
    assert cfg.padded_vocab % 16 == 0
    if cfg.family == "moe":
        assert cfg.active_param_count() < n


def test_moe_sorted_dispatch_matches_dense():
    """Capacity-based sorted dispatch == dense one-hot dispatch when no
    tokens overflow capacity (A4 beyond-paper optimization)."""
    import dataclasses as dc

    from repro.configs.base import ModelConfig
    from repro.models import common as cm

    cfg_d = ModelConfig(
        arch="t", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=48, vocab=64, vocab_pad_multiple=64, n_experts=8,
        top_k=2, capacity_factor=8.0, dtype="float32",
    )
    cfg_s = dc.replace(cfg_d, moe_dispatch="sorted")
    p = cm.moe_init(jax.random.PRNGKey(0), cfg_d)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    yd, auxd = cm.apply_moe(p, x, cfg_d)
    ys, auxs = cm.apply_moe(p, x, cfg_s)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(ys),
                               rtol=2e-4, atol=2e-4)
    gd = jax.grad(lambda q: cm.apply_moe(q, x, cfg_d)[0].sum())(p)
    gs = jax.grad(lambda q: cm.apply_moe(q, x, cfg_s)[0].sum())(p)
    for a, b in zip(jax.tree_util.tree_leaves(gd),
                    jax.tree_util.tree_leaves(gs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)
    # tight capacity drops tokens but stays finite
    yt, _ = cm.apply_moe(p, x, dc.replace(cfg_s, capacity_factor=0.5))
    assert bool(jnp.isfinite(yt).all())
