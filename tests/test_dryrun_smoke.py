"""Dry-run machinery smoke tests (subprocess: needs 512 forced devices).

One cheap cell end-to-end proves: production mesh builds, shardings apply,
AOT compile succeeds, roofline terms emerge.  The full 32-cell x 2-mesh
sweep runs via `python -m repro.launch.dryrun --sweep` (see EXPERIMENTS.md).
"""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(1500)
def test_dryrun_single_cell(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "olmoe-1b-7b", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=1400, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    out = tmp_path / "olmoe-1b-7b__decode_32k__single.json"
    assert out.exists()
    rec = json.loads(out.read_text())
    rl = rec["roofline"]
    assert rl["dominant"] in ("compute", "memory", "collective")
    assert rl["t_memory_s"] > 0
    assert rec["counted"]["flops"] > 0
    assert rec["memory_analysis"]["temp_size_in_bytes"] > 0


def test_roofline_parser_units():
    from repro.launch import roofline

    hlo = """
  %ag = bf16[16,512,128]{2,1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar.1 = f32[1024]{0} all-reduce(%p1), replica_groups=[16,16]<=[256], to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%p2), replica_groups={{0,1}}, dimensions={0}
  %cp = bf16[256,256]{1,0} collective-permute(%p3), source_target_pairs={{0,1}}
"""
    out = roofline.parse_collectives(hlo)
    pk = out["per_kind"]
    assert pk["all-gather"]["count"] == 1
    ag_bytes = 16 * 512 * 128 * 2
    assert pk["all-gather"]["result_bytes"] == ag_bytes
    assert pk["all-gather"]["moved_bytes"] == pytest.approx(ag_bytes * 3 / 4)
    assert pk["all-reduce"]["moved_bytes"] == pytest.approx(
        2 * 1024 * 4 * 15 / 16
    )
    assert pk["reduce-scatter"]["moved_bytes"] == pytest.approx(64 * 4 * 1)
    assert pk["collective-permute"]["count"] == 1
    assert out["total_count"] == 4


def test_model_flops_accounting():
    from repro.configs.base import SHAPES
    from repro.launch.roofline import model_flops
    from repro.models import get_config

    cfg = get_config("yi-9b")
    train = model_flops(cfg, SHAPES["train_4k"])
    assert train == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=1e-6)
    dec = model_flops(cfg, SHAPES["decode_32k"])
    assert dec == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)
    moe = get_config("phi3.5-moe-42b-a6.6b")
    assert model_flops(moe, SHAPES["train_4k"]) < 6 * moe.param_count() * 256 * 4096
