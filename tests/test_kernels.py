"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

TOL = {jnp.float32: dict(rtol=2e-3, atol=2e-3),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestMatmul:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("mkn", [(256, 128, 256), (512, 384, 128),
                                     (130, 70, 90)])
    @pytest.mark.parametrize("kwargs", [
        dict(bm=128, bn=128, bk=128),
        dict(bm=128, bn=128, bk=128, split_k=3),
    ])
    def test_vs_ref(self, dtype, mkn, kwargs):
        from repro.kernels.matmul import ref
        from repro.kernels.matmul.ops import matmul

        m, k, n = mkn
        a = _rand(jax.random.PRNGKey(0), (m, k), dtype)
        b = _rand(jax.random.PRNGKey(1), (k, n), dtype)
        got = matmul(a, b, **kwargs)
        want = ref.matmul(a, b)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype],
        )

    def test_grid_orders_match(self):
        from repro.kernels.matmul.matmul import matmul as kern

        a = _rand(jax.random.PRNGKey(0), (256, 256), jnp.float32)
        b = _rand(jax.random.PRNGKey(1), (256, 256), jnp.float32)
        y1 = kern(a, b, bm=128, bn=128, bk=128, order="mnk")
        y2 = kern(a, b, bm=128, bn=128, bk=128, order="nmk")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5)

    def test_engine_planned(self):
        from repro.core import make_engine
        from repro.kernels.matmul.ops import matmul

        a = jnp.ones((200, 300), jnp.float32)
        b = jnp.ones((300, 100), jnp.float32)
        y = matmul(a, b, engine=make_engine())
        np.testing.assert_allclose(np.asarray(y), 300.0, rtol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("cfg", [
        (2, 4, 2, 256, 256, 64, True, 0),
        (1, 8, 1, 128, 256, 32, True, 128),
        (2, 4, 4, 100, 100, 64, False, 0),
        (1, 6, 2, 192, 64, 128, False, 0),
    ])
    def test_vs_ref(self, dtype, cfg):
        from repro.kernels.flash_attention import ref
        from repro.kernels.flash_attention.ops import flash_attention

        b, hq, hkv, sq, skv, d, causal, off = cfg
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = _rand(ks[0], (b, hq, sq, d), dtype)
        k = _rand(ks[1], (b, hkv, skv, d), dtype)
        v = _rand(ks[2], (b, hkv, skv, d), dtype)
        got = flash_attention(q, k, v, causal=causal, q_offset=off,
                              bq=64, bkv=64)
        want = ref.attention(q, k, v, causal=causal, q_offset=off)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype],
        )


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("cfg", [
        (2, 8, 2, 512, 64, 128, 1), (2, 8, 2, 512, 64, 128, 4),
        (3, 4, 4, 300, 32, 64, 2), (1, 16, 1, 1024, 128, 256, 8),
    ])
    def test_vs_ref_ragged(self, dtype, cfg):
        from repro.kernels.decode_attention import ref
        from repro.kernels.decode_attention.ops import decode_attention

        b, hq, hkv, s, d, bkv, splits = cfg
        ks = jax.random.split(jax.random.PRNGKey(2), 4)
        q = _rand(ks[0], (b, hq, d), dtype)
        k = _rand(ks[1], (b, hkv, s, d), dtype)
        v = _rand(ks[2], (b, hkv, s, d), dtype)
        lengths = jax.random.randint(ks[3], (b,), 1, s + 1).astype(jnp.int32)
        got = decode_attention(q, k, v, lengths, bkv=bkv, splits=splits)
        want = ref.decode_attention(q, k, v, lengths)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype],
        )


def _gather(pool, pages):
    """gather_pages' clamp-to-page-0 contract, inlined for independence."""
    N, psz = pool.shape[0], pool.shape[1]
    b, P = pages.shape
    g = jnp.take(pool, jnp.clip(pages, 0, N - 1), axis=0)
    return g.reshape((b, P * psz) + pool.shape[2:])


def _paged_case(key, b, hq, hkv, N, psz, P, d, dtype, unmapped_tail=True):
    """Random pool + page tables with aliasing (pages sampled with
    replacement, so slots share physical pages and single tables repeat
    them — the prefix-sharing/COW shapes) + ragged lengths that include
    exact page-boundary hits, with optional unmapped -1 tails."""
    ks = jax.random.split(key, 6)
    q = _rand(ks[0], (b, hq, d), dtype)
    k_pool = _rand(ks[1], (N, psz, hkv, d), dtype)
    v_pool = _rand(ks[2], (N, psz, hkv, d), dtype)
    pages = jax.random.randint(ks[3], (b, P), 0, N).astype(jnp.int32)
    mapped = jax.random.randint(ks[4], (b,), 1, P + 1)
    if unmapped_tail:
        pages = jnp.where(jnp.arange(P)[None, :] < mapped[:, None],
                          pages, -1)
    # Half the slots land exactly on a page boundary, half mid-page.
    lengths = jax.random.randint(ks[5], (b,), 1, mapped * psz + 1)
    lengths = jnp.where(jnp.arange(b) % 2 == 0,
                        jnp.maximum(lengths // psz, 1) * psz, lengths)
    return q, k_pool, v_pool, pages, lengths.astype(jnp.int32)


class TestPagedDecodeAttention:
    """The paged kernel's contract: bit-identical to gather_pages + the
    dense split-KV kernel (same splits, bkv == page_size) — gather's
    clamp-to-page-0-then-mask semantics are the reference."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("cfg", [
        (2, 8, 2, 12, 16, 4, 64, 1), (2, 8, 2, 12, 16, 4, 64, 4),
        (3, 4, 4, 9, 8, 5, 32, 2), (1, 16, 4, 20, 16, 8, 128, 3),
    ])
    def test_bit_identity_vs_gather_path(self, dtype, cfg):
        from repro.kernels.decode_attention import ops, ref

        b, hq, hkv, N, psz, P, d, splits = cfg
        q, kp, vp, pages, lengths = _paged_case(
            jax.random.PRNGKey(7), b, hq, hkv, N, psz, P, d, dtype
        )
        got = ops.paged_decode_attention(q, kp, vp, pages, lengths,
                                         splits=splits)
        kd = jnp.swapaxes(_gather(kp, pages), 1, 2)
        vd = jnp.swapaxes(_gather(vp, pages), 1, 2)
        want = ops.decode_attention(q, kd, vd, lengths, bkv=psz,
                                    splits=splits)
        # Bitwise: the paged index-map indirection must change nothing.
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        oracle = ref.decode_attention(q, kd, vd, lengths)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(oracle, np.float32),
            **TOL[dtype],
        )

    def test_aliased_shared_pages(self):
        """Two slots whose tables alias the same physical pages (prefix
        sharing) see identical rows: same q => bit-identical output."""
        from repro.kernels.decode_attention import ops

        psz, d = 8, 32
        ks = jax.random.split(jax.random.PRNGKey(8), 3)
        q1 = _rand(ks[0], (1, 4, d), jnp.float32)
        q = jnp.concatenate([q1, q1], axis=0)
        kp = _rand(ks[1], (6, psz, 2, d), jnp.float32)
        vp = _rand(ks[2], (6, psz, 2, d), jnp.float32)
        pages = jnp.asarray([[2, 5, 2], [2, 5, 2]], jnp.int32)
        lengths = jnp.asarray([20, 20], jnp.int32)
        out = ops.paged_decode_attention(q, kp, vp, pages, lengths, splits=2)
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))

    def test_unmapped_tail_contributes_nothing(self):
        """Poisoning every page not reachable below the cursor (including
        the clamp target of -1 entries' positions past lengths) must not
        change a single bit of the output."""
        from repro.kernels.decode_attention import ops

        b, hq, hkv, N, psz, P, d = 2, 4, 2, 8, 8, 4, 32
        q, kp, vp, pages, _ = _paged_case(
            jax.random.PRNGKey(9), b, hq, hkv, N, psz, P, d, jnp.float32,
            unmapped_tail=False,
        )
        pages = jnp.asarray([[3, 1, -1, -1], [6, -1, -1, -1]], jnp.int32)
        lengths = jnp.asarray([2 * psz, psz - 3], jnp.int32)
        clean = ops.paged_decode_attention(q, kp, vp, pages, lengths)
        reachable = jnp.zeros((N,), bool).at[jnp.asarray([3, 1, 6, 0])].set(
            True
        )  # page 0 is the -1 clamp target: read (masked), so keep it clean
        poison = jnp.where(reachable[:, None, None, None], kp, 1e9)
        vpois = jnp.where(reachable[:, None, None, None], vp, -1e9)
        dirty = ops.paged_decode_attention(q, poison, vpois, pages, lengths)
        np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))

    def test_splits_invariance(self):
        """The split-K decomposition is a numerical no-op (combine merges
        partials in fp32): every split count agrees tightly."""
        from repro.kernels.decode_attention import ops

        b, hq, hkv, N, psz, P, d = 2, 8, 2, 12, 16, 6, 64
        q, kp, vp, pages, lengths = _paged_case(
            jax.random.PRNGKey(10), b, hq, hkv, N, psz, P, d, jnp.float32
        )
        outs = [
            np.asarray(ops.paged_decode_attention(
                q, kp, vp, pages, lengths, splits=s
            ))
            for s in (1, 2, 3, P, P + 5)   # over-asking clamps to P pages
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)


class TestDecodeAttentionPlanning:
    """Regression pins for the ops.py wiring bugs: floor-div split
    planning, the ignored ``engine`` argument, and the inner kernel's
    hard-coded interpret=True."""

    def test_plan_splits_counts_padded_grid_blocks(self):
        from repro.kernels.decode_attention.ops import plan_splits

        # s=513, bkv=512: the padded grid runs 2 blocks — floor division
        # said 1 and starved the second block of a split of its own.
        assert plan_splits(513, 512) == 2
        assert plan_splits(512, 512) == 1
        assert plan_splits(4096, 512) == 8
        assert plan_splits(4097, 512, target_parallelism=16) == 9

    def test_engine_plan_drives_splits(self):
        from repro.core import make_engine
        from repro.core.characterize import attention_op
        from repro.kernels.decode_attention.ops import plan_splits

        eng = make_engine()
        plan = eng.plan_op(attention_op(2, 8, 2, 1, 4096, 64, causal=False,
                                        name="decode_attention"))
        want = max(1, min((4096 + plan.block["bkv"] - 1)
                          // plan.block["bkv"], 4096 // 16))
        assert plan_splits(4096, 16, plan=plan) == want

    def test_engine_argument_is_consulted(self):
        import types

        from repro.kernels.decode_attention import ops, ref

        calls = []

        def plan_op(op):
            calls.append(op)
            return types.SimpleNamespace(block={"bq": 1, "bkv": 64})

        fake = types.SimpleNamespace(plan_op=plan_op)
        ks = jax.random.split(jax.random.PRNGKey(11), 3)
        q = _rand(ks[0], (2, 8, 64), jnp.float32)
        k = _rand(ks[1], (2, 2, 256, 64), jnp.float32)
        v = _rand(ks[2], (2, 2, 256, 64), jnp.float32)
        got = ops.decode_attention(q, k, v, engine=fake)
        assert len(calls) == 1, "engine plan must be consulted"
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.decode_attention(q, k, v)),
            **TOL[jnp.float32],
        )

    def test_inner_kernels_default_interpret_from_backend(self):
        import inspect

        from repro.kernels.decode_attention.decode_attention import (
            decode_attention, paged_decode_attention,
        )

        for fn in (decode_attention, paged_decode_attention):
            sig = inspect.signature(fn)
            assert sig.parameters["interpret"].default is None, (
                "inner kernels must defer to interpret_default(), not "
                "hard-code interpret=True (silently interpreted on TPU)"
            )


class TestSSD:
    @pytest.mark.parametrize("cfg", [
        (2, 128, 4, 32, 2, 16, 32), (1, 100, 2, 64, 1, 32, 32),
        (2, 64, 8, 32, 8, 16, 16),
    ])
    def test_vs_ref(self, cfg):
        from repro.kernels.ssd import ref
        from repro.kernels.ssd.ops import ssd

        b, l, h, dh, g, ds, chunk = cfg
        ks = jax.random.split(jax.random.PRNGKey(3), 6)
        x = _rand(ks[0], (b, l, h, dh), jnp.float32)
        dt = jax.nn.softplus(_rand(ks[1], (b, l, h), jnp.float32))
        A = -jnp.exp(_rand(ks[2], (h,), jnp.float32))
        B = _rand(ks[3], (b, l, g, ds), jnp.float32)
        C = _rand(ks[4], (b, l, g, ds), jnp.float32)
        D = _rand(ks[5], (h,), jnp.float32)
        y, S = ssd(x, dt, A, B, C, D, chunk=chunk)
        yr, Sr = ref.ssd(x, dt, A, B, C, D)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(S), np.asarray(Sr),
                                   rtol=2e-3, atol=2e-3)

    def test_decode_step_matches_scan(self):
        from repro.kernels.ssd import ref
        from repro.kernels.ssd.ssd import ssd_decode_step

        b, h, dh, g, ds = 2, 4, 32, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(4), 6)
        x = _rand(ks[0], (b, 1, h, dh), jnp.float32)
        dt = jax.nn.softplus(_rand(ks[1], (b, 1, h), jnp.float32))
        A = -jnp.exp(_rand(ks[2], (h,), jnp.float32))
        B = _rand(ks[3], (b, 1, g, ds), jnp.float32)
        C = _rand(ks[4], (b, 1, g, ds), jnp.float32)
        S0 = _rand(ks[5], (b, h, ds, dh), jnp.float32)
        yr, Sr = ref.ssd(x, dt, A, B, C, None, init_state=S0)
        yd, Sd = ssd_decode_step(x[:, 0], dt[:, 0], A, B[:, 0], C[:, 0],
                                 None, S0)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(yr[:, 0]),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(Sd), np.asarray(Sr),
                                   rtol=2e-3, atol=2e-3)


class TestMoEGmm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("ecKn", [(4, 256, 128, 256), (8, 100, 200, 130)])
    def test_vs_ref(self, dtype, ecKn):
        from repro.kernels.moe_gmm import ref
        from repro.kernels.moe_gmm.ops import grouped_matmul

        e, c, k, n = ecKn
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        x = _rand(ks[0], (e, c, k), dtype)
        w = _rand(ks[1], (e, k, n), dtype)
        counts = jax.random.randint(ks[2], (e,), 0, c + 1).astype(jnp.int32)
        got = grouped_matmul(x, w, counts, bm=64, bn=64, bk=64)
        want = ref.grouped_matmul(x, w, counts)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype],
        )

    def test_empty_experts_write_zero(self):
        from repro.kernels.moe_gmm.ops import grouped_matmul

        x = jnp.ones((2, 64, 64), jnp.float32)
        w = jnp.ones((2, 64, 64), jnp.float32)
        counts = jnp.array([0, 64], jnp.int32)
        y = grouped_matmul(x, w, counts, bm=64, bn=64, bk=64)
        assert float(jnp.abs(y[0]).max()) == 0.0
        assert float(jnp.abs(y[1]).min()) > 0.0


class TestFusedNorm:
    @pytest.mark.parametrize("kind", ["rms", "layer"])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(4, 100, 512), (300, 256)])
    def test_vs_ref(self, kind, dtype, shape):
        from repro.kernels.fused_norm import ref
        from repro.kernels.fused_norm.ops import fused_norm

        ks = jax.random.split(jax.random.PRNGKey(6), 4)
        x = _rand(ks[0], shape, dtype)
        w = _rand(ks[1], (shape[-1],), jnp.float32)
        b = _rand(ks[2], (shape[-1],), jnp.float32) if kind == "layer" else None
        r = _rand(ks[3], shape, dtype)
        got = fused_norm(x, w, b, r, kind=kind)
        want = ref.fused_norm(x, w, b, r, kind=kind)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype],
        )
