"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

TOL = {jnp.float32: dict(rtol=2e-3, atol=2e-3),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestMatmul:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("mkn", [(256, 128, 256), (512, 384, 128),
                                     (130, 70, 90)])
    @pytest.mark.parametrize("kwargs", [
        dict(bm=128, bn=128, bk=128),
        dict(bm=128, bn=128, bk=128, split_k=3),
    ])
    def test_vs_ref(self, dtype, mkn, kwargs):
        from repro.kernels.matmul import ref
        from repro.kernels.matmul.ops import matmul

        m, k, n = mkn
        a = _rand(jax.random.PRNGKey(0), (m, k), dtype)
        b = _rand(jax.random.PRNGKey(1), (k, n), dtype)
        got = matmul(a, b, **kwargs)
        want = ref.matmul(a, b)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype],
        )

    def test_grid_orders_match(self):
        from repro.kernels.matmul.matmul import matmul as kern

        a = _rand(jax.random.PRNGKey(0), (256, 256), jnp.float32)
        b = _rand(jax.random.PRNGKey(1), (256, 256), jnp.float32)
        y1 = kern(a, b, bm=128, bn=128, bk=128, order="mnk")
        y2 = kern(a, b, bm=128, bn=128, bk=128, order="nmk")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5)

    def test_engine_planned(self):
        from repro.core import make_engine
        from repro.kernels.matmul.ops import matmul

        a = jnp.ones((200, 300), jnp.float32)
        b = jnp.ones((300, 100), jnp.float32)
        y = matmul(a, b, engine=make_engine())
        np.testing.assert_allclose(np.asarray(y), 300.0, rtol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("cfg", [
        (2, 4, 2, 256, 256, 64, True, 0),
        (1, 8, 1, 128, 256, 32, True, 128),
        (2, 4, 4, 100, 100, 64, False, 0),
        (1, 6, 2, 192, 64, 128, False, 0),
    ])
    def test_vs_ref(self, dtype, cfg):
        from repro.kernels.flash_attention import ref
        from repro.kernels.flash_attention.ops import flash_attention

        b, hq, hkv, sq, skv, d, causal, off = cfg
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = _rand(ks[0], (b, hq, sq, d), dtype)
        k = _rand(ks[1], (b, hkv, skv, d), dtype)
        v = _rand(ks[2], (b, hkv, skv, d), dtype)
        got = flash_attention(q, k, v, causal=causal, q_offset=off,
                              bq=64, bkv=64)
        want = ref.attention(q, k, v, causal=causal, q_offset=off)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype],
        )


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("cfg", [
        (2, 8, 2, 512, 64, 128, 1), (2, 8, 2, 512, 64, 128, 4),
        (3, 4, 4, 300, 32, 64, 2), (1, 16, 1, 1024, 128, 256, 8),
    ])
    def test_vs_ref_ragged(self, dtype, cfg):
        from repro.kernels.decode_attention import ref
        from repro.kernels.decode_attention.ops import decode_attention

        b, hq, hkv, s, d, bkv, splits = cfg
        ks = jax.random.split(jax.random.PRNGKey(2), 4)
        q = _rand(ks[0], (b, hq, d), dtype)
        k = _rand(ks[1], (b, hkv, s, d), dtype)
        v = _rand(ks[2], (b, hkv, s, d), dtype)
        lengths = jax.random.randint(ks[3], (b,), 1, s + 1).astype(jnp.int32)
        got = decode_attention(q, k, v, lengths, bkv=bkv, splits=splits)
        want = ref.decode_attention(q, k, v, lengths)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype],
        )


class TestSSD:
    @pytest.mark.parametrize("cfg", [
        (2, 128, 4, 32, 2, 16, 32), (1, 100, 2, 64, 1, 32, 32),
        (2, 64, 8, 32, 8, 16, 16),
    ])
    def test_vs_ref(self, cfg):
        from repro.kernels.ssd import ref
        from repro.kernels.ssd.ops import ssd

        b, l, h, dh, g, ds, chunk = cfg
        ks = jax.random.split(jax.random.PRNGKey(3), 6)
        x = _rand(ks[0], (b, l, h, dh), jnp.float32)
        dt = jax.nn.softplus(_rand(ks[1], (b, l, h), jnp.float32))
        A = -jnp.exp(_rand(ks[2], (h,), jnp.float32))
        B = _rand(ks[3], (b, l, g, ds), jnp.float32)
        C = _rand(ks[4], (b, l, g, ds), jnp.float32)
        D = _rand(ks[5], (h,), jnp.float32)
        y, S = ssd(x, dt, A, B, C, D, chunk=chunk)
        yr, Sr = ref.ssd(x, dt, A, B, C, D)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(S), np.asarray(Sr),
                                   rtol=2e-3, atol=2e-3)

    def test_decode_step_matches_scan(self):
        from repro.kernels.ssd import ref
        from repro.kernels.ssd.ssd import ssd_decode_step

        b, h, dh, g, ds = 2, 4, 32, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(4), 6)
        x = _rand(ks[0], (b, 1, h, dh), jnp.float32)
        dt = jax.nn.softplus(_rand(ks[1], (b, 1, h), jnp.float32))
        A = -jnp.exp(_rand(ks[2], (h,), jnp.float32))
        B = _rand(ks[3], (b, 1, g, ds), jnp.float32)
        C = _rand(ks[4], (b, 1, g, ds), jnp.float32)
        S0 = _rand(ks[5], (b, h, ds, dh), jnp.float32)
        yr, Sr = ref.ssd(x, dt, A, B, C, None, init_state=S0)
        yd, Sd = ssd_decode_step(x[:, 0], dt[:, 0], A, B[:, 0], C[:, 0],
                                 None, S0)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(yr[:, 0]),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(Sd), np.asarray(Sr),
                                   rtol=2e-3, atol=2e-3)


class TestMoEGmm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("ecKn", [(4, 256, 128, 256), (8, 100, 200, 130)])
    def test_vs_ref(self, dtype, ecKn):
        from repro.kernels.moe_gmm import ref
        from repro.kernels.moe_gmm.ops import grouped_matmul

        e, c, k, n = ecKn
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        x = _rand(ks[0], (e, c, k), dtype)
        w = _rand(ks[1], (e, k, n), dtype)
        counts = jax.random.randint(ks[2], (e,), 0, c + 1).astype(jnp.int32)
        got = grouped_matmul(x, w, counts, bm=64, bn=64, bk=64)
        want = ref.grouped_matmul(x, w, counts)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype],
        )

    def test_empty_experts_write_zero(self):
        from repro.kernels.moe_gmm.ops import grouped_matmul

        x = jnp.ones((2, 64, 64), jnp.float32)
        w = jnp.ones((2, 64, 64), jnp.float32)
        counts = jnp.array([0, 64], jnp.int32)
        y = grouped_matmul(x, w, counts, bm=64, bn=64, bk=64)
        assert float(jnp.abs(y[0]).max()) == 0.0
        assert float(jnp.abs(y[1]).min()) > 0.0


class TestFusedNorm:
    @pytest.mark.parametrize("kind", ["rms", "layer"])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(4, 100, 512), (300, 256)])
    def test_vs_ref(self, kind, dtype, shape):
        from repro.kernels.fused_norm import ref
        from repro.kernels.fused_norm.ops import fused_norm

        ks = jax.random.split(jax.random.PRNGKey(6), 4)
        x = _rand(ks[0], shape, dtype)
        w = _rand(ks[1], (shape[-1],), jnp.float32)
        b = _rand(ks[2], (shape[-1],), jnp.float32) if kind == "layer" else None
        r = _rand(ks[3], shape, dtype)
        got = fused_norm(x, w, b, r, kind=kind)
        want = ref.fused_norm(x, w, b, r, kind=kind)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype],
        )
