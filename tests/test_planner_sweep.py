"""Planner/sweep invariants (deterministic — no hypothesis needed).

Covers the batched-memoized planning pipeline:

* cached plans/costs are bit-identical to cold-path plans/costs,
* the vectorized lattice sweep reproduces the scalar cost model,
* the exact lattice search is never worse than the greedy
  ``adaptive_assignment`` on the full 17-workload suite,
* paper §VI.A classifications are unchanged by the new machinery,
* the PlanCache amortizes repeated launches (RNN suites, transformer
  layers) with a high hit rate.
"""
import itertools
import json
import os
import subprocess
import sys

import pytest

from repro import hw
from repro.core import Policy, StaticMode, make_engine
from repro.core.characterize import (
    elementwise_op,
    matmul_op,
    rowwise_op,
)
from repro.core.cost_model import (
    CALIB,
    CostCalib,
    adaptive_assignment,
    op_cost,
    plan_residency,
    workload_cost,
)
from repro.core.planner import PlanCache, Planner, fingerprint_op
from repro.core.policy import static_assignment
from repro.core.sweep import SweepTable, optimal_assignment, sweep_ops
from repro.workloads.suite import SUITE

CHIPS = (hw.PAPER_GPU, hw.V5E)
STATIC = (StaticMode.UNCACHED, StaticMode.CACHER, StaticMode.CACHERW)


def _suite_ops():
    return [op for w in SUITE.values() for op in w.ops]


# ---------------------------------------------------------------------------
# Satellite (a): cached == cold, across modes and chips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chip", CHIPS, ids=lambda c: c.name)
def test_cached_costs_identical_to_cold_path(chip):
    planner = Planner(chip=chip, cache=PlanCache())
    for op in _suite_ops():
        for mode in STATIC:
            a = static_assignment(op, mode)
            for ab, rn in itertools.product((False, True), repeat=2):
                cold = op_cost(op, assignment=a, chip=chip,
                               allocation_bypass=ab, rinse=rn, launches=2)
                first = planner.cost(op, assignment=a, allocation_bypass=ab,
                                     rinse=rn, launches=2)
                hit = planner.cost(op, assignment=a, allocation_bypass=ab,
                                   rinse=rn, launches=2)
                assert cold == first == hit, (op.name, mode, ab, rn)


@pytest.mark.parametrize("chip", CHIPS, ids=lambda c: c.name)
def test_cached_plans_identical_to_cold_path(chip):
    from repro.core import allocator

    planner = Planner(chip=chip, cache=PlanCache())
    for op in _suite_ops():
        for mode in STATIC:
            a = static_assignment(op, mode)
            cold = allocator.plan_op(op, a, chip=chip)
            cached = planner.plan(op, a)
            again = planner.plan(op, a)
            for plan in (cached, again):
                assert plan.assignment == cold.assignment
                assert plan.block == cold.block
                assert plan.grid_order == cold.grid_order
                assert plan.vmem_bytes == cold.vmem_bytes
                assert plan.demotions == cold.demotions
                assert plan.shrink_events == cold.shrink_events


def test_workload_cost_memoized_identical():
    for name, w in SUITE.items():
        for mode in (*STATIC, StaticMode.ADAPTIVE):
            cold = workload_cost(w.ops, mode=mode, chip=hw.PAPER_GPU,
                                 memoize=False)
            warm = workload_cost(w.ops, mode=mode, chip=hw.PAPER_GPU,
                                 plan_cache=PlanCache())
            assert cold == warm, (name, mode)


# ---------------------------------------------------------------------------
# Vectorized sweep == scalar reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chip", CHIPS, ids=lambda c: c.name)
def test_batch_sweep_matches_scalar_cost_model(chip):
    ops = _suite_ops()
    bs = sweep_ops(ops, chip=chip)
    fields = ("t_compute", "t_hbm", "t_overhead", "t_total", "read_bytes",
              "write_bytes", "write_contiguity", "stall_frac")
    for i, op in enumerate(ops):
        for mode in STATIC:
            for ab, rn in itertools.product((False, True), repeat=2):
                ref = op_cost(op, mode=mode, chip=chip, allocation_bypass=ab,
                              rinse=rn, launches=1)
                got = bs.breakdown(i, mode=mode, allocation_bypass=ab,
                                   rinse=rn, launches=1)
                for f in fields:
                    a, b = getattr(ref, f), getattr(got, f)
                    assert abs(a - b) <= 1e-9 * max(abs(a), 1e-30), (
                        op.name, mode, ab, rn, f, a, b
                    )
                assert ref.demotions == got.demotions
                assert ref.vmem_claimed == got.vmem_claimed


# ---------------------------------------------------------------------------
# Satellite (b): exact lattice search never worse than greedy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chip", CHIPS, ids=lambda c: c.name)
def test_exact_search_never_worse_than_greedy_on_suite(chip):
    for name, w in SUITE.items():
        for op in w.ops:
            for ab, rn in itertools.product((False, True), repeat=2):
                greedy = adaptive_assignment(op, chip)
                exact = optimal_assignment(op, chip=chip,
                                           allocation_bypass=ab, rinse=rn)
                t_g = op_cost(op, assignment=greedy, chip=chip,
                              allocation_bypass=ab, rinse=rn,
                              launches=0).t_total
                t_e = op_cost(op, assignment=exact, chip=chip,
                              allocation_bypass=ab, rinse=rn,
                              launches=0).t_total
                assert t_e <= t_g, (name, op.name, ab, rn, t_e, t_g)


def test_adaptive_workload_cost_never_worse_than_best_static():
    """With the exact search, the paper-headline bound holds with NO slack
    (the greedy path needed a 5% tolerance)."""
    for name, w in SUITE.items():
        times = {
            mode: workload_cost(w.ops, mode=mode, chip=hw.PAPER_GPU,
                                launches_per_op=0).t_total
            for mode in (*STATIC, StaticMode.ADAPTIVE)
        }
        best = min(times[m] for m in STATIC)
        assert times[StaticMode.ADAPTIVE] <= best, (name, times)


# ---------------------------------------------------------------------------
# Classification unchanged (paper §VI.A)
# ---------------------------------------------------------------------------

def test_suite_classification_unchanged():
    from repro.core.characterize import classify_workload

    mismatches = {
        name: (w.expected.value,
               classify_workload(w.ops, chip=hw.PAPER_GPU).value)
        for name, w in SUITE.items()
        if classify_workload(w.ops, chip=hw.PAPER_GPU) != w.expected
    }
    assert not mismatches, mismatches


def test_sweep_table_classification_matches_scalar():
    from repro.core.characterize import classify_workload

    table = SweepTable(chip=hw.PAPER_GPU)
    for name, w in SUITE.items():
        via_table = classify_workload(
            w.ops, chip=hw.PAPER_GPU,
            cost_fn=lambda ops, mode: table.workload_cost(
                ops, mode=mode, launches_per_op=0
            ),
        )
        assert via_table == w.expected, name


# ---------------------------------------------------------------------------
# Bugfix regression: plan_residency honours the caller's calibration
# ---------------------------------------------------------------------------

def test_plan_residency_uses_caller_calib():
    from repro.core.characterize import window_op

    # An op whose resident window only partially fits -> 0 < realized < 1.
    op = window_op(1 << 23, 5, 1, reuse_distance_elems=1 << 22, dtype="f32")
    a = static_assignment(op, StaticMode.CACHER)
    base = plan_residency(op, a, hw.PAPER_GPU, CALIB)
    frac = min(base.realized.values())
    assert 0.0 < frac < 1.0
    # demote_threshold below every realized fraction -> no demotions;
    # above -> all resident operands demoted.  Pre-fix, the module-global
    # CALIB.demote_threshold silently overrode both.
    lo = CostCalib(demote_threshold=frac * 0.5)
    hi = CostCalib(demote_threshold=1.1)
    assert plan_residency(op, a, hw.PAPER_GPU, lo).demotions == ()
    assert len(plan_residency(op, a, hw.PAPER_GPU, hi).demotions) == len(
        base.realized
    )


# ---------------------------------------------------------------------------
# Fingerprints + OpSpec hygiene
# ---------------------------------------------------------------------------

def test_fingerprint_shared_by_equal_ops_and_sensitive_to_meta():
    a = matmul_op(512, 512, 512)
    b = matmul_op(512, 512, 512, name="other_name")   # name excluded
    assert fingerprint_op(a) == fingerprint_op(b)
    import dataclasses

    c = dataclasses.replace(a, meta={**a.meta, "achieved_eff": 0.3})
    assert fingerprint_op(a) != fingerprint_op(c)
    d = matmul_op(512, 512, 1024)
    assert fingerprint_op(a) != fingerprint_op(d)


def test_suite_ops_not_mutated_in_place():
    """_with_eff / operand patches must produce new OpSpecs (frozen
    semantics), so fingerprints can never go stale."""
    from repro.workloads.suite import build_suite

    s1 = build_suite()
    s2 = build_suite()
    for name in s1:
        for o1, o2 in zip(s1[name].ops, s2[name].ops):
            assert fingerprint_op(o1) == fingerprint_op(o2)
    assert s1["FwFc"].ops[0].meta["achieved_eff"] == 0.75
    assert s1["BwBN"].ops[0].operands[-1].revisits == 4


# ---------------------------------------------------------------------------
# Cache amortization: repeated launches plan once
# ---------------------------------------------------------------------------

def test_plan_cache_amortizes_rnn_launches():
    eng = make_engine(plan_cache=PlanCache())
    w = SUITE["FwBwLSTM"]
    for i in range(w.launches):
        op = w.ops[i % len(w.ops)]
        plan = eng.plan_op(op)
        eng.cost(op, plan)
    stats = eng.plan_stats()
    assert stats["hit_rate"] > 0.8, stats


def test_plan_cache_amortizes_transformer_layers():
    from repro.configs.base import SHAPES
    from repro.launch.dryrun import plan_model_policies
    from repro.models import get_config

    report = plan_model_policies(get_config("yi-9b"), SHAPES["decode_32k"])
    assert report["plan_cache_hit_rate"] > 0.8, report
    assert report["ops_planned"] == report["layers"] * report["ops_per_layer"]


def test_launch_plan_returns_consistent_cached_objects():
    planner = Planner(chip=hw.V5E, cache=PlanCache())
    op = rowwise_op(512, 2048, passes=3)
    p1, c1 = planner.launch_plan(op)
    p2, c2 = planner.launch_plan(op)
    assert p1 is p2 and c1 is c2          # shared cached instances
    ref = op_cost(op, assignment=p1.assignment, chip=hw.V5E, launches=1)
    assert c1 == ref


# ---------------------------------------------------------------------------
# Engine / serve integration
# ---------------------------------------------------------------------------

def test_engine_cost_matches_pre_cache_semantics():
    """Engine cost through the planner == direct op_cost + MXU fold."""
    from repro.core import allocator

    eng = make_engine(plan_cache=PlanCache())
    op = matmul_op(2048, 4096, 1024)
    plan = eng.plan_op(op)
    got = eng.cost(op, plan)
    ref = op_cost(op, assignment=plan.assignment, chip=eng.chip)
    ref.t_compute /= allocator.mxu_efficiency(plan, eng.chip)
    ref.t_total = max(ref.t_compute, ref.t_hbm) + ref.t_overhead
    assert got == ref


def test_engine_seeds_under_its_own_machine_model():
    """An AB-off engine must seed from the AB-off lattice optimum: the
    exact-<=-greedy guarantee has to hold under the engine's own knobs."""
    for ab, rn in itertools.product((False, True), repeat=2):
        eng = make_engine(allocation_bypass=ab, rinse=rn, chip="gem5-apu",
                          plan_cache=PlanCache())
        for w in SUITE.values():
            for op in w.ops:
                a = eng.assign(op)
                greedy = adaptive_assignment(op, eng.chip)
                t_a = op_cost(op, assignment=a, chip=eng.chip,
                              allocation_bypass=ab, rinse=rn,
                              launches=0).t_total
                t_g = op_cost(op, assignment=greedy, chip=eng.chip,
                              allocation_bypass=ab, rinse=rn,
                              launches=0).t_total
                assert t_a <= t_g, (op.name, ab, rn, t_a, t_g)


def test_opspec_meta_is_frozen():
    """In-place meta mutation would silently alias stale fingerprints in
    the plan cache — it must fail loudly instead."""
    op = matmul_op(256, 256, 256)
    with pytest.raises(TypeError):
        op.meta["achieved_eff"] = 0.1
    import dataclasses

    op2 = dataclasses.replace(op, meta={**op.meta, "achieved_eff": 0.1})
    assert op2.meta["achieved_eff"] == 0.1
    assert fingerprint_op(op2) != fingerprint_op(op)


def test_wide_ops_fall_back_to_greedy_not_lattice_blowup():
    """2^operands rows must never be materialized for wide ops: the search
    falls back to greedy and SweepTable serves scalar costs."""
    wide_out = elementwise_op(1 << 16, n_inputs=2, n_outputs=28, dtype="f32")
    wide_in = elementwise_op(1 << 16, n_inputs=20, n_outputs=1, dtype="f32")
    for op in (wide_out, wide_in):
        a = optimal_assignment(op, chip=hw.PAPER_GPU)
        assert a == adaptive_assignment(op, hw.PAPER_GPU)
        table = SweepTable(chip=hw.PAPER_GPU)
        for mode in (*STATIC, StaticMode.ADAPTIVE):
            got = table.op_cost(op, mode=mode, allocation_bypass=False,
                                rinse=False)
            ref = workload_cost([op], mode=mode, chip=hw.PAPER_GPU,
                                allocation_bypass=False, rinse=False,
                                memoize=False, search="greedy")
            assert got == ref, mode
        assert table.best_assignment(op) == a


def test_plan_cache_keys_chip_by_content_not_name():
    """Two same-named chips with different parameters must not alias
    entries in a shared cache."""
    import dataclasses

    fast_hbm = dataclasses.replace(hw.V5E, hbm_bw=hw.V5E.hbm_bw * 4)
    assert fast_hbm.name == hw.V5E.name
    cache = PlanCache()
    op = matmul_op(1024, 1024, 1024)
    a = static_assignment(op, StaticMode.UNCACHED)
    c1 = Planner(chip=hw.V5E, cache=cache).cost(op, assignment=a)
    c2 = Planner(chip=fast_hbm, cache=cache).cost(op, assignment=a)
    assert c2.t_hbm < c1.t_hbm / 2
    assert c1 == op_cost(op, assignment=a, chip=hw.V5E)
    assert c2 == op_cost(op, assignment=a, chip=fast_hbm)


def test_elementwise_exact_search_prefers_stream():
    op = elementwise_op(1 << 24, dtype="f32")
    a = optimal_assignment(op, chip=hw.PAPER_GPU)
    assert all(p is Policy.STREAM for p in a.values())


# ---------------------------------------------------------------------------
# Benchmark JSON plumbing
# ---------------------------------------------------------------------------

def test_benchmark_json_smoke(tmp_path):
    out = tmp_path / "bench.json"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), env.get("PYTHONPATH")) if p
    )
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--json", str(out),
         "--analytic-only", "--reps", "1"],
        capture_output=True, text=True, timeout=600, cwd=root, env=env,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    blob = json.loads(out.read_text())
    assert blob["sweep_wall_s"] > 0
    assert blob["seed_sweep_wall_s"] > 0
    assert 0.0 < blob["plan_cache_hit_rate"] <= 1.0
    assert blob["rows"], "no benchmark rows emitted"
    names = {row["name"] for row in blob["rows"]}
    assert any(n.startswith("fig10_12/") for n in names)
    assert any(n.startswith("replay/") for n in names)
