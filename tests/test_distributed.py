"""Distribution-layer tests.  shard_map/pjit behaviours need >1 device, so
they run in a subprocess with 8 forced host devices (keeping this process,
and every other test, on 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SNIPPET_HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
"""


def _run(snippet: str, timeout=420):
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", _SNIPPET_HEADER + textwrap.dedent(snippet)],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_sp_decode_matches_reference():
    _run("""
    from repro.distributed.sp_decode import sp_decode_attention, reference
    mesh = jax.make_mesh((8,), ("data",))
    b, hq, hkv, S, d = 2, 8, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, S, d), jnp.float32)
    lengths = jnp.array([500, 300], jnp.int32)
    got = sp_decode_attention(q, k, v, lengths, mesh, axis="data")
    want = reference(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    print("sp_decode ok")
    """)


def test_bucketed_and_compressed_all_reduce():
    _run("""
    from jax.experimental.shard_map import shard_map
    from repro.distributed.collectives import (bucketed_all_reduce,
                                               compressed_all_reduce)
    mesh = jax.make_mesh((8,), ("d",))
    gs = [jax.random.normal(jax.random.PRNGKey(i), (8, 13 + i), jnp.float32)
          for i in range(5)]

    def f(*gs):
        outs = bucketed_all_reduce(list(gs), "d", bucket_bytes=256)
        return tuple(outs)

    outs = shard_map(f, mesh=mesh,
                     in_specs=tuple(P("d") for _ in gs),
                     out_specs=tuple(P("d") for _ in gs))(*gs)
    for g, o in zip(gs, outs):
        want = jnp.broadcast_to(g.reshape(8, 1, -1).sum(0, keepdims=True),
                                (8, 1, g.shape[1])).reshape(8, -1)
        np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    print("bucketed ok")

    g = jax.random.normal(jax.random.PRNGKey(9), (8, 64), jnp.float32)
    err0 = jnp.zeros_like(g)

    def c(g, e):
        return compressed_all_reduce(g, e, "d")

    red, err = shard_map(c, mesh=mesh, in_specs=(P("d"), P("d")),
                         out_specs=(P("d"), P("d")))(g, err0)
    want = jnp.mean(g, axis=0)
    got = np.asarray(red[0])
    rel = np.abs(got - np.asarray(want)).max() / (np.abs(want).max() + 1e-9)
    assert rel < 0.05, rel          # int8 quantization error bound
    assert float(jnp.abs(err).max()) > 0   # error feedback carries residual
    print("compressed ok, rel", rel)
    """)


def test_sharded_train_step_matches_single_device():
    _run("""
    from repro.models import get_config
    from repro.train.step import TrainConfig, init_train_state, make_train_step
    from repro.train import optimizer as opt
    from repro.distributed import sharding as sh
    from repro.data.pipeline import SyntheticLM

    cfg = get_config("yi-9b", smoke=True)
    tcfg = TrainConfig(adamw=opt.AdamWConfig(lr=1e-3, warmup_steps=0,
                                             total_steps=10),
                       batch_axes=("data",))
    train_step, model = make_train_step(cfg, tcfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    batch = SyntheticLM(cfg, batch=8, seq=16, seed=0)(0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    # single device reference
    ref_state, ref_metrics = jax.jit(train_step)(state, batch)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    pshard = sh.params_shardings(state["params"], cfg, mesh)
    oshard = opt.opt_shardings(pshard, state["params"], mesh, zero1=True)
    sshard = {"params": pshard, "opt": oshard}
    bspec = sh.batch_spec(cfg, mesh, 8)
    bshard = {k: NamedSharding(mesh, bspec[k]) for k in batch}
    state2 = init_train_state(model, jax.random.PRNGKey(0))
    with mesh:
        state2 = jax.device_put(state2, sshard)
        batch2 = jax.device_put(batch, bshard)
        new_state, metrics = jax.jit(
            train_step, in_shardings=(sshard, bshard),
            out_shardings=(sshard, None),
        )(state2, batch2)
    np.testing.assert_allclose(float(metrics["loss"]),
                               float(ref_metrics["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(ref_state["params"]),
                    jax.tree_util.tree_leaves(new_state["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-3)
    print("sharded == single device")
    """)


def test_moe_ep_sharded_forward_matches():
    _run("""
    from repro.models import build_model, get_config
    from repro.distributed import sharding as sh

    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    want, _ = model.forward(params, toks)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    pshard = sh.params_shardings(params, cfg, mesh)
    with mesh:
        params2 = jax.device_put(params, pshard)
        toks2 = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
        got, _ = jax.jit(model.forward)(params2, toks2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-3, atol=3e-3)
    print("moe ep ok")
    """)
