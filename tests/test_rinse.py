"""Cache-rinsing (dirty index + flush scheduling) property tests.

Requires the optional ``hypothesis`` dev dependency (requirements-dev.txt);
the module skips gracefully when it is absent.
"""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.rinse import (
    DirtyIndex,
    Extent,
    bucket_flush_schedule,
    write_contiguity,
)


@settings(max_examples=80, deadline=None)
@given(
    n_tiles=st.integers(1, 200),
    tile_size=st.integers(64, 4096),
    region=st.integers(1024, 65536),
    order=st.randoms(),
    rinse=st.booleans(),
)
def test_every_dirty_byte_flushed_exactly_once(n_tiles, tile_size, region,
                                               order, rinse):
    idx = DirtyIndex(region_bytes=region)
    for t in range(n_tiles):
        idx.mark(t, t * tile_size, tile_size)
    evict_order = list(range(n_tiles))
    order.shuffle(evict_order)
    flushed = []
    for t in evict_order:
        flushed.extend(idx.evict(t, rinse=rinse))
    assert sorted(t for t, _ in flushed) == list(range(n_tiles))
    assert idx.dirty_tiles == 0


@settings(max_examples=60, deadline=None)
@given(
    n_tiles=st.integers(2, 150),
    tile_size=st.sampled_from([256, 512, 1024]),
    order=st.randoms(),
)
def test_rinse_contiguity_geq_no_rinse(n_tiles, tile_size, order):
    """Rinsing flushes whole regions address-ordered -> contiguity can only
    improve over eviction-order flushing (paper Fig 13)."""
    def run(rinse):
        idx = DirtyIndex(region_bytes=8 * tile_size)
        for t in range(n_tiles):
            idx.mark(t, t * tile_size, tile_size)
        ev = list(range(n_tiles))
        order.shuffle(ev)
        out = []
        for t in ev:
            out.extend(e for _, e in idx.evict(t, rinse=rinse))
        return write_contiguity(out, burst_bytes=tile_size)

    # Same shuffled order for both runs (hypothesis randoms are stateful:
    # re-seed by running rinse variant on a fresh copy of the order).
    ev = list(range(n_tiles))
    order.shuffle(ev)

    def run_fixed(rinse):
        idx = DirtyIndex(region_bytes=8 * tile_size)
        for t in range(n_tiles):
            idx.mark(t, t * tile_size, tile_size)
        out = []
        for t in ev:
            out.extend(e for _, e in idx.evict(t, rinse=rinse))
        return write_contiguity(out, burst_bytes=tile_size)

    assert run_fixed(True) >= run_fixed(False) - 1e-12


def test_write_contiguity_metric():
    # Perfectly sequential extents: full contiguity.
    seq = [Extent(i * 512, 512) for i in range(16)]
    assert write_contiguity(seq, burst_bytes=512) == 1.0
    # Reversed order: every write breaks the run.
    rev = list(reversed(seq))
    assert write_contiguity(rev, burst_bytes=1024) < 0.6


@settings(max_examples=80, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 1 << 22), min_size=1, max_size=200),
    bucket=st.integers(1 << 16, 1 << 24),
)
def test_bucket_schedule_partitions_in_order(sizes, bucket):
    buckets = bucket_flush_schedule(sizes, bucket)
    flat = [i for b in buckets for i in b]
    assert flat == list(range(len(sizes)))          # order preserved, complete
    for b in buckets:
        if len(b) > 1:
            assert sum(sizes[i] for i in b) <= bucket


def test_flush_all_rinse_is_address_sorted():
    idx = DirtyIndex(region_bytes=1 << 30)
    import random

    rng = random.Random(0)
    tiles = list(range(50))
    rng.shuffle(tiles)
    for t in tiles:
        idx.mark(t, t * 512, 512)
    flushes = idx.flush_all(rinse=True)
    addrs = [e.addr for _, e in flushes]
    assert addrs == sorted(addrs)
    assert write_contiguity([e for _, e in flushes], 512) == 1.0
