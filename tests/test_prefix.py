"""Unit tests for the host-side prefix trie (serve.prefix, DESIGN.md §5.4).

The trie indexes resident full KV pages by token content; the serve
engine owns residency (refcounted PageAllocator) and calls ``evict`` when
pages free.  These tests pin the contract the engine relies on:
longest-match lookup, full-pages-only participation (a partial page never
shares), leaf-upward eviction of zero-ref nodes, and clean re-admission
after release.
"""
import numpy as np
import pytest

from repro.serve.prefix import PrefixIndex

PSZ = 4


def _toks(*chunks):
    """Flatten chunk lists into one token array (np, like r.prompt)."""
    return np.asarray([t for ch in chunks for t in ch], np.int32)


A, B, C, D = (
    [1, 2, 3, 4], [5, 6, 7, 8], [9, 10, 11, 12], [13, 14, 15, 16]
)


def test_longest_match_lookup():
    idx = PrefixIndex(PSZ)
    idx.register(_toks(A, B, C), [10, 11, 12])
    assert idx.lookup(_toks(A, B, C)) == [10, 11, 12]
    # Divergence after two chunks: longest match is the shared prefix.
    assert idx.lookup(_toks(A, B, D)) == [10, 11]
    assert idx.lookup(_toks(A, D)) == [10]
    assert idx.lookup(_toks(D, A, B)) == []
    # A longer query than the resident chain matches the whole chain.
    assert idx.lookup(_toks(A, B, C, D)) == [10, 11, 12]
    assert len(idx) == 3
    assert idx.resident_tokens() == 3 * PSZ


def test_partial_page_boundary_never_shared():
    idx = PrefixIndex(PSZ)
    # Register a prompt of 2.5 pages: only the 2 FULL pages may be indexed.
    idx.register(_toks(A, B, [99, 98]), [20, 21])
    assert len(idx) == 2
    # Lookup of 1.75 pages matches only the full first page.
    assert idx.lookup(_toks(A, B[:3])) == [20]
    # A sub-page prompt can never match anything.
    assert idx.lookup(_toks(A[:3])) == []
    # A whole-page query ending at the boundary matches exactly.
    assert idx.lookup(_toks(A, B)) == [20, 21]


def test_register_keeps_existing_nodes():
    """Re-registering resident content must NOT displace the original
    page (other slots share it); only new chunks register, and the newly
    indexed ids are reported back."""
    idx = PrefixIndex(PSZ)
    assert idx.register(_toks(A, B), [30, 31]) == [30, 31]
    # Same prefix from another slot's table: nothing new registered,
    # lookups keep resolving to the original pages.
    assert idx.register(_toks(A, B), [40, 41]) == []
    assert idx.lookup(_toks(A, B)) == [30, 31]
    # Extending the chain registers only the new tail chunk.
    assert idx.register(_toks(A, B, C), [40, 41, 42]) == [42]
    assert idx.lookup(_toks(A, B, C)) == [30, 31, 42]


def test_eviction_of_zero_ref_nodes():
    idx = PrefixIndex(PSZ)
    idx.register(_toks(A, B, C), [10, 11, 12])
    idx.register(_toks(A, D), [10, 13])        # sibling branch under A
    assert len(idx) == 4
    # Leaf eviction: the chain shortens, siblings survive.
    assert idx.evict([12]) == 1
    assert idx.lookup(_toks(A, B, C)) == [10, 11]
    assert idx.lookup(_toks(A, D)) == [10, 13]
    # Parent + child freed together (a finishing last sharer): any
    # argument order works — eviction is depth-ordered internally.
    assert idx.evict([10, 13, 11]) == 3
    assert len(idx) == 0
    assert idx.lookup(_toks(A, B)) == []
    # Ids never registered (tail/decode pages) are ignored.
    assert idx.evict([77]) == 0


def test_evicting_parent_with_resident_child_asserts():
    """A parent page freeing before its child breaks the refcount
    invariant (every sharer holds the whole chain) — fail loudly."""
    idx = PrefixIndex(PSZ)
    idx.register(_toks(A, B), [10, 11])
    with pytest.raises(AssertionError, match="still resident"):
        idx.evict([10])


def test_readmission_after_release():
    """After a full release/evict cycle the same prompt re-registers
    cleanly under fresh pages — no stale nodes, no page-id aliasing."""
    idx = PrefixIndex(PSZ)
    idx.register(_toks(A, B), [10, 11])
    idx.evict([11, 10])
    assert len(idx) == 0
    # Fresh registration may reuse the very same (recycled) page ids.
    assert idx.register(_toks(A, B), [11, 10]) == [11, 10]
    assert idx.lookup(_toks(A, B)) == [11, 10]


def test_register_rejects_reused_page_id():
    """One physical page backs exactly one trie node: registering a
    held page under a second prefix is an engine bookkeeping bug."""
    idx = PrefixIndex(PSZ)
    idx.register(_toks(A), [10])
    with pytest.raises(AssertionError, match="already registered"):
        idx.register(_toks(B), [10])
